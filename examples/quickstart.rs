//! Quickstart: the three layers of the stack in ~60 lines.
//!
//!   cargo run --release --example quickstart
//!
//! 1. Characterize one in-word GRNG cell (the paper's entropy source).
//! 2. Program a CIM tile, calibrate it, run a Bayesian MVM.
//! 3. One classification through the serving surface (client API v1):
//!    `Coordinator::builder(cfg)…start()` boots the pool,
//!    `coord.infer(Infer::new(px))` returns an `InferResponse` whose
//!    `UncertaintyReport` says *why* a prediction would be deferred.
//!    Uses the PJRT artifacts when built (`make artifacts`), else the
//!    behavioral chip model (`Backend::Cim`) — no toolchain needed.

use bnn_cim::cim::{calibrate, CimTile, MvmOptions};
use bnn_cim::client::{Backend, Config, Coordinator, Infer};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::experiments::run_characterization;
use std::path::Path;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = Config::default();

    // --- 1. GRNG cell ---
    let rep = run_characterization(&cfg.chip.grng, 1000, 1, false);
    println!(
        "GRNG @ {:.0} mV: σ(T_D) = {:.2} ns, latency = {:.0} ns, \
         {:.0} fJ/Sample, Q-Q r = {:.4}",
        cfg.chip.grng.bias_v * 1e3,
        rep.quality.width_sd_s * 1e9,
        rep.quality.mean_latency_s * 1e9,
        rep.quality.mean_energy_j * 1e15,
        rep.quality.qq_r
    );

    // --- 2. CIM tile ---
    let mut tile = CimTile::new(&cfg.chip);
    let cal = calibrate(&mut tile, 16, 32)?;
    println!(
        "calibrated tile: ε₀ residual {:.3}, cost {:.2} nJ",
        cal.grng_residual_rms,
        cal.energy_j * 1e9
    );
    // w = μ + σ·ε with μ ramp and uniform σ.
    let n = cfg.chip.tile.rows * cfg.chip.tile.words_per_row;
    let mu: Vec<f64> = (0..n).map(|i| (i % 256) as f64 - 128.0).collect();
    let sigma = vec![6.0; n];
    tile.program_matrix(&mu, &sigma);
    let x = vec![8u8; cfg.chip.tile.rows];
    let y = tile.mvm(&x, MvmOptions::default());
    println!(
        "Bayesian MVM outputs (μ-path + σε-path): {:?}",
        y.combined()
            .iter()
            .map(|v| v.round())
            .collect::<Vec<_>>()
    );
    println!("tile energy so far:\n{}", tile.ledger.ascii_breakdown());

    // --- 3. Full serving path (client API v1) ---
    let backend = if Path::new("artifacts/manifest.json").exists() {
        Backend::Pjrt
    } else {
        println!("(artifacts not built: serving on the behavioral chip model)");
        Backend::Cim
    };
    let coord = Coordinator::builder(cfg.clone()).backend(backend).start()?;
    let sample = SyntheticPerson::new(cfg.model.image_side, 7).sample(1);
    let resp = coord.infer(Infer::new(sample.pixels).mc_samples(16))?;
    let u = &resp.uncertainty;
    println!(
        "served inference: true={} pred={} ({:.1} ms)\n\
         uncertainty: entropy {:.3} = aleatoric {:.3} + epistemic {:.3} \
         | threshold {:.2} → deferred={}",
        sample.label,
        resp.pred.class,
        resp.latency.as_secs_f64() * 1e3,
        u.entropy,
        u.aleatoric,
        u.epistemic,
        u.threshold,
        resp.deferred()
    );
    coord.shutdown();
    Ok(())
}
