//! `edge_client` — prove the wire format from the outside.
//!
//! A deliberately std-only HTTP client: the request bytes are written by
//! hand (no `edge::http::MiniClient`, no JSON library) so this example
//! demonstrates that any language with a TCP socket can talk to the
//! edge. It submits one synthetic image to `POST /v1/infer` and prints
//! the `UncertaintyReport` verdict fields scanned straight out of the
//! response text.
//!
//! Start a server first, then point the example at it:
//!
//! ```text
//! cargo run --release -- serve --listen 127.0.0.1:8080 --backend sim --workers 2
//! cargo run --release --example edge_client 127.0.0.1:8080
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;

/// Pull the value following `"key":` out of a flat JSON response — good
/// enough for a demo whose point is the wire bytes, not a parser.
fn scan_field<'a>(body: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\":");
    let start = body.find(&needle)? + needle.len();
    let rest = &body[start..];
    let end = rest
        .find(|c| c == ',' || c == '}')
        .unwrap_or(rest.len());
    Some(rest[..end].trim())
}

fn main() {
    let addr = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "127.0.0.1:8080".to_string());

    // A 32×32 synthetic "image": a radial gradient, just plausible enough
    // to classify. Any f32 vector of length image_side² works.
    let side = 32usize;
    let mut body = String::from("{\"pixels\":[");
    for y in 0..side {
        for x in 0..side {
            if y + x > 0 {
                body.push(',');
            }
            let dx = x as f64 - side as f64 / 2.0;
            let dy = y as f64 - side as f64 / 2.0;
            let v = (1.0 - (dx * dx + dy * dy).sqrt() / side as f64).max(0.0);
            body.push_str(&format!("{v:.4}"));
        }
    }
    body.push_str("],\"mc_samples\":16,\"defer_threshold\":0.45}");

    let mut stream = match TcpStream::connect(&addr) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "could not connect to {addr}: {e}\n\
                 start a server first:\n  \
                 cargo run --release -- serve --listen {addr} --backend sim --workers 2"
            );
            std::process::exit(1);
        }
    };

    // The whole request, by hand: request line, framing headers, body.
    let request = format!(
        "POST /v1/infer HTTP/1.1\r\n\
         Host: {addr}\r\n\
         Content-Type: application/json\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(request.as_bytes()).expect("send request");

    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read response");
    let (head, resp_body) = response
        .split_once("\r\n\r\n")
        .unwrap_or((response.as_str(), ""));
    let status_line = head.lines().next().unwrap_or("");
    println!("{status_line}");

    if !status_line.contains(" 200 ") {
        println!("{resp_body}");
        std::process::exit(1);
    }

    let deferred = scan_field(resp_body, "deferred").unwrap_or("?");
    println!(
        "class     = {}\nconfidence= {}\nentropy   = {} nats \
         (aleatoric {} + epistemic {})\nthreshold = {}\ndegraded  = {} | escalated = {}",
        scan_field(resp_body, "class").unwrap_or("?"),
        scan_field(resp_body, "confidence").unwrap_or("?"),
        scan_field(resp_body, "entropy").unwrap_or("?"),
        scan_field(resp_body, "aleatoric").unwrap_or("?"),
        scan_field(resp_body, "epistemic").unwrap_or("?"),
        scan_field(resp_body, "threshold").unwrap_or("?"),
        scan_field(resp_body, "degraded").unwrap_or("?"),
        scan_field(resp_body, "escalated").unwrap_or("?"),
    );
    println!(
        "verdict   = {}",
        if deferred == "true" {
            "DEFER — entropy above threshold, route to a human / full pass"
        } else {
            "ACCEPT — uncertainty within budget"
        }
    );
}
