//! Design-space explorer: how the paper's headline metrics move as the
//! chip parameters change — the co-design ablations DESIGN.md calls out.
//!
//!   cargo run --release --example chip_explorer
//!
//! Sweeps: GRNG bias (energy/quality trade), tile geometry (area vs
//! throughput), ADC resolution (accuracy vs energy), σ precision.

use bnn_cim::config::ChipConfig;
use bnn_cim::energy::{area_breakdown, HeadlineMetrics};
use bnn_cim::experiments::{run_breakdown, run_characterization};
use bnn_cim::grng::GrngBank;

fn headline(chip: &ChipConfig) -> HeadlineMetrics {
    let bank = GrngBank::for_chip(chip);
    let rep = run_breakdown(chip, 1);
    HeadlineMetrics::compute(
        chip,
        bank.hardware_throughput_sa_s(),
        bank.mean_energy_per_sample(),
        rep.mvm_energy_j,
    )
}

fn main() {
    // --- GRNG bias sweep: quality vs energy ---
    println!("GRNG bias design point (2, Fig. 9 trade):");
    println!("  V_R [mV] | σ(T_D) ns | latency ns | fJ/Sa | bank GSa/s | Q-Q r");
    for mv in [120.0, 150.0, 180.0, 210.0] {
        let mut chip = ChipConfig::default();
        chip.grng.bias_v = mv / 1e3;
        let rep = run_characterization(&chip.grng, 800, 3, false);
        let bank = GrngBank::for_chip(&chip);
        println!(
            "  {:>8.0} | {:>9.2} | {:>10.0} | {:>5.0} | {:>10.2} | {:.4}",
            mv,
            rep.quality.width_sd_s * 1e9,
            rep.quality.mean_latency_s * 1e9,
            rep.quality.mean_energy_j * 1e15,
            bank.hardware_throughput_sa_s() / 1e9,
            rep.quality.qq_r
        );
    }

    // --- tile geometry ---
    println!("\ntile geometry (area vs throughput):");
    println!("  rows×words | tile mm² | NN GOp/s | GOp/s/mm² | fJ/Op");
    for (rows, words) in [(32, 8), (64, 8), (64, 16), (128, 8)] {
        let mut chip = ChipConfig::default();
        chip.tile.rows = rows;
        chip.tile.words_per_row = words;
        let m = headline(&chip);
        let area = area_breakdown(&chip.tile, &chip.area);
        println!(
            "  {rows:>4}×{words:<5} | {:>8.4} | {:>8.1} | {:>9.0} | {:>5.0}",
            area.tile_mm2, m.nn_tput_gops, m.nn_tput_gops / area.tile_mm2, m.nn_eff_fj_per_op
        );
    }

    // --- ADC resolution ---
    println!("\nADC resolution (conversion energy scales ~2^b):");
    println!("  bits | MVM pJ | fJ/Op | SRAM share");
    for bits in [4, 6, 8] {
        let mut chip = ChipConfig::default();
        chip.adc.bits = bits;
        // SAR energy ≈ linear-ish in bits at fixed DNL budget (model).
        chip.adc.energy_j = 110.0e-15 * (bits as f64 / 6.0);
        let rep = run_breakdown(&chip, 2);
        println!(
            "  {bits:>4} | {:>6.1} | {:>5.0} | {:>6.1}%",
            rep.mvm_energy_j * 1e12,
            rep.fj_per_op,
            rep.sram_energy_share() * 100.0
        );
    }

    // --- headline recap ---
    let m = headline(&ChipConfig::default());
    println!(
        "\ndefault chip: {:.2} GSa/s RNG @ {:.2} pJ/Sa | {:.0} GOp/s NN @ {:.0} fJ/Op | {:.3} mm²",
        m.rng_tput_gsa_s, m.rng_eff_pj_per_sa, m.nn_tput_gops, m.nn_eff_fj_per_op, m.area_mm2
    );
    println!("paper:        5.12 GSa/s       @ 0.36 pJ/Sa  | 102 GOp/s     @ 672 fJ/Op  | 0.45 mm²");
}
