//! Ablation: serve the same workload with different ε sources — the
//! in-word GRNG bank (this work), the Philox mirror of the L1 kernel,
//! and the Tab. II baseline algorithms (Wallace, Box–Muller, TI-Hadamard,
//! CLT-LFSR). Shows task quality is RNG-robust while the *cost* differs
//! by orders of magnitude (the paper's whole point: the win is
//! energy/locality, not statistics).
//!
//!   cargo run --release --example rng_ablation [n_requests]

use bnn_cim::bayes::{accuracy, ape_by_group, EvalPoint};
use bnn_cim::client::{Backend, Config, Coordinator, Infer, SourceFactory};
use bnn_cim::coordinator::{BaselineSource, EpsilonSource, GrngBankSource, PhiloxSource};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::grng::baselines::{
    box_muller::FixedPointBoxMuller, clt_lfsr::CltLfsr, hadamard::TiHadamard, wallace::Wallace,
};
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    if !Path::new("artifacts/manifest.json").exists() {
        return Err("artifacts missing — run `make artifacts`".into());
    }
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);

    let mut cfg = Config::default();
    cfg.model.mc_samples = 12;

    // Factories receive the shard index; every arm here serves on the
    // default single shard, so only the GRNG/Philox arms use it.
    let sources: Vec<(&str, SourceFactory)> = vec![
        ("in-word GRNG (this work)", GrngBankSource::shard_factory(&cfg.chip)),
        ("philox (L1 kernel mirror)", PhiloxSource::shard_factory(42)),
        ("wallace [11]", Arc::new(|_shard: usize| {
            Box::new(BaselineSource::new(Box::new(Wallace::new(1)))) as Box<dyn EpsilonSource>
        })),
        ("box-muller [12]", Arc::new(|_shard: usize| {
            Box::new(BaselineSource::new(Box::new(FixedPointBoxMuller::new(2))))
                as Box<dyn EpsilonSource>
        })),
        ("ti-hadamard [9]", Arc::new(|_shard: usize| {
            Box::new(BaselineSource::new(Box::new(TiHadamard::new(3)))) as Box<dyn EpsilonSource>
        })),
        ("clt-lfsr (ablation)", Arc::new(|_shard: usize| {
            Box::new(BaselineSource::new(Box::new(CltLfsr::new(4)))) as Box<dyn EpsilonSource>
        })),
    ];

    println!(
        "{:<28} {:>8} {:>8} {:>10} {:>10} {:>12}",
        "ε source", "acc", "APE-inc", "APE-ood", "eps-draws", "model energy"
    );
    for (name, factory) in sources {
        let coord = Coordinator::builder(cfg.clone())
            .backend(Backend::Pjrt)
            .source_factory(factory)
            .start()?;
        let gen = SyntheticPerson::new(cfg.model.image_side, 9);
        let mut points = Vec::new();
        let mut tickets = Vec::new();
        for i in 0..n as u64 {
            let s = gen.sample(i);
            tickets.push((s.label, false, coord.submit(Infer::new(s.pixels))?));
            if i % 4 == 0 {
                let o = gen.ood_sample(i, bnn_cim::data::OodKind::Fragment);
                tickets.push((0, true, coord.submit(Infer::new(o.pixels))?));
            }
        }
        for (label, ood, ticket) in tickets {
            points.push(EvalPoint {
                pred: ticket.wait()?.pred,
                label,
                ood,
            });
        }
        let m = coord.metrics();
        let (_, ape_i, ape_o) = ape_by_group(&points);
        println!(
            "{:<28} {:>8.3} {:>8.3} {:>10.3} {:>10} {:>9.2} µJ",
            name,
            accuracy(&points),
            ape_i,
            ape_o,
            m.epsilon_samples,
            m.epsilon_energy_j * 1e6
        );
        coord.shutdown();
    }
    println!("\n(model energy = ε draws × the published/simulated per-sample cost of that source)");
    Ok(())
}
