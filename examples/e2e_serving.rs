//! End-to-end driver (the DESIGN.md "(e2e)" row): exercises every layer
//! of the stack on a real small workload and reports the paper's
//! headline quantities.
//!
//!   cargo run --release --example e2e_serving -- \
//!       [n_requests] [mc_samples] [workers] [--backend sim|cim|pjrt]
//!
//! (`--sim` is kept as a deprecated alias for `--backend sim`.)
//!
//! Pipeline proven here:
//!   python (build time): synthetic-person training → ELBO Bayesian head
//!     → quantization → Pallas-kernel inference graph → HLO text
//!   rust (request path, client API v1: builder → submit_many → Tickets):
//!     coordinator batches requests → the backend
//!     executes the feature extractor once per batch → T Monte-Carlo head
//!     passes. On `pjrt`/`sim` each pass is fed fresh ε from the
//!     *simulated in-word GRNG bank* (die mismatch + calibration
//!     included); on `cim` the head runs through the behavioral tile
//!     arrays whose in-word banks generate ε during the MVM and whose
//!     ledgers meter energy → entropy/deferral policy.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use bnn_cim::bayes::{accuracy, ape_by_group, ece_percent, EvalPoint};
use bnn_cim::client::{Backend, Config, Coordinator, Infer};
use bnn_cim::data::{OodKind, SyntheticPerson};
use bnn_cim::grng::GrngBank;
use bnn_cim::util::cli::parse_args;
use std::path::Path;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Same parser as the `bnn-cim` CLI: `--backend value`, `--backend=value`,
    // bare `--sim` flag, positionals.
    let args = parse_args(std::env::args().skip(1));
    // `--backend` always wins over the deprecated alias (as in `serve`).
    let backend: Option<Backend> = match args.get("backend") {
        Some(b) => Some(Backend::parse(b)?),
        None if args.has_flag("sim") => {
            eprintln!("warning: --sim is deprecated; use --backend sim");
            Some(Backend::Sim)
        }
        None => None,
    };
    let pos = |i: usize| args.positional.get(i).and_then(|s| s.parse().ok());
    let n_requests: usize = pos(0).unwrap_or(200);
    let mc: usize = pos(1).unwrap_or(16);
    let workers: usize = pos(2).unwrap_or(1);

    let mut cfg = Config::default();
    cfg.model.mc_samples = mc;
    cfg.server.max_batch = 8;
    cfg.server.workers = workers;
    if let Some(b) = backend {
        cfg.server.backend = b;
    }
    if cfg.server.backend == Backend::Pjrt && !Path::new("artifacts/manifest.json").exists() {
        return Err(
            "artifacts missing — run `make artifacts`, or pass --backend sim|cim".into(),
        );
    }
    let coord = Coordinator::builder(cfg.clone()).start()?;
    let gen = SyntheticPerson::new(cfg.model.image_side, 2024);

    println!(
        "=== e2e serving: {n_requests} requests (+25% OOD), T={mc} MC samples, \
         {workers} shard worker(s), backend = {} ===",
        cfg.server.backend.name()
    );
    let t0 = Instant::now();

    // Offer the whole workload asynchronously: `submit_many` enqueues
    // back to back, so the coordinator fuses batches exactly as a burst
    // of individual `submit` calls would.
    let mut expected = Vec::new();
    let mut workload = Vec::new();
    let kinds = [
        OodKind::Fragment,
        OodKind::Texture,
        OodKind::Inverted,
        OodKind::Noise,
    ];
    for i in 0..n_requests as u64 {
        let s = gen.sample(i);
        expected.push((s.label, false));
        workload.push(Infer::new(s.pixels));
        if i % 4 == 0 {
            let o = gen.ood_sample(i, kinds[(i / 4 % 4) as usize]);
            expected.push((0, true));
            workload.push(Infer::new(o.pixels));
        }
    }
    let tickets = coord.submit_many(workload)?;
    let mut points = Vec::new();
    let mut deferred = 0usize;
    for (ticket, &(label, ood)) in tickets.into_iter().zip(expected.iter()) {
        let resp = ticket.wait()?;
        if resp.deferred() {
            deferred += 1;
        }
        points.push(EvalPoint {
            pred: resp.pred,
            label,
            ood,
        });
    }
    let wall = t0.elapsed();

    // --- quality ---
    let acc = accuracy(&points);
    let ece = ece_percent(&points, 15);
    let (ape_c, ape_i, ape_o) = ape_by_group(&points);
    println!(
        "\nquality (BNN over {} + in-word-GRNG ε):",
        cfg.server.backend.name()
    );
    println!("  accuracy (ID)        {:.3}", acc);
    println!("  ECE                  {:.2} %", ece);
    println!("  APE correct/incorrect/OOD   {ape_c:.3} / {ape_i:.3} / {ape_o:.3}");
    println!(
        "  deferred             {} / {} ({:.1} %)",
        deferred,
        points.len(),
        100.0 * deferred as f64 / points.len() as f64
    );

    // --- serving performance ---
    let m = coord.metrics();
    println!("\nserving:");
    println!("  wallclock            {wall:.2?}");
    println!(
        "  throughput           {:.1} inferences/s (each = {} MC passes)",
        points.len() as f64 / wall.as_secs_f64(),
        mc
    );
    println!("  latency p50/p95      {:.1} / {:.1} ms", m.latency_p50_ms, m.latency_p95_ms);
    println!("  batches              {} (mean fill {:.2})", m.batches, m.mean_batch_fill);
    println!("  PJRT executions      {}", m.pjrt_executions);
    if m.per_shard.len() > 1 {
        for s in &m.per_shard {
            println!(
                "  shard {}              {} requests, {} batches, {} exec, {} ε",
                s.shard, s.requests, s.batches, s.engine_executions, s.epsilon_samples
            );
        }
    }

    // --- hardware-model energy of the ε stream ---
    let bank = GrngBank::for_chip(&cfg.chip);
    println!("\nhardware model (the chip this simulates):");
    println!(
        "  ε samples drawn      {} ({:.2} µJ at {:.0} fJ/Sample)",
        m.epsilon_samples,
        m.epsilon_energy_j * 1e6,
        bank.mean_energy_per_sample() * 1e15
    );
    println!(
        "  GRNG bank rate       {:.2} GSa/s (paper 5.12)",
        bank.hardware_throughput_sa_s() / 1e9
    );
    if m.engine_energy_j > 0.0 {
        println!(
            "  tile energy          {:.3} µJ over {} tile MVMs ({:.0} fJ/Op, paper 672)",
            m.engine_energy_j * 1e6,
            m.engine_mvms,
            m.engine_j_per_op() * 1e15,
        );
    }
    coord.shutdown();
    Ok(())
}
