"""Build-time training: backbone + deterministic head (cross-entropy),
then the Bayesian head by maximizing the ELBO (§II-A) with the backbone
frozen. Exports:

  artifacts/weights.json     — consumed by rust `nn::Model::load`
  artifacts/eval_batch.json  — shared eval split (images/labels/OOD) so
                               Rust experiments can evaluate the *same*
                               inputs the training-side metrics used
  artifacts/train_metrics.json

Run:  cd python && python -m compile.train [--steps N] [--out DIR]
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .dataset import SyntheticPerson

SEED = 1234


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, {"m": m, "v": v, "t": t}


def train(steps_backbone=400, steps_head=800, batch=64, out_dir="../artifacts",
          n_train=2048, n_val=512, seed=SEED, verbose=True):
    t0 = time.time()
    gen = SyntheticPerson(32, seed)
    x_train, y_train = gen.split(0, n_train)
    x_val, y_val = gen.split(n_train, n_val)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key)

    # ---- Phase 1: backbone + det head ----
    det_subset = {"features": params["features"], "det_head": params["det_head"]}

    @jax.jit
    def det_step(subset, opt, images, labels):
        def loss_fn(s):
            feats = M.features_fwd(s, images)
            return M.cross_entropy(M.det_head_fwd(s, feats), labels)

        loss, grads = jax.value_and_grad(loss_fn)(subset)
        subset, opt = adam_step(subset, grads, opt, lr=2e-3)
        return subset, opt, loss

    opt = adam_init(det_subset)
    rng = np.random.default_rng(seed)
    for step in range(steps_backbone):
        idx = rng.integers(0, n_train, batch)
        det_subset, opt, loss = det_step(
            det_subset, opt, jnp.asarray(x_train[idx]), jnp.asarray(y_train[idx])
        )
        if verbose and step % 100 == 0:
            print(f"[backbone] step {step} loss {float(loss):.4f}", flush=True)
    params["features"] = det_subset["features"]
    params["det_head"] = det_subset["det_head"]

    # ---- Phase 2: Bayesian head on frozen features (ELBO) ----
    feats_train = np.asarray(
        jax.jit(M.features_fwd)(params, jnp.asarray(x_train))
    )
    feats_val = np.asarray(jax.jit(M.features_fwd)(params, jnp.asarray(x_val)))
    # Initialize μ from the trained deterministic head (warm start).
    for i, det in enumerate(params["det_head"]):
        params["head"][i]["mu"] = det["w"]
        params["head"][i]["b"] = det["b"]
    head = {"head": params["head"]}
    kl_weight = 0.5 / n_train

    @jax.jit
    def head_step(head, opt, feats, labels, key):
        def loss_fn(h):
            return M.elbo_loss(h, feats, labels, key, kl_weight)

        loss, grads = jax.value_and_grad(loss_fn)(head)
        head, opt = adam_step(head, grads, opt, lr=1e-3)
        return head, opt, loss

    opt = adam_init(head)
    for step in range(steps_head):
        idx = rng.integers(0, n_train, batch)
        key, sub = jax.random.split(key)
        head, opt, loss = head_step(
            head, opt, jnp.asarray(feats_train[idx]), jnp.asarray(y_train[idx]), sub
        )
        if verbose and step % 100 == 0:
            print(f"[bayes-head] step {step} elbo-loss {float(loss):.4f}", flush=True)
    params["head"] = head["head"]

    # ---- Metrics ----
    val_logits_det = M.det_head_fwd(params, jnp.asarray(feats_val))
    det_acc = float(M.accuracy(val_logits_det, jnp.asarray(y_val)))
    # Bayesian val accuracy (mean of 8 MC passes, float path).
    probs = 0.0
    for t in range(8):
        key, sub = jax.random.split(key)
        logits = M.head_fwd_train({"head": params["head"]}, jnp.asarray(feats_val), sub)
        probs = probs + jax.nn.softmax(logits, axis=1)
    bayes_acc = float(
        jnp.mean((jnp.argmax(probs, axis=1) == jnp.asarray(y_val)).astype(jnp.float32))
    )
    if verbose:
        print(f"val acc: det {det_acc:.3f} | bayes(float, T=8) {bayes_acc:.3f}")

    # ---- Export ----
    # Calibrate the activation quantizer range from the actual feature
    # distribution (ReLU6's bound of 6.0 wastes most of the 4-bit grid:
    # real features live below ~1).
    act_max = float(np.percentile(feats_train, 99.5))
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    export_weights(params, out / "weights.json", act_max=act_max)
    export_eval_batch(gen, n_train + n_val, out / "eval_batch.json")
    (out / "train_metrics.json").write_text(
        json.dumps(
            {
                "det_val_acc": det_acc,
                "bayes_val_acc_float_T8": bayes_acc,
                "steps_backbone": steps_backbone,
                "steps_head": steps_head,
                "n_train": n_train,
                "seed": seed,
                "wall_s": time.time() - t0,
            },
            indent=2,
        )
    )
    return params, det_acc, bayes_acc


def export_weights(params, path: Path, act_max=M.ACT_MAX):
    doc = {
        "meta": {
            "side": 32,
            "classes": 2,
            "feature_dim": M.FEATURE_DIM,
            "act_max": round(float(act_max), 5),
        },
        "features": [],
        "head": {"layers": []},
        "det_head": {"layers": []},
    }
    for (kind, _cin, _cout, stride), layer in zip(M.ARCH, params["features"]):
        w = np.asarray(layer["w"], dtype=np.float64)
        doc["features"].append(
            {
                "kind": "dw" if kind == "dw" else "conv",
                "stride": stride,
                "w_shape": list(w.shape),
                "w": [round(float(v), 7) for v in w.reshape(-1)],
                "b": [round(float(v), 7) for v in np.asarray(layer["b"]).reshape(-1)],
            }
        )
    doc["features"].append({"kind": "gap"})
    for (in_d, out_d), layer in zip(M.HEAD_DIMS, params["head"]):
        sigma = np.asarray(M.sigma_from_rho(layer["rho"]), dtype=np.float64)
        doc["head"]["layers"].append(
            {
                "in": in_d,
                "out": out_d,
                "relu": (in_d, out_d) != M.HEAD_DIMS[-1],
                "mu": [round(float(v), 7) for v in np.asarray(layer["mu"]).reshape(-1)],
                "sigma": [round(float(v), 7) for v in sigma.reshape(-1)],
                "bias": [round(float(v), 7) for v in np.asarray(layer["b"]).reshape(-1)],
            }
        )
    for (in_d, out_d), layer in zip(M.HEAD_DIMS, params["det_head"]):
        doc["det_head"]["layers"].append(
            {
                "in": in_d,
                "out": out_d,
                "relu": (in_d, out_d) != M.HEAD_DIMS[-1],
                "w": [round(float(v), 7) for v in np.asarray(layer["w"]).reshape(-1)],
                "bias": [round(float(v), 7) for v in np.asarray(layer["b"]).reshape(-1)],
            }
        )
    path.write_text(json.dumps(doc))
    print(f"wrote {path} ({path.stat().st_size/1e6:.2f} MB)")


def export_eval_batch(gen: SyntheticPerson, offset: int, path: Path,
                      n_id=256, n_ood=96):
    imgs, labels = gen.split(offset, n_id)
    ood = gen.ood_split(offset, n_ood)
    doc = {
        "side": gen.side,
        "id_images": [[round(float(v), 5) for v in img.reshape(-1)] for img in imgs],
        "id_labels": [int(v) for v in labels],
        "ood_images": [[round(float(v), 5) for v in img.reshape(-1)] for img in ood],
    }
    path.write_text(json.dumps(doc))
    print(f"wrote {path} ({path.stat().st_size/1e6:.2f} MB)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--head-steps", type=int, default=400)
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--seed", type=int, default=SEED)
    args = ap.parse_args()
    train(
        steps_backbone=args.steps,
        steps_head=args.head_steps,
        out_dir=args.out,
        seed=args.seed,
    )


if __name__ == "__main__":
    main()
