"""L2: JAX partial-Bayesian MobileNet-mini (§III-A).

Architecture (32×32×1 input):
    conv3x3(1→8, s2) → dw3x3(8) → pw(8→16, s2) → dw(16) → pw(16→32, s2)
    → dw(32) → pw(32→64) → GAP → 64-d feature, then a Bayesian FC head
    64→32→2 using the weight decomposition w = μ + σ·ε (Eq. 4).

Three forward paths:
  - ``features_fwd``   — deterministic backbone (HWC, SAME pad, ReLU6)
                         — matches `rust/src/nn/layers.rs`.
  - ``head_fwd_train`` — ELBO training path: local reparameterization.
  - ``head_fwd_sample``— inference path taking explicit ε inputs and
                         calling the L1 Pallas kernel with the hardware
                         quantization grids; `aot.py` lowers this for
                         the Rust runtime.

Python is build-time only: nothing here runs at serving time.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bayes_mvm as K

ARCH = [
    # (kind, cin, cout, stride)
    ("conv", 1, 8, 2),
    ("dw", 8, 8, 1),
    ("conv1", 8, 16, 2),
    ("dw", 16, 16, 1),
    ("conv1", 16, 32, 2),
    ("dw", 32, 32, 1),
    ("conv1", 32, 64, 1),
]
FEATURE_DIM = 64
HEAD_DIMS = [(64, 32), (32, 2)]
ACT_MAX = 6.0


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_params(key):
    """Backbone + deterministic head + Bayesian head parameters."""
    params = {"features": [], "det_head": [], "head": []}
    for kind, cin, cout, _s in ARCH:
        key, k1 = jax.random.split(key)
        if kind == "conv":
            shape = (3, 3, cin, cout)
        elif kind == "conv1":
            shape = (1, 1, cin, cout)
        else:  # dw
            shape = (3, 3, cin)
        fan_in = int(np.prod(shape[:-1])) if kind != "dw" else 9
        w = jax.random.normal(k1, shape) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros(shape[-1] if kind != "dw" else cin)
        params["features"].append({"w": w, "b": b})
    for in_d, out_d in HEAD_DIMS:
        key, k1, k2 = jax.random.split(key, 3)
        w = jax.random.normal(k1, (in_d, out_d)) * jnp.sqrt(2.0 / in_d)
        params["det_head"].append({"w": w, "b": jnp.zeros(out_d)})
        mu = jax.random.normal(k2, (in_d, out_d)) * jnp.sqrt(2.0 / in_d)
        # softplus(−2.0) ≈ 0.127: weight directions the data never
        # constrains keep prior-scale uncertainty (OOD entropy, Fig. 10)
        # while constrained directions shrink during ELBO training.
        rho = jnp.full((in_d, out_d), -2.0)
        params["head"].append({"mu": mu, "rho": rho, "b": jnp.zeros(out_d)})
    return params


def sigma_from_rho(rho):
    """σ = softplus(ρ) — keeps σ positive during training."""
    return jax.nn.softplus(rho)


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------


def _conv(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _dwconv(x, w, b, stride):
    c = x.shape[-1]
    wd = w[..., None]  # HWC -> HWC1
    wd = jnp.transpose(wd, (0, 1, 3, 2))  # HW1C (HWIO with I=1, O=C)
    y = jax.lax.conv_general_dilated(
        x,
        wd,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return y + b


def features_fwd(params, images):
    """images [B, S, S, 1] → features [B, 64]."""
    x = images
    for (kind, _cin, _cout, stride), layer in zip(ARCH, params["features"]):
        if kind == "dw":
            x = _dwconv(x, layer["w"], layer["b"], stride)
        else:
            x = _conv(x, layer["w"], layer["b"], stride)
        x = jnp.clip(x, 0.0, ACT_MAX)  # ReLU6
    return jnp.mean(x, axis=(1, 2))  # GAP


# ---------------------------------------------------------------------------
# Heads
# ---------------------------------------------------------------------------


def det_head_fwd(params, feats):
    x = feats
    for i, layer in enumerate(params["det_head"]):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params["det_head"]):
            x = jax.nn.relu(x)
    return x


def head_fwd_train(params, feats, key):
    """ELBO path: local reparameterization (Kingma et al. 2015) — sample
    the *pre-activations* a ~ N(x·μ, (x²)·σ²) instead of the weights."""
    x = feats
    for i, layer in enumerate(params["head"]):
        sigma = sigma_from_rho(layer["rho"])
        mean = x @ layer["mu"] + layer["b"]
        var = (x * x) @ (sigma * sigma)
        key, sub = jax.random.split(key)
        a = mean + jnp.sqrt(var + 1e-12) * jax.random.normal(sub, mean.shape)
        x = jax.nn.relu(a) if i + 1 < len(params["head"]) else a
    return x


def kl_to_prior(params, prior_sigma: float = 0.3):
    """KL(q‖p) for factorized Gaussians vs N(0, prior_sigma²).

    A loose prior (0.3) avoids over-shrinking μ margins — tight priors
    make the BNN systematically underconfident (high ECE), the opposite
    of the calibration the paper demonstrates.
    """
    kl = 0.0
    for layer in params["head"]:
        sigma = sigma_from_rho(layer["rho"])
        mu = layer["mu"]
        kl += jnp.sum(
            jnp.log(prior_sigma / sigma)
            + (sigma**2 + mu**2) / (2 * prior_sigma**2)
            - 0.5
        )
    return kl


# ---------------------------------------------------------------------------
# Hardware-faithful inference path (what aot.py lowers)
# ---------------------------------------------------------------------------


def quantize_head_weights(head_params, mu_bits=8, sigma_bits=4):
    """Fold float (μ, σ) onto the hardware grids with per-layer scales.

    Mirrors `rust/src/cim/word.rs::WeightScale`: μ fills the 8-bit
    signed-digit grid, σ the 4-bit magnitude grid, each with its own
    scale. The σ-path scale ratio is folded into σ_fixed
    (`sigma_eff = σ_fixed·mu_scale/sigma_scale`) so one kernel call
    returns both paths in μ units.
    """
    out = []
    for layer in head_params:
        mu = np.asarray(layer["mu"], dtype=np.float64)
        sigma = np.asarray(sigma_from_rho(layer["rho"]), dtype=np.float64)
        mu_grid = float(2**mu_bits - 1)
        sg_grid = float(2**sigma_bits - 1)
        mu_scale = mu_grid / max(float(np.abs(mu).max()), 1e-12)
        sigma_scale = sg_grid / max(float(sigma.max()), 1e-12)
        mu_fixed = np.asarray(
            K.quantize_mu(jnp.asarray(mu * mu_scale), mu_bits), dtype=np.float32
        )
        sigma_fixed = np.asarray(
            K.quantize_sigma(jnp.asarray(sigma * sigma_scale), sigma_bits),
            dtype=np.float32,
        )
        sigma_eff = sigma_fixed * np.float32(mu_scale / sigma_scale)
        out.append(
            {
                "mu_fixed": mu_fixed,
                "sigma_fixed": sigma_fixed,
                "sigma_eff": sigma_eff,
                "bias": np.asarray(layer["b"], dtype=np.float32),
                "mu_scale": mu_scale,
                "sigma_scale": sigma_scale,
            }
        )
    return out


def head_fwd_sample(qhead, feats, eps_list, act_max=ACT_MAX, input_bits=4):
    """One MC forward pass with explicit ε inputs via the Pallas kernel.

    Args:
      qhead: output of `quantize_head_weights` (baked constants in AOT).
      feats: [B, in_dim] float features.
      eps_list: per-layer ε, each [B, in_dim, out_dim] ~ N(0,1).
    Returns logits [B, classes].
    """
    x = feats
    for i, (layer, eps) in enumerate(zip(qhead, eps_list)):
        step = act_max / float(2**input_bits - 1)
        codes = K.quantize_act(x, step, input_bits)
        y = K.bayes_mvm_batch(
            codes,
            jnp.asarray(layer["mu_fixed"]),
            jnp.asarray(layer["sigma_eff"]),
            eps,
        )
        x = jnp.asarray(layer["bias"]) + y * (step / layer["mu_scale"])
        if i + 1 < len(qhead):
            x = jax.nn.relu(x)
    return x


def head_fwd_mean(qhead, feats, act_max=ACT_MAX, input_bits=4):
    """μ-only quantized forward pass (ablation / deterministic arm)."""
    eps_list = [
        jnp.zeros((feats.shape[0],) + l["mu_fixed"].shape, jnp.float32)
        for l in qhead
    ]
    return head_fwd_sample(qhead, feats, eps_list, act_max, input_bits)


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


def det_loss(params, images, labels):
    feats = features_fwd(params, images)
    logits = det_head_fwd(params, feats)
    return cross_entropy(logits, labels)


def elbo_loss(params, feats, labels, key, kl_weight):
    logits = head_fwd_train(params, feats, key)
    nll = cross_entropy(logits, labels)
    return nll + kl_weight * kl_to_prior(params)
