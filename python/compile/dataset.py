"""Synthetic person-detection dataset (INRIA substitute) — Python side.

Same *procedure and parameters* as `rust/src/data/generator.rs` (person =
head + torso + legs at random position/scale/contrast over rect clutter;
distractors = poles/blobs; OOD = textures/inverted/noise). The two
implementations draw from the same distribution; they need not be
bit-identical (all experiments use fresh draws — see DESIGN.md).
"""

import numpy as np

BACKGROUND = 0
PERSON = 1


class SyntheticPerson:
    def __init__(self, side: int = 32, seed: int = 0):
        assert side >= 16
        self.side = side
        self.seed = seed

    def _rng(self, index: int, salt: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=(self.seed ^ (index * 0x9E3779B97F4A7C15 + salt)) % 2**64)
        )

    # ------------------------------------------------------------------

    def sample(self, index: int):
        label = index % 2
        rng = self._rng(index, 0x1D)
        img = self._clutter(rng)
        if label == PERSON:
            self._draw_person(img, rng)
        elif rng.random() < 0.5:
            self._draw_distractor(img, rng)
        img = np.clip(img + 0.03 * rng.standard_normal(img.shape), 0.0, 1.0)
        return img.astype(np.float32), label

    def ood_sample(self, index: int, kind: str):
        rng = self._rng(index + 2**62, 0x0D)
        if kind == "texture":
            img = self._texture(rng)
        elif kind == "fragment":
            # Partially visible pedestrian: clutter + 1 body part only —
            # the genuinely ambiguous OOD of the safety-critical story.
            img = self._clutter(rng)
            self._draw_fragment(img, rng)
            img = np.clip(img + 0.03 * rng.standard_normal(img.shape), 0.0, 1.0)
        elif kind == "inverted":
            base, _ = self.sample(index)
            img = 1.0 - base
        elif kind == "noise":
            img = np.clip(0.5 + 0.15 * rng.standard_normal((self.side, self.side)), 0.0, 1.0)
        else:
            raise ValueError(f"unknown OOD kind {kind}")
        return img.astype(np.float32)

    def split(self, offset: int, n: int):
        imgs = np.zeros((n, self.side, self.side, 1), dtype=np.float32)
        labels = np.zeros(n, dtype=np.int32)
        for i in range(n):
            img, lab = self.sample(offset + i)
            imgs[i, :, :, 0] = img
            labels[i] = lab
        return imgs, labels

    def ood_split(self, offset: int, n: int):
        kinds = ["fragment", "texture", "inverted", "noise"]
        imgs = np.zeros((n, self.side, self.side, 1), dtype=np.float32)
        for i in range(n):
            imgs[i, :, :, 0] = self.ood_sample(offset + i, kinds[i % len(kinds)])
        return imgs

    # ------------------------------------------------------------------

    def _clutter(self, rng):
        s = self.side
        gx = (rng.random() - 0.5) * 0.4
        gy = (rng.random() - 0.5) * 0.4
        base = 0.35 + 0.3 * rng.random()
        xs = np.linspace(0, 1, s, endpoint=False) - 0.5
        img = base + gx * xs[None, :] + gy * xs[:, None]
        for _ in range(2 + rng.integers(0, 4)):
            w = 2 + rng.integers(0, s // 3)
            h = 2 + rng.integers(0, s // 3)
            x0 = rng.integers(0, s - w)
            y0 = rng.integers(0, s - h)
            v = 0.2 + 0.6 * rng.random()
            alpha = 0.3 + 0.5 * rng.random()
            img[y0 : y0 + h, x0 : x0 + w] = (
                img[y0 : y0 + h, x0 : x0 + w] * (1 - alpha) + v * alpha
            )
        return img

    def _paint(self, img, x0, y0, x1, y1, v):
        s = self.side
        xa, xb = int(x0 * s), int(x1 * s)
        ya, yb = int(y0 * s), int(y1 * s)
        xa, xb = max(xa, 0), min(xb, s)
        ya, yb = max(ya, 0), min(yb, s)
        if xb > xa and yb > ya:
            img[ya:yb, xa:xb] = np.clip(img[ya:yb, xa:xb] + v, 0.0, 1.0)

    def _draw_person(self, img, rng):
        height = 0.5 + 0.3 * rng.random()
        cx = 0.25 + 0.5 * rng.random()
        top = 0.05 + (0.9 - height) * rng.random()
        contrast = 1.0 if rng.random() < 0.5 else -1.0
        tone = 0.35 * (0.6 + 0.4 * rng.random()) * contrast
        head_r = height * 0.11
        torso_w = height * 0.16
        torso_h = height * 0.42
        leg_w = torso_w * 0.38
        leg_h = height * 0.38
        lean = (rng.random() - 0.5) * 0.06
        self._paint(img, cx - head_r, top, cx + head_r, top + 2 * head_r, tone * 1.1)
        torso_top = top + 2 * head_r + 0.01
        self._paint(
            img, cx - torso_w / 2, torso_top, cx + torso_w / 2, torso_top + torso_h, tone
        )
        leg_top = torso_top + torso_h
        self._paint(
            img,
            cx - torso_w / 2 + lean,
            leg_top,
            cx - torso_w / 2 + leg_w + lean,
            leg_top + leg_h,
            tone * 0.95,
        )
        self._paint(
            img,
            cx + torso_w / 2 - leg_w - lean,
            leg_top,
            cx + torso_w / 2 - lean,
            leg_top + leg_h,
            tone * 0.95,
        )

    def _draw_fragment(self, img, rng):
        """One body part of the person figure (head / torso / legs)."""
        height = 0.5 + 0.3 * rng.random()
        cx = 0.25 + 0.5 * rng.random()
        top = 0.05 + (0.9 - height) * rng.random()
        contrast = 1.0 if rng.random() < 0.5 else -1.0
        tone = 0.35 * (0.6 + 0.4 * rng.random()) * contrast
        head_r = height * 0.11
        torso_w = height * 0.16
        torso_h = height * 0.42
        part = rng.integers(0, 3)
        if part == 0:  # head only
            self._paint(img, cx - head_r, top, cx + head_r, top + 2 * head_r, tone * 1.1)
        elif part == 1:  # torso only
            self._paint(img, cx - torso_w / 2, top, cx + torso_w / 2, top + torso_h, tone)
        else:  # legs only
            leg_w = torso_w * 0.38
            leg_h = height * 0.38
            self._paint(img, cx - torso_w / 2, top, cx - torso_w / 2 + leg_w, top + leg_h, tone * 0.95)
            self._paint(img, cx + torso_w / 2 - leg_w, top, cx + torso_w / 2, top + leg_h, tone * 0.95)

    def _draw_distractor(self, img, rng):
        s = self.side
        tone = (0.3 + 0.4 * rng.random()) * (1.0 if rng.random() < 0.5 else -1.0)
        if rng.random() < 0.5:
            w = 1 + rng.integers(0, 2)
            h = s // 2 + rng.integers(0, s // 3)
            x0 = rng.integers(0, s - w)
            y0 = rng.integers(0, max(s - h, 1))
            img[y0 : min(y0 + h, s), x0 : x0 + w] = np.clip(
                img[y0 : min(y0 + h, s), x0 : x0 + w] + tone, 0.0, 1.0
            )
        else:
            w = s // 4 + rng.integers(0, s // 4)
            x0 = rng.integers(0, s - w)
            y0 = rng.integers(0, s - w)
            img[y0 : y0 + w, x0 : x0 + w] = np.clip(
                img[y0 : y0 + w, x0 : x0 + w] + tone * 0.8, 0.0, 1.0
            )

    def _texture(self, rng):
        # Statistics-matched texture: OOD structure at in-distribution
        # brightness/contrast (see rust generator for rationale).
        s = self.side
        period = 2 + rng.integers(0, 5)
        checker = rng.random() < 0.5
        mid = 0.4 + 0.2 * rng.random()
        amp = 0.08 + 0.1 * rng.random()
        x = np.arange(s) // period
        if checker:
            grid = (x[None, :] + x[:, None]) % 2
        else:
            grid = np.broadcast_to(x[None, :] % 2, (s, s))
        img = np.where(grid == 0, mid - amp, mid + amp).astype(np.float64)
        return np.clip(img + 0.03 * rng.standard_normal((s, s)), 0.0, 1.0)
