"""L1 Pallas kernel: counter-based in-kernel Gaussian RNG.

Hardware-adaptation of the paper's in-word GRNG (DESIGN.md
§Hardware-Adaptation): on the chip, ε is generated physically inside the
SRAM word that stores σ, so samples never cross a memory bus. The TPU
translation of that locality is *in-kernel generation*: ε is derived from
a (key, counter) pair inside the same Pallas kernel invocation that
consumes it, living only in VMEM — it never materializes in HBM.

The bit source is Philox4x32-10 (Salmon et al., SC'11), the canonical
counter-based generator; the Rust coordinator implements the identical
function (`bnn_cim::util::rng::Philox4x32`), so L3 can reproduce the
exact ε-stream an artifact will see (cross-language test vectors in
python/tests/test_kernels.py and rust/src/util/rng.rs).

Pallas kernels here always run with ``interpret=True``: the CPU PJRT
client cannot execute Mosaic custom-calls, and interpret mode lowers to
plain HLO ops that any backend runs (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain ints (converted at trace time inside the kernel): module-level
# jnp arrays would be captured as pallas_call constants, which is an error.
PHILOX_M0 = 0xD2511F53
PHILOX_M1 = 0xCD9E8D57
PHILOX_W0 = 0x9E3779B9
PHILOX_W1 = 0xBB67AE85

TWO_PI = 6.283185307179586


def _mulhilo(a, b):
    """32x32 -> (hi, lo) using 16-bit limbs (jax_enable_x64 is off, so
    uint64 is unavailable; uint32 multiplies wrap, which gives `lo` for
    free and the limb decomposition recovers `hi`)."""
    mask = jnp.uint32(0xFFFF)
    sixteen = jnp.uint32(16)
    al = a & mask
    ah = a >> sixteen
    bl = b & mask
    bh = b >> sixteen
    lo = a * b  # wrapping multiply = low 32 bits
    t = al * bl
    k = t >> sixteen
    t = ah * bl + k
    w2 = t & mask
    w1 = t >> sixteen
    t = al * bh + w2
    k = t >> sixteen
    hi = ah * bh + w1 + k
    return hi, lo


def philox_4x32(key0, key1, c0, c1, c2, c3, rounds=10):
    """Philox4x32 block function on uint32 arrays (vectorized)."""
    k0, k1 = key0, key1
    m0 = jnp.uint32(PHILOX_M0)
    m1 = jnp.uint32(PHILOX_M1)
    w0 = jnp.uint32(PHILOX_W0)
    w1 = jnp.uint32(PHILOX_W1)
    for _ in range(rounds):
        hi0, lo0 = _mulhilo(m0, c0)
        hi1, lo1 = _mulhilo(m1, c2)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
        k0 = k0 + w0
        k1 = k1 + w1
    return c0, c1, c2, c3


def _bits_to_unit_open(bits):
    """uint32 -> float32 in (0, 1]: (bits >> 8 + 1) / 2^24."""
    return (
        (bits >> jnp.uint32(8)).astype(jnp.float32) + jnp.float32(1.0)
    ) * jnp.float32(1.0 / 16777216.0)


def _bits_to_unit(bits):
    """uint32 -> float32 in [0, 1)."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / 16777216.0
    )


def _grng_kernel(key_ref, out_ref, *, block_rows: int, cols: int):
    """Pallas kernel body: fill one [block_rows, cols] tile of ε.

    Counters are derived from the global element index so every tile and
    every grid step draws from a disjoint counter range (random access —
    the property the chip gets from having one physical GRNG per word).
    """
    # program_id is int32 — cast BEFORE mixing with uint32 counters, or
    # the whole index computation silently promotes to int32 and the
    # Philox shifts turn arithmetic (sign-extending) on high-bit lanes.
    tile = pl.program_id(0).astype(jnp.uint32)
    key0 = key_ref[0]
    key1 = key_ref[1]
    # Global element index of each slot in this tile.
    base = tile * jnp.uint32(block_rows * cols)
    idx = base + jax.lax.broadcasted_iota(jnp.uint32, (block_rows, cols), 0) * jnp.uint32(cols)
    idx = idx + jax.lax.broadcasted_iota(jnp.uint32, (block_rows, cols), 1)
    zero = jnp.zeros_like(idx)
    r0, r1, _r2, _r3 = philox_4x32(key0, key1, idx, zero, zero, zero)
    # Box–Muller on two independent 24-bit uniforms.
    u1 = _bits_to_unit_open(r0)
    u2 = _bits_to_unit(r1)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    eps = r * jnp.cos(jnp.float32(TWO_PI) * u2)
    out_ref[...] = eps


@functools.partial(jax.jit, static_argnames=("rows", "cols", "block_rows"))
def sample_epsilon(key, rows: int, cols: int, block_rows: int = 0):
    """Generate an ε matrix [rows, cols] ~ N(0,1) from a uint32[2] key.

    ``block_rows`` controls the VMEM tile height (0 = whole array in one
    tile). On real TPU hardware the BlockSpec keeps each ε tile resident
    in VMEM next to the σ tile that consumes it — the "in-word" locality.
    """
    if block_rows <= 0 or block_rows > rows:
        block_rows = rows
    assert rows % block_rows == 0, "rows must divide into blocks"
    grid = rows // block_rows
    return pl.pallas_call(
        functools.partial(_grng_kernel, block_rows=block_rows, cols=cols),
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(jnp.asarray(key, dtype=jnp.uint32))


def philox_bits(key, n: int):
    """First output word of Philox4x32-10 for counters 0..n-1 (testing)."""
    key = jnp.asarray(key, dtype=jnp.uint32)
    idx = jnp.arange(n, dtype=jnp.uint32)
    zero = jnp.zeros_like(idx)
    r0, r1, r2, r3 = philox_4x32(key[0], key[1], idx, zero, zero, zero)
    return jnp.stack([r0, r1, r2, r3], axis=1)
