"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: pytest asserts
`kernel(...) ≈ ref(...)` over hypothesis-generated shapes/values
(python/tests/test_kernels.py).
"""

import jax.numpy as jnp
import numpy as np


def quantize_mu_ref(mu, bits: int = 8):
    grid_max = float(2**bits - 1)
    x = jnp.clip(mu, -grid_max, grid_max)
    return 2.0 * jnp.round((x - 1.0) / 2.0) + 1.0


def quantize_sigma_ref(sigma, bits: int = 4):
    grid_max = float(2**bits - 1)
    return jnp.clip(jnp.round(sigma), 0.0, grid_max)


def adc_quantize_ref(v, lsb, bits: int = 6):
    half = float(2 ** (bits - 1))
    code = jnp.clip(jnp.round(v / lsb), -half, half - 1.0)
    return code * lsb


def bayes_mvm_ref(
    x_codes,
    mu_fixed,
    sigma_fixed,
    eps,
    adc_bits: int = 6,
    adc_lsb_mu: float = 7.5,
    adc_lsb_sigma: float = 7.5,
    use_adc: bool = False,
):
    """Oracle for kernels.bayes_mvm: plain jnp einsum."""
    y_mu = jnp.einsum("r,ro->o", x_codes.astype(jnp.float32), mu_fixed)
    y_sigma = jnp.einsum(
        "r,ro->o", x_codes.astype(jnp.float32), sigma_fixed * eps
    )
    if use_adc:
        y_mu = adc_quantize_ref(y_mu, adc_lsb_mu, adc_bits)
        y_sigma = adc_quantize_ref(y_sigma, adc_lsb_sigma, adc_bits)
    return y_mu + y_sigma


def philox4x32_ref(key, counters):
    """NumPy reference Philox4x32-10 (counter in lane 0, rest zero).

    Returns [n, 4] uint32. Mirrors bnn_cim::util::rng::Philox4x32 —
    cross-language vectors are pinned in tests on both sides.
    """
    M0 = np.uint64(0xD2511F53)
    M1 = np.uint64(0xCD9E8D57)
    W0 = np.uint32(0x9E3779B9)
    W1 = np.uint32(0xBB67AE85)
    c0 = np.asarray(counters, dtype=np.uint32)
    c1 = np.zeros_like(c0)
    c2 = np.zeros_like(c0)
    c3 = np.zeros_like(c0)
    k0 = np.uint32(key & 0xFFFFFFFF)
    k1 = np.uint32((key >> 32) & 0xFFFFFFFF)
    for _ in range(10):
        p0 = M0 * c0.astype(np.uint64)
        p1 = M1 * c2.astype(np.uint64)
        hi0 = (p0 >> np.uint64(32)).astype(np.uint32)
        lo0 = p0.astype(np.uint32)
        hi1 = (p1 >> np.uint64(32)).astype(np.uint32)
        lo1 = p1.astype(np.uint32)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
        k0 = np.uint32((int(k0) + int(W0)) & 0xFFFFFFFF)
        k1 = np.uint32((int(k1) + int(W1)) & 0xFFFFFFFF)
    return np.stack([c0, c1, c2, c3], axis=1)


def box_muller_ref(bits0, bits1):
    """Oracle for the kernel's bits→Gaussian mapping."""
    u1 = ((bits0 >> np.uint32(8)).astype(np.float32) + np.float32(1.0)) * np.float32(
        1.0 / 16777216.0
    )
    u2 = (bits1 >> np.uint32(8)).astype(np.float32) * np.float32(1.0 / 16777216.0)
    r = np.sqrt(-2.0 * np.log(u1))
    return r * np.cos(np.float32(2.0 * np.pi) * u2)
