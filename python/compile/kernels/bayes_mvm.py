"""L1 Pallas kernel: the decomposed Bayesian CIM matrix-vector product.

The paper's compute hot-spot (Eq. 5): for quantized inputs X and the
weight decomposition w = μ + σ·ε,

    Y_j = Σ_i X_i·μ_ij  +  Σ_i X_i·σ_ij·ε_ij

with hardware-faithful quantization grids:
  - X: unsigned 4-bit codes (IDAC input),
  - μ: 8-bit *signed-digit* grid — digits ∈ {−1,+1} per bit ⇒ odd
    integers in [−255, 255] (differential SRAM encoding, Fig. 5),
  - σ: 4-bit unsigned magnitude,
  - per-path 6-bit ADC quantization of partial sums (optional).

TPU mapping (DESIGN.md §Hardware-Adaptation): the two subarrays of
Fig. 3 become two MXU matmuls sharing the X operand; BlockSpec tiles
(μ, σ, ε resident in VMEM) express what the chip does with
bitline-parallel words. interpret=True for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def quantize_mu(mu, bits: int = 8):
    """Round to the signed-digit grid: odd integers in [−(2^b−1), 2^b−1].

    x → 2·round((x−1)/2)+1 gives the nearest odd integer.
    """
    grid_max = float(2**bits - 1)
    x = jnp.clip(mu, -grid_max, grid_max)
    return 2.0 * jnp.round((x - 1.0) / 2.0) + 1.0


def quantize_sigma(sigma, bits: int = 4):
    """Round to the unsigned magnitude grid [0, 2^b−1]."""
    grid_max = float(2**bits - 1)
    return jnp.clip(jnp.round(sigma), 0.0, grid_max)


def quantize_act(x, step, bits: int = 4):
    """Activation → IDAC code grid: round(x/step) clamped to [0, 2^b−1]."""
    grid_max = float(2**bits - 1)
    return jnp.clip(jnp.round(x / step), 0.0, grid_max)


def adc_quantize(v, lsb, bits: int = 6):
    """Differential SAR ADC transfer: round to codes, clamp, reconstruct."""
    half = float(2 ** (bits - 1))
    code = jnp.clip(jnp.round(v / lsb), -half, half - 1.0)
    return code * lsb


def _mvm_kernel(
    x_ref,
    mu_ref,
    sigma_ref,
    eps_ref,
    out_ref,
    *,
    adc_bits: int,
    adc_lsb_mu: float,
    adc_lsb_sigma: float,
    use_adc: bool,
):
    """One (batch-row × out-tile) block of the decomposed MVM.

    x: [rows] codes; mu/sigma/eps: [rows, out_block]. The σε product is
    formed in VMEM (ε never leaves the kernel when fused with the GRNG
    kernel) and both paths hit the MXU as matmul/broadcast-reduce ops.
    """
    x = x_ref[...]
    mu = mu_ref[...]
    sigma = sigma_ref[...]
    eps = eps_ref[...]
    # μ path: X·μ — contraction over rows (MXU matvec).
    y_mu = jnp.einsum("r,ro->o", x, mu, preferred_element_type=jnp.float32)
    # σε path: X·(σ⊙ε) — the in-word product then the same contraction.
    y_sigma = jnp.einsum(
        "r,ro->o", x, sigma * eps, preferred_element_type=jnp.float32
    )
    if use_adc:
        y_mu = adc_quantize(y_mu, adc_lsb_mu, adc_bits)
        y_sigma = adc_quantize(y_sigma, adc_lsb_sigma, adc_bits)
    out_ref[...] = y_mu + y_sigma


@functools.partial(
    jax.jit,
    static_argnames=("out_block", "adc_bits", "use_adc"),
)
def bayes_mvm(
    x_codes,
    mu_fixed,
    sigma_fixed,
    eps,
    out_block: int = 0,
    adc_bits: int = 6,
    adc_lsb_mu: float = 7.5,
    adc_lsb_sigma: float = 7.5,
    use_adc: bool = False,
):
    """Decomposed Bayesian MVM: Y = X·μ + X·(σ⊙ε).

    Args:
      x_codes: [rows] float32 (integer-valued codes).
      mu_fixed: [rows, cols] float32 on the signed-digit grid.
      sigma_fixed: [rows, cols] float32 on the σ grid.
      eps: [rows, cols] float32 N(0,1) samples.
      out_block: output-tile width (0 = whole output in one tile).
      use_adc: apply the per-path ADC transfer (word-level approximation
        of the per-bit-column ADCs; the Rust simulator models per-column).

    Returns [cols] float32 in fixed-point units.
    """
    rows, cols = mu_fixed.shape
    if out_block <= 0 or out_block > cols:
        out_block = cols
    assert cols % out_block == 0, "cols must divide into out blocks"
    grid = cols // out_block
    kernel = functools.partial(
        _mvm_kernel,
        adc_bits=adc_bits,
        adc_lsb_mu=adc_lsb_mu,
        adc_lsb_sigma=adc_lsb_sigma,
        use_adc=use_adc,
    )
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((rows,), lambda i: (0,)),
            pl.BlockSpec((rows, out_block), lambda i: (0, i)),
            pl.BlockSpec((rows, out_block), lambda i: (0, i)),
            pl.BlockSpec((rows, out_block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((out_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cols,), jnp.float32),
        interpret=True,
    )(
        x_codes.astype(jnp.float32),
        mu_fixed.astype(jnp.float32),
        sigma_fixed.astype(jnp.float32),
        eps.astype(jnp.float32),
    )


def bayes_mvm_batch(x_codes, mu_fixed, sigma_fixed, eps, **kw):
    """vmap over a batch: x [B, rows], eps [B, rows, cols] → [B, cols]."""
    fn = lambda x, e: bayes_mvm(x, mu_fixed, sigma_fixed, e, **kw)
    return jax.vmap(fn)(x_codes, eps)
