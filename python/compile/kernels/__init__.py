"""L1 Pallas kernels: in-kernel GRNG + decomposed Bayesian CIM MVM."""

from . import bayes_mvm, grng, ref  # noqa: F401
