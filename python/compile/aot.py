"""AOT lowering: JAX → HLO *text* artifacts for the Rust PJRT runtime.

Emits (into artifacts/):
  features.hlo.txt — images[B,32,32,1]            → (features[B,64],)
  head.hlo.txt     — feats[B,64], ε1[B,64,32],
                     ε2[B,32,2]                   → (probs[B,2],)
  full.hlo.txt     — images, ε1, ε2               → (probs[B,2],)
  manifest.json    — shapes/entry-points/batch for the Rust loader.

Weights are baked into the computations as constants (the chip analogy:
weights are *programmed into the tile*; only activations and ε flow).
ε is an *input*: the Rust coordinator's in-word GRNG bank generates it —
the L3↔L1 bridge this architecture is about.

HLO TEXT, not `.serialize()`: jax ≥ 0.5 emits protos with 64-bit ids
which xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default elides baked weight
    # tensors as `constant({...})`, which the xla_extension 0.5.1 text
    # parser silently zero-fills.
    return comp.as_hlo_text(print_large_constants=True)


def load_params(weights_path: Path):
    """Rebuild a params pytree from weights.json (no training needed)."""
    doc = json.loads(weights_path.read_text())
    params = {"features": [], "det_head": [], "head": []}
    for layer in doc["features"]:
        if layer["kind"] == "gap":
            continue
        w = jnp.asarray(
            np.asarray(layer["w"], dtype=np.float32).reshape(layer["w_shape"])
        )
        b = jnp.asarray(np.asarray(layer["b"], dtype=np.float32))
        params["features"].append({"w": w, "b": b})
    for layer in doc["head"]["layers"]:
        mu = jnp.asarray(
            np.asarray(layer["mu"], dtype=np.float32).reshape(
                layer["in"], layer["out"]
            )
        )
        sigma = np.asarray(layer["sigma"], dtype=np.float64).reshape(
            layer["in"], layer["out"]
        )
        # invert softplus to store ρ (model code recomputes σ).
        rho = jnp.asarray(np.log(np.expm1(np.maximum(sigma, 1e-9))), jnp.float32)
        b = jnp.asarray(np.asarray(layer["bias"], dtype=np.float32))
        params["head"].append({"mu": mu, "rho": rho, "b": b})
    for layer in doc["det_head"]["layers"]:
        w = jnp.asarray(
            np.asarray(layer["w"], dtype=np.float32).reshape(
                layer["in"], layer["out"]
            )
        )
        b = jnp.asarray(np.asarray(layer["bias"], dtype=np.float32))
        params["det_head"].append({"w": w, "b": b})
    return params, doc["meta"]


def build_and_export(artifacts_dir: Path, batch: int = BATCH):
    weights = artifacts_dir / "weights.json"
    if not weights.exists():
        raise SystemExit(
            f"{weights} missing — run `python -m compile.train` first "
            "(the Makefile does this)."
        )
    params, meta = load_params(weights)
    qhead = M.quantize_head_weights(params["head"])
    side = meta["side"]

    # ---- features ----
    def features_fn(images):
        return (M.features_fwd(params, images),)

    img_spec = jax.ShapeDtypeStruct((batch, side, side, 1), jnp.float32)
    feats_hlo = to_hlo_text(jax.jit(features_fn).lower(img_spec))

    # ---- head (quantized, Pallas kernel inside, ε as inputs) ----
    act_max = float(meta.get("act_max", M.ACT_MAX))

    def head_fn(feats, eps1, eps2):
        logits = M.head_fwd_sample(qhead, feats, [eps1, eps2], act_max=act_max)
        return (jax.nn.softmax(logits, axis=1),)

    f_spec = jax.ShapeDtypeStruct((batch, M.FEATURE_DIM), jnp.float32)
    e1_spec = jax.ShapeDtypeStruct(
        (batch,) + qhead[0]["mu_fixed"].shape, jnp.float32
    )
    e2_spec = jax.ShapeDtypeStruct(
        (batch,) + qhead[1]["mu_fixed"].shape, jnp.float32
    )
    head_hlo = to_hlo_text(jax.jit(head_fn).lower(f_spec, e1_spec, e2_spec))

    # ---- full pipeline ----
    def full_fn(images, eps1, eps2):
        feats = M.features_fwd(params, images)
        logits = M.head_fwd_sample(qhead, feats, [eps1, eps2], act_max=act_max)
        return (jax.nn.softmax(logits, axis=1),)

    full_hlo = to_hlo_text(jax.jit(full_fn).lower(img_spec, e1_spec, e2_spec))

    (artifacts_dir / "features.hlo.txt").write_text(feats_hlo)
    (artifacts_dir / "head.hlo.txt").write_text(head_hlo)
    (artifacts_dir / "full.hlo.txt").write_text(full_hlo)

    manifest = {
        "batch": batch,
        "side": side,
        "feature_dim": M.FEATURE_DIM,
        "classes": meta["classes"],
        "head_dims": M.HEAD_DIMS,
        "entry_points": {
            "features": {
                "file": "features.hlo.txt",
                "inputs": [["images", [batch, side, side, 1]]],
                "outputs": [["features", [batch, M.FEATURE_DIM]]],
            },
            "head": {
                "file": "head.hlo.txt",
                "inputs": [
                    ["features", [batch, M.FEATURE_DIM]],
                    ["eps1", [batch] + list(qhead[0]["mu_fixed"].shape)],
                    ["eps2", [batch] + list(qhead[1]["mu_fixed"].shape)],
                ],
                "outputs": [["probs", [batch, meta["classes"]]]],
            },
            "full": {
                "file": "full.hlo.txt",
                "inputs": [
                    ["images", [batch, side, side, 1]],
                    ["eps1", [batch] + list(qhead[0]["mu_fixed"].shape)],
                    ["eps2", [batch] + list(qhead[1]["mu_fixed"].shape)],
                ],
                "outputs": [["probs", [batch, meta["classes"]]]],
            },
        },
    }
    (artifacts_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    for f in ["features.hlo.txt", "head.hlo.txt", "full.hlo.txt", "manifest.json"]:
        p = artifacts_dir / f
        print(f"wrote {p} ({p.stat().st_size/1e3:.0f} kB)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()
    build_and_export(Path(args.out), args.batch)


if __name__ == "__main__":
    main()
