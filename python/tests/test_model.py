"""L2 model shape/behaviour tests + dataset checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.dataset import SyntheticPerson


def small_batch(n=4, seed=0):
    gen = SyntheticPerson(32, seed)
    return gen.split(0, n)


def test_feature_shapes():
    params = M.init_params(jax.random.PRNGKey(0))
    imgs, _ = small_batch()
    feats = M.features_fwd(params, jnp.asarray(imgs))
    assert feats.shape == (4, M.FEATURE_DIM)
    assert bool(jnp.all(feats >= 0.0)) and bool(jnp.all(feats <= M.ACT_MAX))


def test_det_head_shapes():
    params = M.init_params(jax.random.PRNGKey(1))
    feats = jnp.ones((4, M.FEATURE_DIM))
    logits = M.det_head_fwd(params, feats)
    assert logits.shape == (4, 2)


def test_elbo_train_path_is_stochastic():
    params = M.init_params(jax.random.PRNGKey(2))
    feats = jnp.ones((4, M.FEATURE_DIM))
    a = M.head_fwd_train(params, feats, jax.random.PRNGKey(3))
    b = M.head_fwd_train(params, feats, jax.random.PRNGKey(4))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_kl_positive_and_differentiable():
    params = M.init_params(jax.random.PRNGKey(5))
    kl = M.kl_to_prior(params)
    assert float(kl) > 0.0
    grads = jax.grad(lambda p: M.kl_to_prior(p))({"head": params["head"]})
    g = np.asarray(grads["head"][0]["mu"])
    assert np.isfinite(g).all()


def test_quantized_head_sample_path():
    params = M.init_params(jax.random.PRNGKey(6))
    qhead = M.quantize_head_weights(params["head"])
    # grids respected
    for layer in qhead:
        mu = layer["mu_fixed"]
        assert np.all(np.abs(mu) <= 255)
        assert np.all(np.mod(np.abs(mu), 2) == 1)
        assert np.all((layer["sigma_fixed"] >= 0) & (layer["sigma_fixed"] <= 15))
    feats = jnp.asarray(np.random.default_rng(0).uniform(0, 6, (2, 64)), jnp.float32)
    eps = [
        jnp.asarray(np.random.default_rng(1).normal(0, 1, (2, 64, 32)), jnp.float32),
        jnp.asarray(np.random.default_rng(2).normal(0, 1, (2, 32, 2)), jnp.float32),
    ]
    logits = M.head_fwd_sample(qhead, feats, eps)
    assert logits.shape == (2, 2)
    assert np.isfinite(np.asarray(logits)).all()


def test_quantized_mean_path_close_to_float_mean():
    params = M.init_params(jax.random.PRNGKey(7))
    qhead = M.quantize_head_weights(params["head"])
    feats = jnp.asarray(np.random.default_rng(3).uniform(0, 6, (4, 64)), jnp.float32)
    q_logits = np.asarray(M.head_fwd_mean(qhead, feats))
    # float μ-only reference
    x = feats
    for i, layer in enumerate(params["head"]):
        x = x @ layer["mu"] + layer["b"]
        if i + 1 < len(params["head"]):
            x = jax.nn.relu(x)
    f_logits = np.asarray(x)
    # Quantization (4-bit acts!) is coarse; demand correlation not equality.
    r = np.corrcoef(q_logits.reshape(-1), f_logits.reshape(-1))[0, 1]
    assert r > 0.9, f"quantized mean path decorrelated: r={r}"


def test_dataset_balance_and_range():
    imgs, labels = small_batch(50, seed=9)
    assert imgs.shape == (50, 32, 32, 1)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    assert labels.sum() == 25  # balanced


def test_dataset_ood_split():
    gen = SyntheticPerson(32, 4)
    ood = gen.ood_split(0, 6)
    assert ood.shape == (6, 32, 32, 1)
    # inverted kind inverts its in-distribution twin
    base, _ = gen.sample(1)
    inv = gen.ood_sample(1, "inverted")
    np.testing.assert_allclose(base + inv, 1.0, atol=1e-5)
