// GOOD: separate mul + add roundings; "mul_add" only in comment/string.
pub fn mac(acc: f64, a: f64, b: f64) -> f64 {
    // mul_add is forbidden here: two roundings, bit-identical on all arms.
    let why = "no mul_add";
    let _ = why;
    acc + a * b
}
