// GOOD: unsafe in the allowed dir, annotated.
pub fn lane_sum(a: &[f64]) -> f64 {
    // SAFETY: caller guarantees a is non-empty; bounds checked above.
    unsafe { *a.get_unchecked(0) }
}

/// Doc-sectioned form.
///
/// # Safety
/// Caller must ensure AVX2 is available.
pub unsafe fn lane_dot(a: &[f64], b: &[f64]) -> f64 {
    a[0] * b[0]
}
