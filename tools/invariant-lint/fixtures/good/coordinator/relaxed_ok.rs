// GOOD: Relaxed ordering justified.
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(counter: &AtomicUsize) -> usize {
    // RELAXED: monotonic stats counter; no data published through it.
    counter.fetch_add(1, Ordering::Relaxed)
}
