// GOOD: both paths acquire alpha before beta — a consistent global
// order, so the lock graph is acyclic.
use std::sync::Mutex;

pub fn worker_a(alpha: &Mutex<u32>, beta: &Mutex<u32>) {
    let g = alpha.lock();
    beta.lock();
    drop(g);
}

pub fn worker_b(alpha: &Mutex<u32>, beta: &Mutex<u32>) {
    let g = alpha.lock();
    let h = beta.lock();
    drop(h);
    drop(g);
}
