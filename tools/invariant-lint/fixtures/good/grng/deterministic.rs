// GOOD: replay-pinned module with counter-based state only; wall-clock
// timing confined to a cfg(test) module.
use std::collections::BTreeMap;

pub fn fill(seed: u64, out: &mut [u64]) {
    let mut s = seed;
    for v in out.iter_mut() {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *v = s;
    }
}

pub fn histogram(samples: &[u64]) -> BTreeMap<u64, u64> {
    let mut h = BTreeMap::new();
    for &s in samples {
        *h.entry(s % 16).or_insert(0) += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_fine_in_tests() {
        let t0 = std::time::Instant::now();
        let mut out = [0u64; 4];
        fill(7, &mut out);
        assert!(t0.elapsed().as_secs() < 60);
    }
}
