// BAD (R3): hash-ordered iteration inside a replay-pinned module.
use std::collections::HashMap;

pub fn total(map: &HashMap<u32, f64>) -> f64 {
    map.values().sum()
}
