// BAD (R3): wall-clock read inside a replay-pinned module.
use std::time::Instant;

pub fn seed_from_clock() -> u64 {
    Instant::now().elapsed().as_nanos() as u64
}
