// BAD (R2): fused multiply-add in a bit-identity kernel module.
pub fn mac(acc: f64, a: f64, b: f64) -> f64 {
    a.mul_add(b, acc)
}
