// BAD (R1): unsafe outside the allowed dirs, even though annotated.
pub fn peek(a: &[f64]) -> f64 {
    // SAFETY: caller guarantees a is non-empty.
    unsafe { *a.get_unchecked(0) }
}
