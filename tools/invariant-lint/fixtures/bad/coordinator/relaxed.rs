// BAD (R4): Relaxed ordering with no RELAXED: justification.
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(counter: &AtomicUsize) -> usize {
    counter.fetch_add(1, Ordering::Relaxed)
}
