// BAD (R5): two paths acquire the same pair of locks in opposite
// orders — the classic AB/BA deadlock shape.
use std::sync::Mutex;

pub fn worker_a(alpha: &Mutex<u32>, beta: &Mutex<u32>) {
    let g = alpha.lock();
    beta.lock();
    drop(g);
}

pub fn worker_b(alpha: &Mutex<u32>, beta: &Mutex<u32>) {
    let g = beta.lock();
    alpha.lock();
    drop(g);
}
