// BAD (R1): unsafe inside the allowed dir but with no SAFETY comment.
pub fn lane_sum(a: &[f64]) -> f64 {
    unsafe { *a.get_unchecked(0) }
}
