//! Minimal TOML-subset parser for `contracts.toml`, in the same idiom as
//! the tree's `util::toml`: sections (`[a.b]`), bare or quoted keys, and
//! string / integer / boolean / string-array values. Everything is stored
//! flat as `section.path.key -> Value` so callers read dotted paths.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Bool(bool),
    List(Vec<String>),
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

#[derive(Debug, Default)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, TomlError> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| TomlError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| TomlError {
                line: lineno,
                msg: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = parse_key(line[..eq].trim(), lineno)?;
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let full = if section.is_empty() {
                key
            } else {
                format!("{section}.{key}")
            };
            doc.entries.insert(full, value);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// String-array value; absent key reads as the empty list.
    pub fn list(&self, key: &str) -> Vec<String> {
        match self.entries.get(key) {
            Some(Value::List(v)) => v.clone(),
            Some(Value::Str(s)) => vec![s.clone()],
            _ => Vec::new(),
        }
    }

    /// All `prefix.<name> = "str"` pairs, keyed by `<name>`.
    pub fn table(&self, prefix: &str) -> BTreeMap<String, String> {
        let want = format!("{prefix}.");
        let mut out = BTreeMap::new();
        for (k, v) in &self.entries {
            if let Some(name) = k.strip_prefix(&want) {
                if let Value::Str(s) = v {
                    out.insert(name.to_string(), s.clone());
                }
            }
        }
        out
    }
}

fn parse_key(raw: &str, lineno: usize) -> Result<String, TomlError> {
    let raw = raw.trim();
    if let Some(inner) = raw.strip_prefix('"') {
        return inner.strip_suffix('"').map(str::to_string).ok_or(TomlError {
            line: lineno,
            msg: "unterminated quoted key".into(),
        });
    }
    if raw.is_empty() {
        return Err(TomlError {
            line: lineno,
            msg: "empty key".into(),
        });
    }
    Ok(raw.to_string())
}

fn parse_value(raw: &str, lineno: usize) -> Result<Value, TomlError> {
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = raw.strip_prefix('"') {
        let s = inner.strip_suffix('"').ok_or_else(|| TomlError {
            line: lineno,
            msg: "unterminated string".into(),
        })?;
        return Ok(Value::Str(s.to_string()));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let body = inner.strip_suffix(']').ok_or_else(|| TomlError {
            line: lineno,
            msg: "unterminated array (arrays must be single-line)".into(),
        })?;
        let mut items = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match parse_value(part, lineno)? {
                Value::Str(s) => items.push(s),
                _ => {
                    return Err(TomlError {
                        line: lineno,
                        msg: "only string arrays are supported".into(),
                    })
                }
            }
        }
        return Ok(Value::List(items));
    }
    raw.parse::<i64>().map(Value::Int).map_err(|_| TomlError {
        line: lineno,
        msg: format!("unrecognized value `{raw}`"),
    })
}

/// Split on commas that sit outside string quotes.
fn split_top_level(body: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in body.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

/// Drop a `#` comment, respecting `#` inside quoted strings.
fn strip_comment(raw: &str) -> &str {
    let mut in_str = false;
    for (i, c) in raw.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &raw[..i],
            _ => {}
        }
    }
    raw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_keys_and_arrays() {
        let doc = Doc::parse(
            r#"
top = 3
[rules.fma]
deny_dirs = ["arch", "cim"] # trailing comment
[lockgraph.vars]
slot = "in_flight"
"quoted.key" = "v"
"#,
        )
        .unwrap();
        assert_eq!(doc.get("top"), Some(&Value::Int(3)));
        assert_eq!(
            doc.list("rules.fma.deny_dirs"),
            vec!["arch".to_string(), "cim".to_string()]
        );
        let vars = doc.table("lockgraph.vars");
        assert_eq!(vars.get("slot").map(String::as_str), Some("in_flight"));
        assert_eq!(vars.get("quoted.key").map(String::as_str), Some("v"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("key value").is_err());
        assert!(Doc::parse("k = [1, 2]").is_err());
    }
}
