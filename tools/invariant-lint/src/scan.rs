//! Source scanning: comment/string stripping, a flat token stream with
//! line numbers, `#[cfg(test)]` block ranges, and the `.rs` file walk.
//!
//! The stripper replaces comment and string-literal *contents* with
//! spaces so byte offsets and line numbers survive; rule passes that
//! need the comments back (SAFETY/RELAXED windows) search the raw lines.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct Token {
    pub line: usize, // 1-indexed
    pub text: String,
    pub is_ident: bool,
}

#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the scan root, forward slashes.
    pub rel: String,
    pub raw_lines: Vec<String>,
    pub tokens: Vec<Token>,
    /// Inclusive line ranges covered by `#[cfg(test)] mod ... { }`.
    pub test_ranges: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn load(path: &Path, rel: &str) -> io::Result<SourceFile> {
        let text = fs::read_to_string(path)?;
        Ok(SourceFile::from_text(rel, &text))
    }

    pub fn from_text(rel: &str, text: &str) -> SourceFile {
        let stripped = strip(text);
        let tokens = tokenize(&stripped);
        let test_ranges = find_test_ranges(&tokens);
        SourceFile {
            rel: rel.to_string(),
            raw_lines: text.lines().map(str::to_string).collect(),
            tokens,
            test_ranges,
        }
    }

    pub fn in_test_range(&self, line: usize) -> bool {
        self.test_ranges.iter().any(|&(lo, hi)| lo <= line && line <= hi)
    }

    /// True when any raw line in `[line - above, line]` contains `needle`.
    pub fn window_contains(&self, line: usize, above: usize, needles: &[&str]) -> bool {
        let lo = line.saturating_sub(above + 1);
        let hi = line.min(self.raw_lines.len());
        self.raw_lines[lo..hi]
            .iter()
            .any(|l| needles.iter().any(|n| l.contains(n)))
    }
}

/// Replace comments and string/char-literal contents with spaces,
/// preserving newlines. Handles nested block comments, raw strings, and
/// the lifetime-vs-char-literal ambiguity.
pub fn strip(text: &str) -> String {
    let b = text.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        // Line comment.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            while i < b.len() && b[i] != b'\n' {
                out.push(b' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nesting).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1;
            out.push(b' ');
            out.push(b' ');
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else {
                    out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (and br variants).
        if c == b'r' || (c == b'b' && i + 1 < b.len() && b[i + 1] == b'r') {
            let start = if c == b'b' { i + 1 } else { i };
            let mut j = start + 1;
            while j < b.len() && b[j] == b'#' {
                j += 1;
            }
            if j < b.len() && b[j] == b'"' && (i == 0 || !is_ident_byte(b[i - 1])) {
                let hashes = j - (start + 1);
                for _ in i..=j {
                    out.push(b' ');
                }
                i = j + 1;
                let close: Vec<u8> = std::iter::once(b'"')
                    .chain(std::iter::repeat(b'#').take(hashes))
                    .collect();
                while i < b.len() {
                    if b[i] == b'"' && b[i..].starts_with(&close) {
                        for _ in 0..close.len() {
                            out.push(b' ');
                        }
                        i += close.len();
                        break;
                    }
                    out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
                continue;
            }
        }
        // Ordinary string.
        if c == b'"' {
            out.push(b'"');
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' && i + 1 < b.len() {
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    out.push(b'"');
                    i += 1;
                    break;
                }
                out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime: 'x' or '\n' is a literal; 'ident not
        // followed by a closing quote is a lifetime.
        if c == b'\'' {
            let lit_end = char_literal_end(b, i);
            if let Some(end) = lit_end {
                for _ in i..end {
                    out.push(b' ');
                }
                i = end;
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    // b[i] == '\''. Escaped: '\X...'; plain: 'C'.
    if i + 1 >= b.len() {
        return None;
    }
    if b[i + 1] == b'\\' {
        let mut j = i + 2;
        while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
            j += 1;
        }
        return if j < b.len() && b[j] == b'\'' { Some(j + 1) } else { None };
    }
    // Plain literal: exactly one char (ASCII or multibyte) then a close
    // quote. Anything else ('a, 'static, <'a, 'b>) is a lifetime.
    let first = b[i + 1];
    let width = if first < 0x80 {
        1
    } else if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    };
    let close = i + 1 + width;
    if close < b.len() && b[close] == b'\'' && first != b'\n' {
        Some(close + 1)
    } else {
        None
    }
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

pub fn tokenize(stripped: &str) -> Vec<Token> {
    let b = stripped.as_bytes();
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        if c == b'_' || c.is_ascii_alphabetic() {
            let start = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            toks.push(Token {
                line,
                text: stripped[start..i].to_string(),
                is_ident: true,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            // Consume a fraction only when digits follow the dot, so
            // `self.0.lock()` keeps its field-access dots.
            if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < b.len() && is_ident_byte(b[i]) {
                    i += 1;
                }
            }
            toks.push(Token {
                line,
                text: stripped[start..i].to_string(),
                is_ident: false,
            });
            continue;
        }
        // Multi-char puncts we care about keeping atomic.
        let mut matched = false;
        for pat in ["::", "=>", "->", "||", "&&", "..=", ".."] {
            if stripped[i..].starts_with(pat) {
                toks.push(Token {
                    line,
                    text: pat.to_string(),
                    is_ident: false,
                });
                i += pat.len();
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }
        let ch = stripped[i..].chars().next().unwrap();
        toks.push(Token {
            line,
            text: ch.to_string(),
            is_ident: false,
        });
        i += ch.len_utf8();
    }
    toks
}

/// Inclusive line ranges of `#[cfg(test)] mod name { ... }` blocks.
fn find_test_ranges(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0;
    while i + 6 < toks.len() {
        let is_cfg_test = toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")"
            && toks[i + 6].text == "]";
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // Find the item the attribute decorates; only `mod` blocks are
        // excluded wholesale (fn-level cfg(test) is rare in this tree).
        let mut j = i + 7;
        while j < toks.len() && toks[j].text != "mod" && toks[j].text != "{" && toks[j].text != ";"
        {
            j += 1;
        }
        if j < toks.len() && toks[j].text == "mod" {
            // Advance to the opening brace, then match it.
            while j < toks.len() && toks[j].text != "{" {
                j += 1;
            }
            if j < toks.len() {
                let start_line = toks[i].line;
                let mut depth = 0i32;
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "{" => depth += 1,
                        "}" => {
                            depth -= 1;
                            if depth == 0 {
                                ranges.push((start_line, toks[j].line));
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            }
        }
        i = j.max(i + 1);
    }
    ranges
}

/// All `.rs` files under `root`, sorted, as (abs path, rel path) pairs.
/// A bare file argument yields itself with its file name as rel.
pub fn rs_files(root: &Path) -> io::Result<Vec<(PathBuf, String)>> {
    let mut out = Vec::new();
    if root.is_file() {
        let rel = root
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        out.push((root.to_path_buf(), rel));
        return Ok(out);
    }
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = fs::read_dir(&dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.push((p, rel));
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings_preserving_lines() {
        let src = "let a = \"Instant::now()\"; // Instant::now()\nlet b = 'x';\n/* HashMap */ let c = 1;\n";
        let s = strip(src);
        assert!(!s.contains("Instant"));
        assert!(!s.contains("HashMap"));
        assert_eq!(s.matches('\n').count(), src.matches('\n').count());
        assert!(s.contains("let b ="));
    }

    #[test]
    fn lifetimes_survive_char_literals_do_not() {
        let s = strip("fn f<'a>(x: &'a str) -> char { 'y' }");
        assert!(s.contains("'a"));
        assert!(!s.contains("'y'"));
    }

    #[test]
    fn raw_strings_are_stripped() {
        let s = strip("let x = r#\"mul_add\"#; let y = 2;");
        assert!(!s.contains("mul_add"));
        assert!(s.contains("let y = 2"));
    }

    #[test]
    fn tokenizer_keeps_field_access_dots() {
        let toks = tokenize("self.0.lock()");
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["self", ".", "0", ".", "lock", "(", ")"]);
    }

    #[test]
    fn cfg_test_mod_ranges_found() {
        let f = SourceFile::from_text(
            "x.rs",
            "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn tail() {}\n",
        );
        assert_eq!(f.test_ranges, vec![(2, 5)]);
        assert!(f.in_test_range(4));
        assert!(!f.in_test_range(6));
    }
}
