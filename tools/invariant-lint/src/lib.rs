//! invariant-lint: static enforcement of the repo's determinism and
//! concurrency contracts (DESIGN.md §11).
//!
//! Rules:
//! - **R1** — `unsafe` confined to the SIMD arch layer, every use
//!   annotated `// SAFETY:` (or a `# Safety` doc section).
//! - **R2** — no fused-multiply-add tokens in bit-identity kernels.
//! - **R3** — no wall clocks, hash-ordered collections, or ambient
//!   randomness in replay-pinned modules.
//! - **R4** — every `Ordering::Relaxed` carries a `// RELAXED:`
//!   justification.
//! - **R5** — the coordinator's lock-acquisition graph is acyclic.
//!
//! Everything is std-only and hand-rolled, same ethos as the edge's
//! JSON codec: the linter must never acquire a dependency surface
//! larger than the invariants it guards.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

pub mod lockgraph;
pub mod rules;
pub mod scan;
pub mod toml_lite;

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl Diagnostic {
    pub fn new(file: &str, line: usize, rule: &'static str, msg: String) -> Diagnostic {
        Diagnostic {
            file: file.to_string(),
            line,
            rule,
            msg,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.msg
        )
    }
}

/// Parsed `contracts.toml`.
#[derive(Debug, Clone)]
pub struct Contracts {
    pub unsafe_allowed_dirs: Vec<String>,
    pub fma_deny_dirs: Vec<String>,
    pub fma_tokens: Vec<String>,
    pub replay_pinned: Vec<String>,
    pub replay_banned: Vec<String>,
    pub relaxed_allow: Vec<String>,
    pub lock_scan: Vec<String>,
    pub lock_types: BTreeMap<String, String>,
    pub lock_vars: BTreeMap<String, String>,
    pub lock_ignore_methods: Vec<String>,
}

impl Contracts {
    pub fn from_doc(doc: &toml_lite::Doc) -> Contracts {
        Contracts {
            unsafe_allowed_dirs: doc.list("rules.unsafe.allowed_dirs"),
            fma_deny_dirs: doc.list("rules.fma.deny_dirs"),
            fma_tokens: doc.list("rules.fma.tokens"),
            replay_pinned: doc.list("rules.replay.pinned"),
            replay_banned: doc.list("rules.replay.banned"),
            relaxed_allow: doc.list("rules.relaxed.allow"),
            lock_scan: doc.list("lockgraph.scan"),
            lock_types: doc.table("lockgraph.types"),
            lock_vars: doc.table("lockgraph.vars"),
            lock_ignore_methods: doc.list("lockgraph.ignore_methods"),
        }
    }

    pub fn load(path: &Path) -> Result<Contracts, String> {
        let text = fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let doc = toml_lite::Doc::parse(&text)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(Contracts::from_doc(&doc))
    }

    /// Contracts used by the unit tests: a miniature of the real file.
    pub fn test_default() -> Contracts {
        let mut lock_types = BTreeMap::new();
        for (k, v) in [
            ("ShardTable", "shard_table"),
            ("InFlight", "in_flight"),
            ("SwapState", "swap_state"),
            ("Metrics", "metrics"),
        ] {
            lock_types.insert(k.to_string(), v.to_string());
        }
        let mut lock_vars = BTreeMap::new();
        for (k, v) in [
            ("slot", "in_flight"),
            ("metrics", "metrics"),
            ("h", "handles"),
            ("entries", "shard_table"),
        ] {
            lock_vars.insert(k.to_string(), v.to_string());
        }
        Contracts {
            unsafe_allowed_dirs: vec!["arch".into()],
            fma_deny_dirs: vec!["arch".into(), "cim".into(), "grng".into()],
            fma_tokens: ["mul_add", "fma", "_mm256_fmadd_pd", "vfmaq_f64"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            replay_pinned: vec!["arch".into(), "cim".into(), "grng".into()],
            replay_banned: ["Instant", "SystemTime", "HashMap", "HashSet", "thread_rng"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            relaxed_allow: Vec::new(),
            lock_scan: vec!["coordinator".into()],
            lock_types,
            lock_vars,
            lock_ignore_methods: ["clone", "len", "iter", "push", "send", "recv", "close"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        }
    }
}

/// Lint every `.rs` file under `root` (or `root` itself when it is a
/// file). Diagnostics come back sorted by (file, line, rule).
pub fn lint_root(root: &Path, c: &Contracts) -> io::Result<Vec<Diagnostic>> {
    let mut sources = Vec::new();
    for (abs, rel) in scan::rs_files(root)? {
        sources.push(scan::SourceFile::load(&abs, &rel)?);
    }
    let mut diags = Vec::new();
    for f in &sources {
        rules::r1_unsafe(f, c, &mut diags);
        rules::r2_fma(f, c, &mut diags);
        rules::r3_replay(f, c, &mut diags);
        rules::r4_relaxed(f, c, &mut diags);
    }
    diags.extend(lockgraph::analyze(&sources, c).diagnostics);
    diags.sort();
    diags.dedup();
    Ok(diags)
}
