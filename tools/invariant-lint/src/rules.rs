//! Rules R1–R4: token-level invariant checks over one stripped source
//! file. R5 (lock-order cycles) lives in `lockgraph.rs`.

use crate::scan::SourceFile;
use crate::{Contracts, Diagnostic};

/// `rel` is under `dir` when `dir` names one of its ancestor directories
/// (entries may be nested like "util/rng.rs", which matches exactly or
/// as a prefix).
fn under(rel: &str, dirs: &[String]) -> bool {
    dirs.iter().any(|d| {
        let d = d.trim_end_matches('/');
        rel == d || rel.starts_with(&format!("{d}/"))
    })
}

/// R1: `unsafe` confined to the allowed dirs, and every occurrence
/// carries a `// SAFETY:` (or `# Safety` doc section) within the
/// preceding 10 lines.
pub fn r1_unsafe(file: &SourceFile, c: &Contracts, out: &mut Vec<Diagnostic>) {
    for t in &file.tokens {
        if t.text != "unsafe" {
            continue;
        }
        if !under(&file.rel, &c.unsafe_allowed_dirs) {
            out.push(Diagnostic::new(
                &file.rel,
                t.line,
                "R1",
                format!(
                    "`unsafe` outside the allowed dirs ({:?}) — keep unsafe confined to the SIMD arch layer",
                    c.unsafe_allowed_dirs
                ),
            ));
        }
        if !file.window_contains(t.line, 10, &["SAFETY:", "# Safety"]) {
            out.push(Diagnostic::new(
                &file.rel,
                t.line,
                "R1",
                "`unsafe` without a `// SAFETY:` comment (or `# Safety` doc section) in the preceding 10 lines"
                    .to_string(),
            ));
        }
    }
}

/// R2: no fused-multiply-add tokens in kernel/hot-path modules — the
/// bit-identity contract requires separate mul + add roundings.
pub fn r2_fma(file: &SourceFile, c: &Contracts, out: &mut Vec<Diagnostic>) {
    if !under(&file.rel, &c.fma_deny_dirs) {
        return;
    }
    for t in &file.tokens {
        if t.is_ident && c.fma_tokens.iter().any(|b| b == &t.text) {
            out.push(Diagnostic::new(
                &file.rel,
                t.line,
                "R2",
                format!(
                    "fused-op token `{}` in a bit-identity kernel module — use separate mul + add",
                    t.text
                ),
            ));
        }
    }
}

/// R3: replay-pinned modules must not touch wall clocks, hash-ordered
/// collections, or ambient randomness. `#[cfg(test)] mod` blocks are
/// exempt (tests may time things; they are not replayed).
pub fn r3_replay(file: &SourceFile, c: &Contracts, out: &mut Vec<Diagnostic>) {
    if !under(&file.rel, &c.replay_pinned) {
        return;
    }
    for t in &file.tokens {
        if !t.is_ident || file.in_test_range(t.line) {
            continue;
        }
        if c.replay_banned.iter().any(|b| b == &t.text) {
            out.push(Diagnostic::new(
                &file.rel,
                t.line,
                "R3",
                format!(
                    "`{}` inside replay-pinned module — wall clocks, hash ordering, and ambient randomness break bit-identical replay",
                    t.text
                ),
            ));
        }
    }
}

/// R4: every `Ordering::Relaxed` outside the allowlist carries a
/// `// RELAXED:` justification within the preceding 3 lines.
pub fn r4_relaxed(file: &SourceFile, c: &Contracts, out: &mut Vec<Diagnostic>) {
    if c.relaxed_allow.iter().any(|f| f == &file.rel) {
        return;
    }
    let toks = &file.tokens;
    for i in 0..toks.len().saturating_sub(2) {
        if toks[i].text == "Ordering"
            && toks[i + 1].text == "::"
            && toks[i + 2].text == "Relaxed"
            && !file.window_contains(toks[i].line, 3, &["RELAXED:"])
        {
            out.push(Diagnostic::new(
                &file.rel,
                toks[i].line,
                "R4",
                "`Ordering::Relaxed` without a `// RELAXED:` justification in the preceding 3 lines"
                    .to_string(),
            ));
        }
    }
}

pub fn is_under(rel: &str, dirs: &[String]) -> bool {
    under(rel, dirs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn contracts() -> Contracts {
        Contracts::test_default()
    }

    fn run_on(rel: &str, src: &str) -> Vec<Diagnostic> {
        let f = SourceFile::from_text(rel, src);
        let c = contracts();
        let mut out = Vec::new();
        r1_unsafe(&f, &c, &mut out);
        r2_fma(&f, &c, &mut out);
        r3_replay(&f, &c, &mut out);
        r4_relaxed(&f, &c, &mut out);
        out
    }

    #[test]
    fn r1_flags_unsafe_outside_arch_and_missing_safety() {
        let d = run_on("cim/x.rs", "fn f() { unsafe { core(); } }");
        assert!(d.iter().any(|d| d.rule == "R1" && d.msg.contains("outside")));
        assert!(d.iter().any(|d| d.rule == "R1" && d.msg.contains("SAFETY")));
    }

    #[test]
    fn r1_passes_annotated_arch_unsafe() {
        let d = run_on(
            "arch/x.rs",
            "fn f() {\n    // SAFETY: caller checked the CPU feature.\n    unsafe { core(); }\n}",
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn r2_flags_mul_add_in_kernels_only() {
        let bad = run_on("grng/fill.rs", "fn f(a: f64) -> f64 { a.mul_add(2.0, 1.0) }");
        assert!(bad.iter().any(|d| d.rule == "R2"));
        let ok = run_on("coordinator/x.rs", "fn f(a: f64) -> f64 { a.mul_add(2.0, 1.0) }");
        assert!(ok.iter().all(|d| d.rule != "R2"));
    }

    #[test]
    fn r3_flags_wall_clock_outside_tests_only() {
        let bad = run_on("cim/t.rs", "fn f() { let t = Instant::now(); }");
        assert!(bad.iter().any(|d| d.rule == "R3"));
        let ok = run_on(
            "cim/t.rs",
            "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let x = Instant::now(); }\n}\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn r3_ignores_strings_and_comments() {
        let ok = run_on(
            "cim/t.rs",
            "// Instant::now() is forbidden here.\nfn f() -> &'static str { \"HashMap\" }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn r4_requires_relaxed_justification() {
        let bad = run_on("coordinator/a.rs", "fn f() { x.load(Ordering::Relaxed); }");
        assert!(bad.iter().any(|d| d.rule == "R4"));
        let ok = run_on(
            "coordinator/a.rs",
            "fn f() {\n    // RELAXED: pure hint, applied at batch boundaries.\n    x.load(Ordering::Relaxed);\n}",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }
}
