//! R5: static lock-order screening over the coordinator.
//!
//! The scanner extracts every `Mutex`/`RwLock` acquisition site
//! (`.lock()` / zero-arg `.read()` / `.write()`), resolves the receiver
//! to a named *lock class* via `contracts.toml` (`[lockgraph.types]` for
//! `self`-rooted acquisitions inside an `impl`, `[lockgraph.vars]` for
//! free variables), tracks guard lifetimes with scope heuristics, and
//! follows named calls transitively to build a lock-class digraph.
//! A cycle (including a self-edge: re-locking a held class) fails the
//! lint. Unresolvable receivers are themselves diagnostics so the maps
//! stay maintained as the coordinator grows.
//!
//! Known under-approximations (documented in DESIGN.md §11): anonymous
//! closures are scanned as detached roots — their internal lock edges
//! are seen, but a closure executed synchronously under a held guard
//! does not inherit that guard — and locks internal to unscanned
//! modules (`util::threadpool::Bounded`, `runtime::SharedModelCache`)
//! are invisible to the graph.

use std::collections::{BTreeMap, BTreeSet};

use crate::scan::{SourceFile, Token};
use crate::{Contracts, Diagnostic};

const ACQUIRE_METHODS: [&str; 3] = ["lock", "read", "write"];
const GUARD_CHAIN: [&str; 3] = ["unwrap", "expect", "unwrap_or_else"];
const KEYWORDS: [&str; 10] = [
    "if", "while", "for", "match", "return", "loop", "fn", "let", "move", "in",
];

#[derive(Debug)]
struct Func {
    /// Bare name; anonymous closures get `"<closure>"` and are never
    /// resolvable as callees.
    name: String,
    /// Surrounding `impl` type, for `self`-rooted receiver resolution.
    qual: Option<String>,
    file_idx: usize,
    /// Token index range [start, end) of the body.
    body: (usize, usize),
}

#[derive(Debug, Clone)]
struct Event {
    line: usize,
    held: Vec<String>,
    kind: EventKind,
}

#[derive(Debug, Clone)]
enum EventKind {
    Acquire(String),
    Call(String),
}

struct Guard {
    lock: String,
    depth: i32,
    binding: Option<String>,
    temp: bool,
}

pub struct LockGraph {
    /// Ordered edges (held, acquired) -> first observed site.
    pub edges: BTreeMap<(String, String), (String, usize)>,
    pub diagnostics: Vec<Diagnostic>,
}

pub fn analyze(files: &[SourceFile], c: &Contracts) -> LockGraph {
    let mut diags = Vec::new();
    let mut funcs = Vec::new();
    for (idx, f) in files.iter().enumerate() {
        if !crate::rules::is_under(&f.rel, &c.lock_scan) {
            continue;
        }
        collect_funcs(f, idx, &mut funcs);
    }
    let events: Vec<Vec<Event>> = funcs
        .iter()
        .map(|fun| scan_body(&files[fun.file_idx], fun, &funcs, c, &mut diags))
        .collect();

    // Bare name -> function indices (closures excluded).
    let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, f) in funcs.iter().enumerate() {
        if f.name != "<closure>" {
            by_name.entry(f.name.as_str()).or_default().push(i);
        }
    }

    // Transitive acquisition sets, to fixpoint.
    let mut acq: Vec<BTreeSet<String>> = events
        .iter()
        .map(|evs| {
            evs.iter()
                .filter_map(|e| match &e.kind {
                    EventKind::Acquire(l) => Some(l.clone()),
                    EventKind::Call(_) => None,
                })
                .collect()
        })
        .collect();
    loop {
        let mut changed = false;
        for (i, evs) in events.iter().enumerate() {
            let mut add: BTreeSet<String> = BTreeSet::new();
            for e in evs {
                if let EventKind::Call(name) = &e.kind {
                    if let Some(targets) = by_name.get(name.as_str()) {
                        for &t in targets {
                            if t != i {
                                add.extend(acq[t].iter().cloned());
                            }
                        }
                    }
                }
            }
            for l in add {
                changed |= acq[i].insert(l);
            }
        }
        if !changed {
            break;
        }
    }

    // Edges: held -> (direct acquisition | every lock a callee reaches).
    let mut edges: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();
    for (i, evs) in events.iter().enumerate() {
        let file = &files[funcs[i].file_idx];
        for e in evs {
            if e.held.is_empty() {
                continue;
            }
            let acquired: Vec<String> = match &e.kind {
                EventKind::Acquire(l) => vec![l.clone()],
                EventKind::Call(name) => by_name
                    .get(name.as_str())
                    .map(|ts| {
                        ts.iter()
                            .filter(|&&t| t != i)
                            .flat_map(|&t| acq[t].iter().cloned())
                            .collect()
                    })
                    .unwrap_or_default(),
            };
            for h in &e.held {
                for a in &acquired {
                    edges
                        .entry((h.clone(), a.clone()))
                        .or_insert_with(|| (file.rel.clone(), e.line));
                }
            }
        }
    }

    for cycle in find_cycles(&edges) {
        let mut sites = Vec::new();
        for w in cycle.windows(2) {
            if let Some((f, l)) = edges.get(&(w[0].clone(), w[1].clone())) {
                sites.push(format!("{}->{} at {}:{}", w[0], w[1], f, l));
            }
        }
        let (file, line) = edges
            .get(&(cycle[0].clone(), cycle[1].clone()))
            .cloned()
            .unwrap_or_default();
        diags.push(Diagnostic::new(
            &file,
            line,
            "R5",
            format!(
                "lock-order cycle: {} ({})",
                cycle.join(" -> "),
                sites.join(", ")
            ),
        ));
    }

    LockGraph {
        edges,
        diagnostics: diags,
    }
}

/// Collect named fns (with impl context), `let name = |..|` closures,
/// and anonymous closures (as detached `"<closure>"` roots).
fn collect_funcs(file: &SourceFile, file_idx: usize, out: &mut Vec<Func>) {
    let toks = &file.tokens;
    let mut depth: i32 = 0;
    let mut impl_stack: Vec<(String, i32)> = Vec::new();
    let mut named_pipes: BTreeSet<usize> = BTreeSet::new();
    let mut i = 0;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                while impl_stack.last().map(|&(_, d)| depth < d).unwrap_or(false) {
                    impl_stack.pop();
                }
            }
            "impl" => {
                // Type name = last top-level ident before the body `{`,
                // skipping generic params.
                let mut j = i + 1;
                let mut angle = 0i32;
                let mut last_ident = None;
                while j < toks.len() && !(angle == 0 && toks[j].text == "{") {
                    match toks[j].text.as_str() {
                        "<" => angle += 1,
                        ">" => angle -= 1,
                        _ if toks[j].is_ident && angle == 0 && toks[j].text != "for" => {
                            last_ident = Some(toks[j].text.clone());
                        }
                        _ => {}
                    }
                    j += 1;
                }
                if let Some(name) = last_ident {
                    impl_stack.push((name, depth + 1));
                }
            }
            "fn" if i + 1 < toks.len() && toks[i + 1].is_ident => {
                let name = toks[i + 1].text.clone();
                // Body `{` = first brace outside the parameter parens.
                let mut j = i + 2;
                let mut paren = 0i32;
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "(" => paren += 1,
                        ")" => paren -= 1,
                        ";" if paren == 0 => break, // trait method decl
                        "{" if paren == 0 => break,
                        _ => {}
                    }
                    j += 1;
                }
                if j < toks.len() && toks[j].text == "{" {
                    let end = match_brace(toks, j);
                    out.push(Func {
                        name,
                        qual: impl_stack.last().map(|(n, _)| n.clone()),
                        file_idx,
                        body: (j + 1, end),
                    });
                }
            }
            "let" => {
                // `let name = |..| body` / `let name = move |..| body`
                let mut j = i + 1;
                if j < toks.len() && toks[j].text == "mut" {
                    j += 1;
                }
                if j + 1 < toks.len() && toks[j].is_ident && toks[j + 1].text == "=" {
                    let name = toks[j].text.clone();
                    let mut k = j + 2;
                    if k < toks.len() && toks[k].text == "move" {
                        k += 1;
                    }
                    if k < toks.len() && (toks[k].text == "|" || toks[k].text == "||") {
                        if let Some((start, end)) = closure_body(toks, k) {
                            named_pipes.insert(k);
                            out.push(Func {
                                name,
                                qual: None,
                                file_idx,
                                body: (start, end),
                            });
                        }
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    // Anonymous closures: `|` / `||` in argument or expression position
    // that a `let name =` didn't already claim.
    let mut i = 0;
    while i < toks.len() {
        let t = toks[i].text.as_str();
        if (t == "|" || t == "||") && !named_pipes.contains(&i) {
            let prev = if i == 0 { "" } else { toks[i - 1].text.as_str() };
            if matches!(prev, "(" | "," | "=" | "move" | "=>" | ";" | "{" | "}" | "return") {
                if let Some((start, end)) = closure_body(toks, i) {
                    out.push(Func {
                        name: "<closure>".to_string(),
                        qual: None,
                        file_idx,
                        body: (start, end),
                    });
                    i = end;
                    continue;
                }
            }
        }
        i += 1;
    }
}

/// Token index of the matching `}` for the `{` at `open`.
fn match_brace(toks: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    toks.len()
}

/// Body range of a closure whose params start at `pipe` (a `|` or `||`
/// token). Block bodies span the braces; expression bodies run to the
/// `,`/`)`/`;` that ends them at depth zero.
fn closure_body(toks: &[Token], pipe: usize) -> Option<(usize, usize)> {
    let mut j = pipe;
    if toks[j].text == "||" {
        j += 1;
    } else {
        j += 1;
        while j < toks.len() && toks[j].text != "|" {
            j += 1;
        }
        j += 1;
    }
    if j >= toks.len() {
        return None;
    }
    if toks[j].text == "{" {
        return Some((j + 1, match_brace(toks, j)));
    }
    let start = j;
    let mut paren = 0i32;
    let mut brace = 0i32;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "(" => paren += 1,
            ")" => {
                if paren == 0 {
                    return Some((start, j));
                }
                paren -= 1;
            }
            "{" => brace += 1,
            "}" => brace -= 1,
            "," if paren == 0 && brace == 0 => return Some((start, j)),
            ";" if paren == 0 && brace == 0 => return Some((start, j)),
            _ => {}
        }
        j += 1;
    }
    Some((start, toks.len()))
}

/// Scan one function body for acquisitions and calls with held sets.
fn scan_body(
    file: &SourceFile,
    fun: &Func,
    all: &[Func],
    c: &Contracts,
    diags: &mut Vec<Diagnostic>,
) -> Vec<Event> {
    let toks = &file.tokens;
    let (start, end) = fun.body;
    // Nested registered bodies (closures, nested fns) are scanned as
    // their own detached functions; skip them here.
    let nested: Vec<(usize, usize)> = all
        .iter()
        .filter(|f| f.file_idx == fun.file_idx && f.body.0 > start && f.body.1 <= end)
        .map(|f| f.body)
        .collect();

    let mut events = Vec::new();
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0i32;
    let mut i = start;
    'outer: while i < end {
        for &(ns, ne) in &nested {
            if i >= ns && i < ne {
                i = ne;
                continue 'outer;
            }
        }
        let t = &toks[i];
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
            }
            ";" => {
                guards.retain(|g| !(g.temp && depth <= g.depth));
            }
            _ => {
                // drop(name) releases a let-bound guard early.
                if t.text == "drop"
                    && i + 3 < end
                    && toks[i + 1].text == "("
                    && toks[i + 2].is_ident
                    && toks[i + 3].text == ")"
                {
                    let victim = toks[i + 2].text.clone();
                    guards.retain(|g| g.binding.as_deref() != Some(victim.as_str()));
                    i += 4;
                    continue;
                }
                let is_method = i > 0 && toks[i - 1].text == ".";
                let calls_paren = i + 1 < end && toks[i + 1].text == "(";
                if t.is_ident && calls_paren {
                    let zero_arg = i + 2 < end && toks[i + 2].text == ")";
                    if is_method && zero_arg && ACQUIRE_METHODS.contains(&t.text.as_str()) {
                        let path = receiver_path(toks, i - 1, start);
                        match resolve(&path, fun.qual.as_deref(), c) {
                            Some(lock) => {
                                events.push(Event {
                                    line: t.line,
                                    held: guards.iter().map(|g| g.lock.clone()).collect(),
                                    kind: EventKind::Acquire(lock.clone()),
                                });
                                let binding = find_binding(toks, i, start);
                                guards.push(Guard {
                                    lock,
                                    depth,
                                    temp: binding.is_none(),
                                    binding,
                                });
                            }
                            None => diags.push(Diagnostic::new(
                                &file.rel,
                                t.line,
                                "R5",
                                format!(
                                    "unresolved lock receiver `{}` — add it to [lockgraph.vars] or [lockgraph.types] in contracts.toml",
                                    path.join(".")
                                ),
                            )),
                        }
                        i += 3;
                        continue;
                    }
                    let is_macro = i + 1 < end && toks[i + 1].text == "!";
                    let skip = KEYWORDS.contains(&t.text.as_str())
                        || is_macro
                        || (is_method
                            && (c.lock_ignore_methods.iter().any(|m| m == &t.text)
                                || GUARD_CHAIN.contains(&t.text.as_str())));
                    if !skip {
                        events.push(Event {
                            line: t.line,
                            held: guards.iter().map(|g| g.lock.clone()).collect(),
                            kind: EventKind::Call(t.text.clone()),
                        });
                    }
                }
            }
        }
        i += 1;
    }
    events
}

/// Dotted receiver path ending at the `.` before the acquire method.
fn receiver_path(toks: &[Token], dot: usize, floor: usize) -> Vec<String> {
    let mut segs = Vec::new();
    let mut j = dot; // toks[j] == "."
    while j > floor {
        let prev = &toks[j - 1];
        let field_like =
            prev.is_ident || (!prev.text.is_empty() && prev.text.chars().all(|c| c.is_ascii_digit()));
        if field_like {
            segs.push(prev.text.clone());
            if j >= 2 && toks[j - 2].text == "." {
                j -= 2;
                continue;
            }
        }
        break;
    }
    segs.reverse();
    segs
}

/// Resolve a receiver path to a lock class. `self`-rooted paths use the
/// impl type map; free paths try each segment (last first) in the vars
/// map.
fn resolve(path: &[String], qual: Option<&str>, c: &Contracts) -> Option<String> {
    if path.first().map(String::as_str) == Some("self") {
        return qual.and_then(|q| c.lock_types.get(q).cloned());
    }
    for seg in path.iter().rev() {
        if let Some(l) = c.lock_vars.get(seg) {
            return Some(l.clone());
        }
    }
    None
}

/// `let`-bound guard name for the statement containing token `i`, if any.
fn find_binding(toks: &[Token], i: usize, floor: usize) -> Option<String> {
    let mut j = i;
    let mut let_at = None;
    while j > floor {
        j -= 1;
        match toks[j].text.as_str() {
            ";" | "{" | "}" => break,
            "let" => {
                let_at = Some(j);
                break;
            }
            _ => {}
        }
    }
    let let_at = let_at?;
    let mut name = None;
    let mut k = let_at + 1;
    while k < i && toks[k].text != "=" {
        if toks[k].is_ident
            && !matches!(toks[k].text.as_str(), "mut" | "ref" | "Ok" | "Some" | "Err")
        {
            name = Some(toks[k].text.clone());
        }
        k += 1;
    }
    name
}

fn dfs_back_to_root(
    node: &str,
    root: &str,
    adj: &BTreeMap<&str, BTreeSet<&str>>,
    path: &mut Vec<String>,
) -> Option<Vec<String>> {
    path.push(node.to_string());
    if let Some(nexts) = adj.get(node) {
        for &n in nexts {
            if n == root {
                let mut cyc = path.clone();
                cyc.push(root.to_string());
                path.pop();
                return Some(cyc);
            }
            if !path.iter().any(|p| p == n) {
                if let Some(cyc) = dfs_back_to_root(n, root, adj, path) {
                    path.pop();
                    return Some(cyc);
                }
            }
        }
    }
    path.pop();
    None
}

/// Elementary cycles in the lock-class digraph, deduplicated by node
/// set, each returned as [a, b, ..., a].
fn find_cycles(edges: &BTreeMap<(String, String), (String, usize)>) -> Vec<Vec<String>> {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        adj.entry(a.as_str()).or_default().insert(b.as_str());
    }
    let roots: Vec<&str> = adj.keys().copied().collect();
    let mut seen_sets: BTreeSet<Vec<String>> = BTreeSet::new();
    let mut cycles = Vec::new();
    for root in roots {
        let mut path = Vec::new();
        if let Some(cyc) = dfs_back_to_root(root, root, &adj, &mut path) {
            let mut key: Vec<String> = cyc[..cyc.len() - 1].to_vec();
            key.sort();
            if seen_sets.insert(key) {
                cycles.push(cyc);
            }
        }
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::SourceFile;

    fn analyze_src(src: &str) -> LockGraph {
        let f = SourceFile::from_text("coordinator/x.rs", src);
        analyze(&[f], &Contracts::test_default())
    }

    #[test]
    fn ordered_edges_no_cycle() {
        let g = analyze_src(
            "fn a(slot: S, metrics: M) {\n  let g = slot.lock();\n  metrics.lock();\n}\n",
        );
        assert!(g.diagnostics.is_empty(), "{:?}", g.diagnostics);
        assert!(g.edges.contains_key(&("in_flight".into(), "metrics".into())));
    }

    #[test]
    fn opposite_orders_cycle() {
        let g = analyze_src(
            "fn a(slot: S, metrics: M) { let g = slot.lock(); metrics.lock(); }\n\
             fn b(slot: S, metrics: M) { let g = metrics.lock(); slot.lock(); }\n",
        );
        assert!(g
            .diagnostics
            .iter()
            .any(|d| d.rule == "R5" && d.msg.contains("cycle")));
    }

    #[test]
    fn transitive_cycle_through_call() {
        let g = analyze_src(
            "fn a(slot: S) { let g = slot.lock(); touch(); }\n\
             fn touch(metrics: M) { metrics.lock(); }\n\
             fn b(metrics: M) { let g = metrics.lock(); grab(); }\n\
             fn grab(slot: S) { slot.lock(); }\n",
        );
        assert!(g
            .diagnostics
            .iter()
            .any(|d| d.rule == "R5" && d.msg.contains("cycle")));
    }

    #[test]
    fn drop_releases_guard() {
        let g = analyze_src(
            "fn a(slot: S, metrics: M) {\n  let g = slot.lock();\n  drop(g);\n  metrics.lock();\n}\n",
        );
        assert!(!g.edges.contains_key(&("in_flight".into(), "metrics".into())));
    }

    #[test]
    fn temp_guard_releases_at_statement_end() {
        let g = analyze_src(
            "fn a(metrics: M, slot: S) {\n  metrics.lock().count += 1;\n  slot.lock();\n}\n",
        );
        assert!(!g.edges.contains_key(&("metrics".into(), "in_flight".into())));
    }

    #[test]
    fn self_rooted_acquisition_uses_impl_map() {
        let g = analyze_src(
            "struct Metrics;\nimpl Metrics {\n  fn bump(&self) { self.inner.lock().x += 1; }\n}\n",
        );
        assert!(g.diagnostics.is_empty(), "{:?}", g.diagnostics);
    }

    #[test]
    fn unresolved_receiver_is_reported() {
        let g = analyze_src("fn a(mystery: S) { mystery.lock(); }\n");
        assert!(g
            .diagnostics
            .iter()
            .any(|d| d.msg.contains("unresolved lock receiver")));
    }

    #[test]
    fn detached_closures_do_not_inherit_guards() {
        let g = analyze_src(
            "fn a(slot: S, metrics: M) {\n  let g = slot.lock();\n  spawn(move || { metrics.lock(); });\n}\n",
        );
        assert!(!g.edges.contains_key(&("in_flight".into(), "metrics".into())));
    }

    #[test]
    fn closure_internal_edges_are_still_seen() {
        let g = analyze_src(
            "fn a(slot: S, metrics: M) {\n  spawn(move || {\n    let g = slot.lock();\n    metrics.lock();\n  });\n}\n",
        );
        assert!(g.edges.contains_key(&("in_flight".into(), "metrics".into())));
    }

    #[test]
    fn relock_is_a_self_cycle() {
        let g = analyze_src("fn a(slot: S) { let g = slot.lock(); slot.lock(); }\n");
        assert!(g
            .diagnostics
            .iter()
            .any(|d| d.rule == "R5" && d.msg.contains("cycle")));
    }

    #[test]
    fn let_closure_is_resolvable_as_callee() {
        let g = analyze_src(
            "fn a(metrics: M, h: H) {\n  let lock_handles = |x| h.lock();\n  let g = metrics.lock();\n  lock_handles(1);\n}\n",
        );
        assert!(g.edges.contains_key(&("metrics".into(), "handles".into())));
    }
}
