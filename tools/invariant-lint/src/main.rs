//! CLI: `invariant-lint [--contracts PATH] <paths...>`
//!
//! Lints every `.rs` file under each path against the contracts file
//! (default: the checked-in `contracts.toml` next to this tool), prints
//! `file:line: [R#] message` diagnostics, and exits nonzero when any
//! rule fires. One-command repro over the tree:
//!
//! ```text
//! cargo run -p invariant-lint -- rust/src
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut contracts_path =
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/contracts.toml"));
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--contracts" => match args.next() {
                Some(p) => contracts_path = PathBuf::from(p),
                None => {
                    eprintln!("--contracts requires a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                eprintln!("usage: invariant-lint [--contracts PATH] <paths...>");
                return ExitCode::SUCCESS;
            }
            _ => roots.push(PathBuf::from(arg)),
        }
    }
    if roots.is_empty() {
        eprintln!("usage: invariant-lint [--contracts PATH] <paths...>");
        return ExitCode::from(2);
    }

    let contracts = match invariant_lint::Contracts::load(&contracts_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("invariant-lint: {e}");
            return ExitCode::from(2);
        }
    };

    let mut total = 0usize;
    for root in &roots {
        match invariant_lint::lint_root(root, &contracts) {
            Ok(diags) => {
                for d in &diags {
                    println!("{d}");
                }
                total += diags.len();
            }
            Err(e) => {
                eprintln!("invariant-lint: {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }
    if total > 0 {
        eprintln!("invariant-lint: {total} violation(s)");
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
