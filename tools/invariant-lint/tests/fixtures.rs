//! Fixture suite: every rule fires on its known-bad snippet, stays
//! silent on the known-good mirror, and the tree itself lints clean
//! (the self-check CI runs as `cargo run -p invariant-lint -- rust/src`).

use std::path::{Path, PathBuf};
use std::process::Command;

use invariant_lint::{lint_root, Contracts, Diagnostic};

fn tool_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_contracts() -> Contracts {
    Contracts::load(&tool_dir().join("fixtures/contracts.toml")).expect("fixture contracts")
}

fn lint_fixtures(sub: &str) -> Vec<Diagnostic> {
    lint_root(&tool_dir().join("fixtures").join(sub), &fixture_contracts()).expect("lint")
}

fn has(diags: &[Diagnostic], file: &str, rule: &str) -> bool {
    diags.iter().any(|d| d.file == file && d.rule == rule)
}

#[test]
fn every_bad_fixture_is_flagged() {
    let diags = lint_fixtures("bad");
    assert!(has(&diags, "arch/no_safety.rs", "R1"), "{diags:?}");
    assert!(has(&diags, "cim/unsafe_here.rs", "R1"), "{diags:?}");
    assert!(has(&diags, "cim/fma.rs", "R2"), "{diags:?}");
    assert!(has(&diags, "grng/wallclock.rs", "R3"), "{diags:?}");
    assert!(has(&diags, "grng/hashmap_iter.rs", "R3"), "{diags:?}");
    assert!(has(&diags, "coordinator/relaxed.rs", "R4"), "{diags:?}");
    assert!(
        diags
            .iter()
            .any(|d| d.rule == "R5" && d.msg.contains("cycle")),
        "{diags:?}"
    );
}

#[test]
fn bad_unsafe_in_allowed_dir_flags_only_the_missing_safety() {
    let diags = lint_fixtures("bad");
    let arch: Vec<&Diagnostic> = diags
        .iter()
        .filter(|d| d.file == "arch/no_safety.rs")
        .collect();
    assert!(arch.iter().all(|d| d.msg.contains("SAFETY")), "{arch:?}");
    assert!(
        arch.iter().all(|d| !d.msg.contains("outside")),
        "arch is an allowed dir: {arch:?}"
    );
}

#[test]
fn good_fixtures_are_silent() {
    let diags = lint_fixtures("good");
    assert!(diags.is_empty(), "good fixtures must lint clean: {diags:?}");
}

#[test]
fn diagnostics_carry_file_and_line() {
    let diags = lint_fixtures("bad");
    for d in &diags {
        assert!(d.line > 0, "{d:?}");
        assert!(!d.file.is_empty(), "{d:?}");
    }
    // Deterministic ordering: sorted by (file, line, rule).
    let mut sorted = diags.clone();
    sorted.sort();
    assert_eq!(diags, sorted);
}

#[test]
fn binary_exits_nonzero_on_bad_zero_on_good() {
    let bin = env!("CARGO_BIN_EXE_invariant-lint");
    let contracts = tool_dir().join("fixtures/contracts.toml");
    let run = |sub: &str| {
        Command::new(bin)
            .arg("--contracts")
            .arg(&contracts)
            .arg(tool_dir().join("fixtures").join(sub))
            .output()
            .expect("spawn invariant-lint")
    };
    let bad = run("bad");
    assert!(!bad.status.success(), "bad fixtures must fail the lint");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains(":"), "diagnostics use file:line: {stdout}");
    let good = run("good");
    assert!(good.status.success(), "good fixtures must pass the lint");
}

#[test]
fn self_check_the_tree_lints_clean() {
    // The merged tree must satisfy its own contracts: this is the same
    // invocation CI runs (`cargo run -p invariant-lint -- rust/src`).
    let repo_src = tool_dir().join("../../rust/src");
    assert!(repo_src.is_dir(), "expected rust/src at {repo_src:?}");
    let contracts = Contracts::load(&tool_dir().join("contracts.toml")).expect("contracts");
    let diags = lint_root(&repo_src, &contracts).expect("lint rust/src");
    assert!(
        diags.is_empty(),
        "rust/src must lint clean; violations:\n{}",
        diags
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn lock_graph_sees_the_coordinator() {
    // Guard against the scanner silently going blind: the real tree
    // must yield a non-empty acquisition graph with the known classes.
    let repo_src = tool_dir().join("../../rust/src");
    let contracts = Contracts::load(&tool_dir().join("contracts.toml")).expect("contracts");
    let mut sources = Vec::new();
    for (abs, rel) in invariant_lint::scan::rs_files(&repo_src).expect("walk") {
        sources.push(invariant_lint::scan::SourceFile::load(&abs, &rel).expect("read"));
    }
    let graph = invariant_lint::lockgraph::analyze(&sources, &contracts);
    assert!(
        graph.diagnostics.is_empty(),
        "{:?}",
        graph.diagnostics
    );
    let classes: std::collections::BTreeSet<&str> = graph
        .edges
        .keys()
        .flat_map(|(a, b)| [a.as_str(), b.as_str()])
        .collect();
    assert!(
        classes.contains("metrics"),
        "expected the in_flight->metrics edge from the dispatch hot path; got {classes:?} ({:?})",
        graph.edges.keys().collect::<Vec<_>>()
    );
}
