#!/usr/bin/env python3
"""Reference mirror of invariant-lint (rules R1-R5) for toolchain-less
containers.

The authoring environment for this repo historically has no Rust
toolchain (see ROADMAP.md), so this script re-implements the linter's
exact token-level semantics in Python. It exists to validate contract
changes and annotation sweeps locally before CI runs the real binary;
the Rust implementation in ../src is authoritative. Keep the two in
sync when changing rule semantics.

Usage: python3 tools/invariant-lint/dev/mirror.py [--contracts PATH]
       [--edges] <paths...>
"""

import sys
from pathlib import Path


def parse_toml(text):
    """TOML subset matching src/toml_lite.rs (sections, strings, ints,
    bools, single-line string arrays). No tomllib: the authoring
    containers may run Python < 3.11."""
    doc = {}
    section = []
    for raw in text.splitlines():
        # Strip comments outside strings.
        out, in_str = [], False
        for ch in raw:
            if ch == '"':
                in_str = not in_str
            if ch == "#" and not in_str:
                break
            out.append(ch)
        line = "".join(out).strip()
        if not line:
            continue
        if line.startswith("["):
            section = line.strip("[]").strip().split(".")
            continue
        key, _, val = line.partition("=")
        key = key.strip().strip('"')
        val = val.strip()
        if val.startswith("["):
            items = []
            body = val.strip("[]")
            cur, in_str = [], False
            parts = []
            for ch in body:
                if ch == '"':
                    in_str = not in_str
                if ch == "," and not in_str:
                    parts.append("".join(cur))
                    cur = []
                else:
                    cur.append(ch)
            parts.append("".join(cur))
            for p in parts:
                p = p.strip()
                if p:
                    items.append(p.strip('"'))
            parsed = items
        elif val.startswith('"'):
            parsed = val.strip('"')
        elif val in ("true", "false"):
            parsed = val == "true"
        else:
            parsed = int(val)
        node = doc
        for s in section:
            node = node.setdefault(s, {})
        node[key] = parsed
    return doc

MULTI = ["::", "=>", "->", "||", "&&", "..=", ".."]
ACQUIRE = {"lock", "read", "write"}
GUARD_CHAIN = {"unwrap", "expect", "unwrap_or_else"}
KEYWORDS = {"if", "while", "for", "match", "return", "loop", "fn", "let", "move", "in"}


def strip(text: str) -> str:
    b = text
    out = []
    i, n = 0, len(b)
    while i < n:
        c = b[i]
        if c == "/" and i + 1 < n and b[i + 1] == "/":
            while i < n and b[i] != "\n":
                out.append(" ")
                i += 1
            continue
        if c == "/" and i + 1 < n and b[i + 1] == "*":
            depth = 1
            out.append("  ")
            i += 2
            while i < n and depth:
                if b[i] == "/" and i + 1 < n and b[i + 1] == "*":
                    depth += 1
                    out.append("  ")
                    i += 2
                elif b[i] == "*" and i + 1 < n and b[i + 1] == "/":
                    depth -= 1
                    out.append("  ")
                    i += 2
                else:
                    out.append("\n" if b[i] == "\n" else " ")
                    i += 1
            continue
        if c == "r" or (c == "b" and i + 1 < n and b[i + 1] == "r"):
            start = i + 1 if c == "b" else i
            j = start + 1
            while j < n and b[j] == "#":
                j += 1
            prev_ident = i > 0 and (b[i - 1].isalnum() or b[i - 1] == "_")
            if j < n and b[j] == '"' and not prev_ident:
                hashes = j - (start + 1)
                out.append(" " * (j - i + 1))
                i = j + 1
                close = '"' + "#" * hashes
                while i < n:
                    if b[i] == '"' and b[i : i + len(close)] == close:
                        out.append(" " * len(close))
                        i += len(close)
                        break
                    out.append("\n" if b[i] == "\n" else " ")
                    i += 1
                continue
        if c == '"':
            out.append('"')
            i += 1
            while i < n:
                if b[i] == "\\" and i + 1 < n:
                    out.append("  ")
                    i += 2
                    continue
                if b[i] == '"':
                    out.append('"')
                    i += 1
                    break
                out.append("\n" if b[i] == "\n" else " ")
                i += 1
            continue
        if c == "'":
            end = char_literal_end(b, i)
            if end is not None:
                out.append(" " * (end - i))
                i = end
                continue
        out.append(c)
        i += 1
    return "".join(out)


def char_literal_end(b, i):
    n = len(b)
    if i + 1 >= n:
        return None
    if b[i + 1] == "\\":
        j = i + 2
        while j < n and b[j] not in ("'", "\n"):
            j += 1
        return j + 1 if j < n and b[j] == "'" else None
    # Exactly one char then a closing quote (mirror counts UTF-8 bytes;
    # Python strings are chars, which matches one codepoint per char).
    close = i + 2
    if close < n and b[close] == "'" and b[i + 1] != "\n":
        return close + 1
    return None


def tokenize(s):
    toks = []  # (line, text, is_ident)
    line, i, n = 1, 0, len(s)
    while i < n:
        c = s[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "_" or c.isalpha():
            j = i
            while j < n and (s[j] == "_" or s[j].isalnum()):
                j += 1
            toks.append((line, s[i:j], True))
            i = j
            continue
        if c.isdigit():
            j = i
            while j < n and (s[j] == "_" or s[j].isalnum()):
                j += 1
            if j + 1 < n and s[j] == "." and s[j + 1].isdigit():
                j += 1
                while j < n and (s[j] == "_" or s[j].isalnum()):
                    j += 1
            toks.append((line, s[i:j], False))
            i = j
            continue
        matched = False
        for pat in MULTI:
            if s.startswith(pat, i):
                toks.append((line, pat, False))
                i += len(pat)
                matched = True
                break
        if matched:
            continue
        toks.append((line, c, False))
        i += 1
    return toks


def test_ranges(toks):
    ranges = []
    i = 0
    while i + 6 < len(toks):
        if [t[1] for t in toks[i : i + 7]] == ["#", "[", "cfg", "(", "test", ")", "]"]:
            j = i + 7
            while j < len(toks) and toks[j][1] not in ("mod", "{", ";"):
                j += 1
            if j < len(toks) and toks[j][1] == "mod":
                while j < len(toks) and toks[j][1] != "{":
                    j += 1
                if j < len(toks):
                    start_line = toks[i][0]
                    depth = 0
                    while j < len(toks):
                        if toks[j][1] == "{":
                            depth += 1
                        elif toks[j][1] == "}":
                            depth -= 1
                            if depth == 0:
                                ranges.append((start_line, toks[j][0]))
                                break
                        j += 1
            i = max(j, i + 1)
        else:
            i += 1
    return ranges


class Src:
    def __init__(self, rel, text):
        self.rel = rel
        self.raw = text.splitlines()
        self.toks = tokenize(strip(text))
        self.tests = test_ranges(self.toks)

    def in_test(self, line):
        return any(lo <= line <= hi for lo, hi in self.tests)

    def window(self, line, above, needles):
        lo = max(0, line - above - 1)
        return any(any(nd in l for nd in needles) for l in self.raw[lo:line])


def under(rel, dirs):
    for d in dirs:
        d = d.rstrip("/")
        if rel == d or rel.startswith(d + "/"):
            return True
    return False


def rules_r1_r4(f, c, out):
    for line, text, is_ident in f.toks:
        if text == "unsafe":
            if not under(f.rel, c["rules"]["unsafe"]["allowed_dirs"]):
                out.append((f.rel, line, "R1", "unsafe outside allowed dirs"))
            if not f.window(line, 10, ["SAFETY:", "# Safety"]):
                out.append((f.rel, line, "R1", "unsafe without SAFETY"))
    if under(f.rel, c["rules"]["fma"]["deny_dirs"]):
        for line, text, is_ident in f.toks:
            if is_ident and text in c["rules"]["fma"]["tokens"]:
                out.append((f.rel, line, "R2", f"fused-op token {text}"))
    if under(f.rel, c["rules"]["replay"]["pinned"]):
        for line, text, is_ident in f.toks:
            if is_ident and not f.in_test(line) and text in c["rules"]["replay"]["banned"]:
                out.append((f.rel, line, "R3", f"banned ident {text}"))
    if f.rel not in c["rules"]["relaxed"]["allow"]:
        t = f.toks
        for i in range(len(t) - 2):
            if t[i][1] == "Ordering" and t[i + 1][1] == "::" and t[i + 2][1] == "Relaxed":
                if not f.window(t[i][0], 3, ["RELAXED:"]):
                    out.append((f.rel, t[i][0], "R4", "Relaxed without RELAXED:"))


def match_brace(toks, open_i):
    depth = 0
    j = open_i
    while j < len(toks):
        if toks[j][1] == "{":
            depth += 1
        elif toks[j][1] == "}":
            depth -= 1
            if depth == 0:
                return j
        j += 1
    return len(toks)


def closure_body(toks, pipe):
    j = pipe
    if toks[j][1] == "||":
        j += 1
    else:
        j += 1
        while j < len(toks) and toks[j][1] != "|":
            j += 1
        j += 1
    if j >= len(toks):
        return None
    if toks[j][1] == "{":
        return (j + 1, match_brace(toks, j))
    start = j
    paren = brace = 0
    while j < len(toks):
        t = toks[j][1]
        if t == "(":
            paren += 1
        elif t == ")":
            if paren == 0:
                return (start, j)
            paren -= 1
        elif t == "{":
            brace += 1
        elif t == "}":
            brace -= 1
        elif t in (",", ";") and paren == 0 and brace == 0:
            return (start, j)
        j += 1
    return (start, len(toks))


def collect_funcs(f, file_idx, out):
    toks = f.toks
    depth = 0
    impls = []
    named_pipes = set()
    i = 0
    while i < len(toks):
        t = toks[i][1]
        if t == "{":
            depth += 1
        elif t == "}":
            depth -= 1
            while impls and depth < impls[-1][1]:
                impls.pop()
        elif t == "impl":
            j = i + 1
            angle = 0
            last_ident = None
            while j < len(toks) and not (angle == 0 and toks[j][1] == "{"):
                tj = toks[j][1]
                if tj == "<":
                    angle += 1
                elif tj == ">":
                    angle -= 1
                elif toks[j][2] and angle == 0 and tj != "for":
                    last_ident = tj
                j += 1
            if last_ident:
                impls.append((last_ident, depth + 1))
        elif t == "fn" and i + 1 < len(toks) and toks[i + 1][2]:
            name = toks[i + 1][1]
            j = i + 2
            paren = 0
            while j < len(toks):
                tj = toks[j][1]
                if tj == "(":
                    paren += 1
                elif tj == ")":
                    paren -= 1
                elif tj in (";", "{") and paren == 0:
                    break
                j += 1
            if j < len(toks) and toks[j][1] == "{":
                out.append(
                    dict(name=name, qual=impls[-1][0] if impls else None,
                         file=file_idx, body=(j + 1, match_brace(toks, j)))
                )
        elif t == "let":
            j = i + 1
            if j < len(toks) and toks[j][1] == "mut":
                j += 1
            if j + 1 < len(toks) and toks[j][2] and toks[j + 1][1] == "=":
                name = toks[j][1]
                k = j + 2
                if k < len(toks) and toks[k][1] == "move":
                    k += 1
                if k < len(toks) and toks[k][1] in ("|", "||"):
                    body = closure_body(toks, k)
                    if body:
                        named_pipes.add(k)
                        out.append(dict(name=name, qual=None, file=file_idx, body=body))
        i += 1
    i = 0
    while i < len(toks):
        t = toks[i][1]
        if t in ("|", "||") and i not in named_pipes:
            prev = toks[i - 1][1] if i else ""
            if prev in ("(", ",", "=", "move", "=>", ";", "{", "}", "return"):
                body = closure_body(toks, i)
                if body:
                    out.append(dict(name="<closure>", qual=None, file=file_idx, body=body))
                    i = body[1]
                    continue
        i += 1


def receiver_path(toks, dot, floor):
    segs = []
    j = dot
    while j > floor:
        line, text, is_ident = toks[j - 1]
        if is_ident or (text and text.isdigit()):
            segs.append(text)
            if j >= 2 and toks[j - 2][1] == ".":
                j -= 2
                continue
        break
    segs.reverse()
    return segs


def resolve(path, qual, lg):
    if path and path[0] == "self":
        return lg.get("types", {}).get(qual) if qual else None
    for seg in reversed(path):
        if seg in lg.get("vars", {}):
            return lg["vars"][seg]
    return None


def find_binding(toks, i, floor):
    j = i
    let_at = None
    while j > floor:
        j -= 1
        t = toks[j][1]
        if t in (";", "{", "}"):
            break
        if t == "let":
            let_at = j
            break
    if let_at is None:
        return None
    name = None
    k = let_at + 1
    while k < i and toks[k][1] != "=":
        if toks[k][2] and toks[k][1] not in ("mut", "ref", "Ok", "Some", "Err"):
            name = toks[k][1]
        k += 1
    return name


def scan_body(f, fun, allf, lg, diags):
    toks = f.toks
    start, end = fun["body"]
    nested = [g["body"] for g in allf
              if g["file"] == fun["file"] and g["body"][0] > start and g["body"][1] <= end]
    events = []
    guards = []  # dict(lock, depth, binding, temp)
    depth = 0
    ignore = set(lg.get("ignore_methods", []))
    i = start
    while i < end:
        skipped = False
        for ns, ne in nested:
            if ns <= i < ne:
                i = ne
                skipped = True
                break
        if skipped:
            continue
        line, text, is_ident = toks[i]
        if text == "{":
            depth += 1
        elif text == "}":
            depth -= 1
            guards = [g for g in guards if g["depth"] <= depth]
        elif text == ";":
            guards = [g for g in guards if not (g["temp"] and depth <= g["depth"])]
        else:
            if (
                text == "drop"
                and i + 3 < end
                and toks[i + 1][1] == "("
                and toks[i + 2][2]
                and toks[i + 3][1] == ")"
            ):
                victim = toks[i + 2][1]
                guards = [g for g in guards if g["binding"] != victim]
                i += 4
                continue
            is_method = i > 0 and toks[i - 1][1] == "."
            calls_paren = i + 1 < end and toks[i + 1][1] == "("
            if is_ident and calls_paren:
                zero_arg = i + 2 < end and toks[i + 2][1] == ")"
                if is_method and zero_arg and text in ACQUIRE:
                    path = receiver_path(toks, i - 1, start)
                    lock = resolve(path, fun["qual"], lg)
                    if lock:
                        events.append((line, [g["lock"] for g in guards], ("acq", lock)))
                        binding = find_binding(toks, i, start)
                        guards.append(
                            dict(lock=lock, depth=depth, binding=binding, temp=binding is None)
                        )
                    else:
                        diags.append(
                            (f.rel, line, "R5", f"unresolved lock receiver {'.'.join(path)}")
                        )
                    i += 3
                    continue
                is_macro = i + 1 < end and toks[i + 1][1] == "!"
                skip = (
                    text in KEYWORDS
                    or is_macro
                    or (is_method and (text in ignore or text in GUARD_CHAIN))
                )
                if not skip:
                    events.append((line, [g["lock"] for g in guards], ("call", text)))
        i += 1
    return events


def lockgraph(files, c, diags):
    lg = c.get("lockgraph", {})
    scan_dirs = lg.get("scan", [])
    funcs = []
    for idx, f in enumerate(files):
        if under(f.rel, scan_dirs):
            collect_funcs(f, idx, funcs)
    events = [scan_body(files[fn["file"]], fn, funcs, lg, diags) for fn in funcs]
    by_name = {}
    for i, fn in enumerate(funcs):
        if fn["name"] != "<closure>":
            by_name.setdefault(fn["name"], []).append(i)
    acq = [set(l for _, _, (k, l) in evs if k == "acq") for evs in events]
    changed = True
    while changed:
        changed = False
        for i, evs in enumerate(events):
            for _, _, (k, name) in evs:
                if k == "call":
                    for t in by_name.get(name, []):
                        if t != i and not acq[t] <= acq[i]:
                            acq[i] |= acq[t]
                            changed = True
    edges = {}
    for i, evs in enumerate(events):
        f = files[funcs[i]["file"]]
        for line, held, (k, name) in evs:
            if not held:
                continue
            acquired = [name] if k == "acq" else sorted(
                set().union(*[acq[t] for t in by_name.get(name, []) if t != i] or [set()])
            )
            for h in held:
                for a in acquired:
                    edges.setdefault((h, a), (f.rel, line))
    # Cycle detection.
    adj = {}
    for a, b in edges:
        adj.setdefault(a, set()).add(b)
    seen_sets = set()
    cycles = []
    for root in sorted(adj):
        path = []

        def dfs(node):
            path.append(node)
            for nxt in sorted(adj.get(node, ())):
                if nxt == root:
                    return path + [root]
                if nxt not in path:
                    got = dfs(nxt)
                    if got:
                        return got
            path.pop()
            return None

        cyc = dfs(root)
        if cyc:
            key = tuple(sorted(cyc[:-1]))
            if key not in seen_sets:
                seen_sets.add(key)
                cycles.append(cyc)
    for cyc in cycles:
        f, l = edges.get((cyc[0], cyc[1]), ("", 0))
        diags.append((f, l, "R5", "lock-order cycle: " + " -> ".join(cyc)))
    return edges


def main():
    args = sys.argv[1:]
    contracts_path = None
    show_edges = False
    roots = []
    i = 0
    while i < len(args):
        if args[i] == "--contracts":
            contracts_path = Path(args[i + 1])
            i += 2
        elif args[i] == "--edges":
            show_edges = True
            i += 1
        else:
            roots.append(Path(args[i]))
            i += 1
    if contracts_path is None:
        contracts_path = Path(__file__).resolve().parent.parent / "contracts.toml"
    c = parse_toml(contracts_path.read_text())
    total = 0
    for root in roots:
        files = []
        if root.is_file():
            files.append(Src(root.name, root.read_text()))
        else:
            for p in sorted(root.rglob("*.rs")):
                files.append(Src(str(p.relative_to(root)), p.read_text()))
        diags = []
        for f in files:
            rules_r1_r4(f, c, diags)
        edges = lockgraph(files, c, diags)
        diags.sort()
        for d in sorted(set(diags)):
            print("%s:%d: [%s] %s" % d)
        total += len(diags)
        if show_edges:
            for (a, b), (fr, lr) in sorted(edges.items()):
                print(f"# edge {a} -> {b}  ({fr}:{lr})", file=sys.stderr)
    sys.exit(1 if total else 0)


if __name__ == "__main__":
    main()
