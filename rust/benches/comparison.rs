//! Bench: Tab. II — comparison to other work, including software
//! microbenchmarks of the re-implemented baseline GRNG algorithms.

use bnn_cim::config::ChipConfig;
use bnn_cim::experiments::tab2;
use bnn_cim::grng::baselines::all_sources;
use bnn_cim::util::bench::{black_box, Suite};

fn main() {
    let mut suite = Suite::new("comparison (Tab. II)");
    suite.header();

    // Software throughput of each baseline algorithm (context column).
    for mut src in all_sources(0xC0FFEE) {
        let name = src.name();
        suite.bench_throughput(&format!("sw {name}"), 1.0, || {
            black_box(src.sample());
        });
    }
    // Our in-word GRNG (fast path) for the same comparison.
    let chip = ChipConfig::default();
    let mut cell = bnn_cim::grng::GrngCell::ideal(&chip.grng, 5);
    suite.bench_throughput("sw in-word grng (sim fast path)", 1.0, || {
        black_box(cell.eps_fast());
    });

    let (rows, m) = tab2::comparison_table(&chip, 0);
    println!("\n{}", tab2::render(&rows, &m));
    suite.note("tab2.rng_tput_gsa_s (paper 5.12)", format!("{:.2}", m.rng_tput_gsa_s));
    suite.note(
        "tab2.rng_eff_pj_per_sa (paper 0.36)",
        format!("{:.3}", m.rng_eff_pj_per_sa),
    );
    suite.note("tab2.nn_tput_gops (paper 102)", format!("{:.1}", m.nn_tput_gops));
    suite.note(
        "tab2.nn_eff_fj_per_op (paper 672)",
        format!("{:.0}", m.nn_eff_fj_per_op),
    );
    suite.note("tab2.area_mm2 (paper 0.45)", format!("{:.3}", m.area_mm2));
    suite.note(
        "tab2.norm_rng_tput (paper 11.4 GSa/s/mm2)",
        format!("{:.1}", m.rng_tput_norm_gsa_s_mm2),
    );
    suite.finish();
}
