//! Bench: Fig. 10 & 11 — uncertainty quality of the three inference arms
//! plus the σ-precision and deferral-threshold sweeps.

use bnn_cim::config::ChipConfig;
use bnn_cim::experiments::{fig10_11::Arm, run_uncertainty, sigma_bit_sweep};
use bnn_cim::nn::Model;
use bnn_cim::util::bench::Suite;
use std::path::Path;

fn main() {
    let mut suite = Suite::new("uncertainty (Fig. 10, Fig. 11)");
    suite.header();
    let weights = Path::new("artifacts/weights.json");
    if !weights.exists() {
        suite.note("status", "skipped (run `make artifacts`)".into());
        suite.finish();
        return;
    }
    let chip = ChipConfig::default();
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_id, n_ood, mc) = if quick { (80, 32, 8) } else { (200, 80, 16) };

    let mut reports = Vec::new();
    for arm in [Arm::DetNn, Arm::BnnFloat, Arm::BnnHw] {
        let mut model = Model::load(weights).unwrap();
        let t = if arm == Arm::DetNn { 1 } else { mc };
        let t0 = std::time::Instant::now();
        let rep = run_uncertainty(&mut model, &chip, arm, n_id, n_ood, t, 5);
        suite.note(
            &format!("{arm:?} ({:.1?})", t0.elapsed()),
            rep.render(),
        );
        reports.push(rep);
    }
    let det = &reports[0];
    let bnn = &reports[1];
    let hw = &reports[2];
    suite.note(
        "fig10.ape_incorrect det→bnn (paper 0.350→0.513, +46.6%)",
        format!(
            "{:.3} → {:.3} ({:+.1}%)",
            det.ape_incorrect,
            bnn.ape_incorrect,
            (bnn.ape_incorrect / det.ape_incorrect - 1.0) * 100.0
        ),
    );
    suite.note(
        "fig10.ece det→bnn (paper 4.88→3.31, −32.2%)",
        format!(
            "{:.2}% → {:.2}% ({:+.1}%)",
            det.ece_percent,
            bnn.ece_percent,
            (bnn.ece_percent / det.ece_percent - 1.0) * 100.0
        ),
    );
    suite.note(
        "fig11.recovery_gain bnn-hw (paper +3.5%)",
        format!("{:+.2}%", hw.mean_recovery_gain() * 100.0),
    );

    // Fig. 11-left: σ precision sweep on the hardware arm.
    let sweep = sigma_bit_sweep(weights, &chip, &[2, 3, 4], n_id / 2, mc / 2, 9);
    for (bits, rep) in &sweep {
        suite.note(
            &format!("fig11.sigma_{bits}bit"),
            format!("acc {:.3} ECE {:.2}%", rep.accuracy, rep.ece_percent),
        );
    }
    suite.finish();
}
