//! Bench: NN throughput — the paper's 102 GOp/s headline is a *hardware*
//! rate (64×8×2 ops per 10 ns cycle); here we report that model number
//! alongside measured wallclock of every inference path in the stack:
//! rust-native layers, CIM-sim head, and the PJRT artifacts the
//! coordinator actually serves.

use bnn_cim::config::ChipConfig;
#[cfg(feature = "pjrt")]
use bnn_cim::config::Config;
use bnn_cim::data::SyntheticPerson;
use bnn_cim::nn::Model;
#[cfg(feature = "pjrt")]
use bnn_cim::runtime::Engine;
#[cfg(feature = "pjrt")]
use bnn_cim::util::bench::fmt_si;
use bnn_cim::util::bench::{black_box, Suite};
#[cfg(feature = "pjrt")]
use std::path::Path;

fn main() {
    let mut suite = Suite::new("nn_throughput");
    suite.header();
    let chip = ChipConfig::default();
    let hw_gops = chip.tile.ops_per_mvm() as f64 * chip.tile.clock_hz / 1e9;
    suite.note("hardware model NN tput (paper 102 GOp/s)", format!("{hw_gops:.1} GOp/s"));

    let gen = SyntheticPerson::new(32, 5);
    let img = gen.sample(1).pixels;

    // Rust-native reference path.
    let mut model = Model::random(32, 2, 7);
    let feats = model.forward_features(&img);
    suite.bench("features fwd (rust-native)", || {
        black_box(model.forward_features(&img));
    });
    suite.bench("bayes head MC sample (float ref)", || {
        black_box(model.head_sample_ref(&feats));
    });
    model.map_head_to_hardware(&chip);
    suite.bench("bayes head MC sample (CIM sim)", || {
        black_box(model.head_sample_hw(&feats));
    });

    // PJRT artifact path (what the coordinator serves).
    #[cfg(feature = "pjrt")]
    if Path::new("artifacts/manifest.json").exists() {
        let mut engine = Engine::load(Path::new("artifacts")).unwrap();
        let m = engine.manifest().clone();
        let fspec = m.entry("features").unwrap().clone();
        let hspec = m.entry("head").unwrap().clone();
        let b = m.batch;
        let images = vec![0.5f32; b * m.side * m.side];
        let feats = engine
            .run("features", &[(&images, &fspec.inputs[0].1)])
            .unwrap();
        let eps1 = vec![0.1f32; hspec.input_len(1)];
        let eps2 = vec![0.1f32; hspec.input_len(2)];
        let r = suite
            .bench_throughput("pjrt features (batch 8)", b as f64, || {
                black_box(
                    engine
                        .run("features", &[(&images, &fspec.inputs[0].1)])
                        .unwrap(),
                );
            })
            .clone();
        suite.note(
            "pjrt features imgs/s",
            fmt_si(r.throughput_per_sec().unwrap_or(0.0)),
        );
        suite.bench_throughput("pjrt head MC pass (batch 8)", b as f64, || {
            black_box(
                engine
                    .run(
                        "head",
                        &[
                            (&feats, &hspec.inputs[0].1),
                            (&eps1, &hspec.inputs[1].1),
                            (&eps2, &hspec.inputs[2].1),
                        ],
                    )
                    .unwrap(),
            );
        });
        // End-to-end serving throughput via the client API v1 surface.
        use bnn_cim::client::{Backend, Coordinator, Infer};
        let mut cfg = Config::default();
        cfg.model.mc_samples = 8;
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Pjrt)
            .start()
            .unwrap();
        let opts = suite.opts();
        let _ = opts;
        let t0 = std::time::Instant::now();
        let n_req = 48;
        let tickets = coord
            .submit_many((0..n_req).map(|i| Infer::new(gen.sample(i).pixels)))
            .unwrap();
        for ticket in tickets {
            let _ = ticket.wait();
        }
        let dt = t0.elapsed();
        suite.note(
            "coordinator e2e (T=8, batch≤8)",
            format!(
                "{n_req} req in {dt:.2?} → {:.1} req/s",
                n_req as f64 / dt.as_secs_f64()
            ),
        );
        let snap = coord.metrics();
        suite.note(
            "coordinator batches",
            format!("{} (fill {:.2})", snap.batches, snap.mean_batch_fill),
        );
        coord.shutdown();
    } else {
        suite.note("pjrt", "skipped (artifacts not built)".into());
    }
    #[cfg(not(feature = "pjrt"))]
    suite.note(
        "pjrt",
        "skipped (built without the `pjrt` feature — see benches/sharded_serving.rs)".into(),
    );
    suite.finish();
}
