//! Bench: Fig. 12 — tile energy & area breakdown for one complete MVM.

use bnn_cim::config::ChipConfig;
use bnn_cim::energy::Component;
use bnn_cim::experiments::run_breakdown;
use bnn_cim::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("breakdown (Fig. 12)");
    suite.header();
    let chip = ChipConfig::default();
    let rep = run_breakdown(&chip, 3);
    println!("{}", rep.render());
    suite.note(
        "fig12.sram_energy_share (paper >0.63)",
        format!("{:.3}", rep.sram_energy_share()),
    );
    suite.note(
        "fig12.sram_area_share (paper ~0.48)",
        format!("{:.3}", rep.sram_area_share()),
    );
    suite.note(
        "fig12.grng_energy_share",
        format!(
            "{:.3}",
            rep.energy.component_j(Component::Grng) / rep.mvm_energy_j
        ),
    );
    suite.note("fig12.mvm_energy_pj", format!("{:.2}", rep.mvm_energy_j * 1e12));
    suite.note(
        "fig12.nn_eff_fj_per_op (paper 672)",
        format!("{:.0}", rep.fj_per_op),
    );
    suite.note("fig12.tile_area_mm2", format!("{:.4}", rep.area.tile_mm2));
    suite.note(
        "fig12.chip_area_mm2 (paper 0.45)",
        format!("{:.3}", rep.area.chip_mm2),
    );
    suite.finish();
}
