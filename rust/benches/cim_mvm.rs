//! Bench: MVM hot path — pre-PR AoS baseline (`CimTile::mvm_legacy`) vs
//! the bit-plane SoA fast path (`CimTile::mvm`) vs the MC-batched fast
//! path (`CimTile::mvm_batch` / `TileArray::mvm_batch`), on the default
//! 64×8 chip tile. Writes the calibrated `BENCH_cim_mvm.json` at the
//! repo root (the smoke-scale seed comes from `tests/mvm_props.rs`), so
//! the MVM perf trajectory across PRs is machine-readable.
//!
//! The two paths are bit-identical (pinned by tests/mvm_props.rs); this
//! bench measures only the wallclock consequences of the layout change:
//! contiguous branch-free multiply-accumulates, reusable scratch buffers,
//! and batch-amortized IDAC drives / plane builds / ledger deposits.
//! The SIMD cases (ISSUE 6) A/B the runtime-dispatched vector arm against
//! the forced-scalar oracle on the same tile — also bit-identical, so the
//! delta is pure kernel throughput.

use bnn_cim::arch::{detected_level, lane_dot_at, ForcedLevelGuard, SimdLevel};
use bnn_cim::cim::{calibrate, CimTile, MvmOptions, TileArray};
use bnn_cim::config::ChipConfig;
use bnn_cim::util::bench::{
    black_box, repo_root_artifact, write_mvm_report, MvmBenchCase, Suite,
};
use bnn_cim::util::rng::{Pcg64, Rng64};

fn main() {
    let mut suite = Suite::new("cim_mvm (AoS legacy vs SoA fast path vs MC batch)");
    suite.header();
    let chip = ChipConfig::default();
    let ops = chip.tile.ops_per_mvm() as f64;
    let mut tile = CimTile::new(&chip);
    calibrate(&mut tile, 16, 32).unwrap();
    let mut rng = Pcg64::new(3);
    let n = chip.tile.rows * chip.tile.words_per_row;
    let mu: Vec<f64> = (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) * 200.0).collect();
    let sg: Vec<f64> = (0..n).map(|_| rng.next_f64() * 12.0).collect();
    tile.program_matrix(&mu, &sg);
    let x: Vec<u8> = (0..chip.tile.rows).map(|_| rng.next_below(16) as u8).collect();

    let fresh = MvmOptions::default();
    let held = MvmOptions {
        refresh_epsilon: false,
        ..MvmOptions::default()
    };
    let batch = 32usize;

    let legacy_fresh = suite
        .bench_throughput("legacy AoS mvm (fresh ε)", ops, || {
            black_box(tile.mvm_legacy(&x, fresh));
        })
        .ns_per_iter;
    let soa_fresh = suite
        .bench_throughput("SoA mvm (fresh ε)", ops, || {
            black_box(tile.mvm(&x, fresh));
        })
        .ns_per_iter;
    let batch_fresh = suite
        .bench_throughput("SoA mvm_batch/32 (fresh ε)", ops * batch as f64, || {
            black_box(tile.mvm_batch(&x, batch, fresh));
        })
        .ns_per_iter
        / batch as f64;
    let legacy_held = suite
        .bench_throughput("legacy AoS mvm (held ε)", ops, || {
            black_box(tile.mvm_legacy(&x, held));
        })
        .ns_per_iter;
    let soa_held = suite
        .bench_throughput("SoA mvm (held ε)", ops, || {
            black_box(tile.mvm(&x, held));
        })
        .ns_per_iter;
    let batch_held = suite
        .bench_throughput("SoA mvm_batch/32 (held ε)", ops * batch as f64, || {
            black_box(tile.mvm_batch(&x, batch, held));
        })
        .ns_per_iter
        / batch as f64;

    // SIMD arm vs forced-scalar arm on the identical SoA path (held ε
    // isolates the lane_dot/mul_into kernels), end-to-end and at the raw
    // lane_dot kernel over one 64-row plane.
    let soa_held_scalar = {
        let _scalar = ForcedLevelGuard::new(SimdLevel::Scalar);
        suite
            .bench_throughput("SoA mvm (held ε, forced scalar)", ops, || {
                black_box(tile.mvm(&x, held));
            })
            .ns_per_iter
    };
    let soa_held_simd = {
        let _vector = ForcedLevelGuard::new(detected_level());
        suite
            .bench_throughput("SoA mvm (held ε, SIMD)", ops, || {
                black_box(tile.mvm(&x, held));
            })
            .ns_per_iter
    };
    let rows = chip.tile.rows;
    let ka: Vec<f64> = (0..rows).map(|_| rng.next_f64() - 0.5).collect();
    let kb: Vec<f64> = (0..rows).map(|_| rng.next_f64() - 0.5).collect();
    let lane_dot_scalar_ns = suite
        .bench_throughput("lane_dot kernel 64 rows (scalar)", rows as f64, || {
            black_box(lane_dot_at(SimdLevel::Scalar, black_box(&ka), black_box(&kb)));
        })
        .ns_per_iter;
    let lane_dot_simd_ns = suite
        .bench_throughput("lane_dot kernel 64 rows (SIMD)", rows as f64, || {
            black_box(lane_dot_at(detected_level(), black_box(&ka), black_box(&kb)));
        })
        .ns_per_iter;

    // Array-level batching (the serving head's layer-0 shape, 64→32).
    let mut arr = TileArray::new(&chip, 64, 32);
    arr.program_matrix(&vec![100.0; 64 * 32], &vec![6.0; 64 * 32]);
    let x64: Vec<u8> = (0..64).map(|_| rng.next_below(16) as u8).collect();
    suite.bench_throughput("array 64x32 mvm_batch/32 (fresh ε)", 64.0 * 32.0 * 2.0 * batch as f64, || {
        black_box(arr.mvm_batch(&x64, batch, fresh));
    });

    let speedup_single_thread = legacy_held / batch_held.max(1e-9);
    let speedup_fresh = legacy_fresh / batch_fresh.max(1e-9);
    let speedup_simd_vs_scalar = soa_held_scalar / soa_held_simd.max(1e-9);
    let speedup_lane_dot = lane_dot_scalar_ns / lane_dot_simd_ns.max(1e-9);
    suite.note(
        "held-ε speedup (batched SoA vs legacy)",
        format!("{speedup_single_thread:.2}x"),
    );
    suite.note(
        "fresh-ε speedup (batched SoA vs legacy)",
        format!("{speedup_fresh:.2}x"),
    );
    suite.note(
        "SIMD speedup (held-ε mvm, vs forced scalar)",
        format!("{speedup_simd_vs_scalar:.2}x at {}", detected_level()),
    );
    suite.note(
        "SIMD speedup (lane_dot kernel, 64 rows)",
        format!("{speedup_lane_dot:.2}x at {}", detected_level()),
    );

    let cases = [
        MvmBenchCase::new("legacy_aos_fresh_eps", legacy_fresh, ops),
        MvmBenchCase::new("soa_fresh_eps", soa_fresh, ops),
        MvmBenchCase::new("soa_batch32_fresh_eps", batch_fresh, ops),
        MvmBenchCase::new("legacy_aos_held_eps", legacy_held, ops),
        MvmBenchCase::new("soa_held_eps", soa_held, ops),
        MvmBenchCase::new("soa_batch32_held_eps", batch_held, ops),
        MvmBenchCase::new("soa_held_eps_forced_scalar", soa_held_scalar, ops),
        MvmBenchCase::new("soa_held_eps_simd", soa_held_simd, ops),
    ];
    let quick = std::env::args().any(|a| a == "--quick");
    let source = if quick {
        "benches/cim_mvm.rs --quick (calibrated, release profile)"
    } else {
        "benches/cim_mvm.rs (calibrated, release profile)"
    };
    write_mvm_report(
        &repo_root_artifact("BENCH_cim_mvm.json"),
        source,
        chip.tile.rows,
        chip.tile.words_per_row,
        &cases,
        &[
            ("speedup_single_thread", speedup_single_thread),
            ("speedup_fresh_eps", speedup_fresh),
            ("speedup_simd_vs_scalar", speedup_simd_vs_scalar),
            ("speedup_lane_dot_simd_vs_scalar", speedup_lane_dot),
        ],
    );
    suite.finish();
}
