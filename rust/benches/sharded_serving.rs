//! Bench: sharded serving — batched-request throughput vs. worker count,
//! swept across engine backends (`sim` vs `cim`).
//!
//! Runs the full coordinator (dispatcher → round-robin shard pool) on the
//! artifact-free backends, so it needs no PJRT toolchain:
//!
//! - `sim` — pure-Rust engine, ε supplied externally by per-shard GRNG
//!   banks. Measures the coordination fabric itself.
//! - `cim` — the behavioral chip model: head MVMs through calibrated tile
//!   arrays with in-word ε and live energy ledgers. Measures the cost of
//!   full-fidelity hardware serving (and reports fJ/Sample + fJ/Op).
//!
//! The offered load is pre-queued through the client API v1 surface
//! (`Coordinator::builder` + `submit_many`, via
//! `util::bench::measure_serving_sweep`) so throughput measures the
//! pool, not the client. Besides the human-readable table, the sweep is written
//! machine-readably to `BENCH_serving.json` at the repo root, seeding the
//! perf trajectory across PRs.

use bnn_cim::config::{Backend, Config};
use bnn_cim::util::bench::{
    is_calibrated_report, measure_serving_sweep, repo_root_artifact, ServingSweepPoint, Suite,
};
use bnn_cim::util::json::Json;

fn run_point(
    backend: Backend,
    workers: usize,
    mc_workers: usize,
    n_req: usize,
    mc: usize,
) -> ServingSweepPoint {
    let mut cfg = Config::default();
    cfg.server.backend = backend;
    cfg.model.mc_samples = mc;
    cfg.server.workers = workers;
    cfg.server.mc_workers = mc_workers;
    cfg.server.max_batch = 8;
    cfg.server.batch_deadline_ms = 0.5;
    measure_serving_sweep(&cfg, n_req)
}

fn main() {
    let mut suite = Suite::new("sharded_serving (dispatcher + shard pool, sim vs cim)");
    suite.header();
    let quick = std::env::args().any(|a| a == "--quick");
    let sim_req = if quick { 64 } else { 256 };
    // The chip model runs the full analog chain per MVM: offer less load
    // so the sweep finishes in bench time.
    let cim_req = if quick { 16 } else { 48 };
    let mc = if quick { 8 } else { 32 };

    // Warm passes (both backends) so page-cache/allocator effects don't
    // bias each sweep's workers=1 baseline.
    let _ = run_point(Backend::Sim, 1, 1, sim_req / 4, mc);
    let _ = run_point(Backend::Cim, 1, 1, cim_req / 4, mc);

    let mut sweeps: Vec<Json> = Vec::new();
    // For cim, also sweep the engine-level MC fan-out (`mc_workers`):
    // shard workers scale across requests, MC replicas scale across the
    // Monte-Carlo samples *inside* each fused batch.
    let plans: [(Backend, usize, &[usize]); 2] = [
        (Backend::Sim, sim_req, &[1]),
        (Backend::Cim, cim_req, &[1, 4]),
    ];
    for &(backend, n_req, mc_worker_sweep) in &plans {
        let mut baseline = 0.0f64;
        for &mc_workers in mc_worker_sweep {
            for &workers in &[1usize, 2, 4] {
                let p = run_point(backend, workers, mc_workers, n_req, mc);
                if workers == 1 && mc_workers == mc_worker_sweep[0] {
                    baseline = p.req_per_s;
                }
                let mut line = format!(
                    "{:.1} req/s ({:.2}x vs 1 worker), {} batches, fill {:.2}",
                    p.req_per_s,
                    p.req_per_s / baseline.max(1e-9),
                    p.batches,
                    p.mean_fill
                );
                if p.engine_fj_per_op > 0.0 {
                    line.push_str(&format!(
                        ", {:.0} fJ/Sa, {:.0} fJ/Op",
                        p.eps_fj_per_sample, p.engine_fj_per_op
                    ));
                }
                suite.note(
                    &format!(
                        "{} workers={workers} mc_workers={mc_workers} ({n_req} req, T={mc})",
                        backend.name()
                    ),
                    line,
                );
                sweeps.push(p.to_json());
            }
        }
    }
    suite.note(
        "epsilon sourcing",
        "sim: per-shard GRNG-bank sources (external ε) | cim: in-word ε \
         inside the engine's tile arrays, no coordinator supply"
            .into(),
    );

    // Machine-readable sweep at the repo root. Only a full-scale run may
    // claim the calibrated mark (a `source` without "smoke", which
    // `util::bench::is_calibrated_report` gives precedence); a --quick
    // run is smoke-scale — it stays overwritable and must not replace an
    // existing calibrated report.
    let root = repo_root_artifact("BENCH_serving.json");
    if quick && is_calibrated_report(&root) {
        println!("  keeping calibrated {}", root.display());
    } else {
        let source = if quick {
            "benches/sharded_serving.rs --quick (smoke-scale)"
        } else {
            "benches/sharded_serving.rs (calibrated, release profile)"
        };
        suite.write_report(
            &root,
            vec![
                ("source", Json::Str(source.to_string())),
                ("sweeps", Json::Arr(sweeps)),
            ],
        );
        println!("  wrote {}", root.display());
    }
    suite.finish();
}
