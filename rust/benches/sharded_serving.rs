//! Bench: sharded serving — batched-request throughput vs. worker count.
//!
//! Runs the full coordinator (dispatcher → round-robin shard pool, each
//! shard owning a SimEngine replica plus its own split-seeded GRNG bank)
//! on the pure-Rust backend, so it needs no artifacts and no PJRT
//! toolchain. The offered load is pre-queued so throughput measures the
//! pool, not the client: expect req/s to scale monotonically 1 → 4
//! workers (bounded by available cores).

use bnn_cim::config::Config;
use bnn_cim::coordinator::Coordinator;
use bnn_cim::data::SyntheticPerson;
use bnn_cim::util::bench::Suite;
use std::time::{Duration, Instant};

fn throughput_with_workers(workers: usize, n_req: usize, mc: usize) -> (f64, u64, f64) {
    let mut cfg = Config::default();
    cfg.model.mc_samples = mc;
    cfg.server.workers = workers;
    cfg.server.max_batch = 8;
    cfg.server.queue_capacity = n_req + 8;
    cfg.server.batch_deadline_ms = 0.5;
    let coord = Coordinator::start_sim(cfg.clone()).unwrap();
    let gen = SyntheticPerson::new(cfg.model.image_side, 7);
    // Pre-generate so the dataset is not on the measured path.
    let imgs: Vec<Vec<f32>> = (0..n_req as u64).map(|i| gen.sample(i).pixels).collect();
    let t0 = Instant::now();
    let receivers: Vec<_> = imgs
        .into_iter()
        .map(|px| coord.submit(px, 0).expect("queue sized for full load"))
        .collect();
    for rx in receivers {
        rx.recv_timeout(Duration::from_secs(300)).expect("response");
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    coord.shutdown();
    (n_req as f64 / dt, m.batches, m.mean_batch_fill)
}

fn main() {
    let mut suite = Suite::new("sharded_serving (dispatcher + shard pool, sim engine)");
    suite.header();
    let quick = std::env::args().any(|a| a == "--quick");
    let n_req = if quick { 64 } else { 256 };
    let mc = if quick { 8 } else { 32 };

    // Warm pass so page-cache/allocator effects don't bias workers=1.
    let _ = throughput_with_workers(1, n_req / 4, mc);

    let mut baseline = 0.0f64;
    for &workers in &[1usize, 2, 4] {
        let (rps, batches, fill) = throughput_with_workers(workers, n_req, mc);
        if workers == 1 {
            baseline = rps;
        }
        suite.note(
            &format!("workers={workers} ({n_req} req, T={mc})"),
            format!(
                "{rps:.1} req/s ({:.2}x vs 1 worker), {batches} batches, fill {fill:.2}",
                rps / baseline.max(1e-9)
            ),
        );
    }
    suite.note(
        "epsilon sourcing",
        "per-shard GRNG banks (SplitMix64 splits of die_seed), no shared RNG".into(),
    );
    suite.finish();
}
