//! Bench: GRNG subsystem — regenerates Fig. 8 (characterization),
//! Fig. 9 (bias sweep) and Tab. I (temperature sweep), plus wallclock
//! throughput of the two simulation modes and the bank-level fill paths
//! (SoA block sampler vs the retained per-cell AoS walk), written to the
//! repo-root `BENCH_grng_fill.json` (calibrated; the smoke-scale seed is
//! `tests/grng_props.rs`).

use bnn_cim::arch::{detected_level, ForcedLevelGuard, SimdLevel};
use bnn_cim::config::{ChipConfig, GrngConfig};
use bnn_cim::experiments::{self, fig9, tab1};
use bnn_cim::grng::{GrngBank, GrngCell};
use bnn_cim::util::bench::{
    black_box, repo_root_artifact, write_grng_fill_report, GrngFillCase, Suite,
};

fn main() {
    let mut suite = Suite::new("grng (Fig. 8, Fig. 9, Tab. I, bank fill)");
    suite.header();
    let cfg = GrngConfig::default();

    // --- wallclock throughput of the two sampling modes ---
    let mut cell = GrngCell::ideal(&cfg, 1);
    suite.bench_throughput("sample_fast (closed form)", 1.0, || {
        black_box(cell.eps_fast());
    });
    let mut cell2 = GrngCell::ideal(&cfg, 2);
    suite.bench_throughput("sample_circuit (stochastic ODE)", 1.0, || {
        black_box(cell2.sample_circuit());
    });

    // --- bank fill: SoA block sampler vs retained AoS walk ---
    // All three paths are bit-identical (tests/grng_props.rs); this
    // measures only the layout change. One iteration = one whole-bank
    // conversion (rows × words fresh ε), the unit the chip delivers per
    // cycle.
    let chip = ChipConfig::default();
    let cells = chip.tile.rows * chip.tile.words_per_row;
    let mut buf = vec![0.0f64; cells];
    let mut bank_block = GrngBank::for_chip(&chip);
    let block = suite
        .bench_throughput("bank fill_epsilon (SoA block)", cells as f64, || {
            bank_block.fill_epsilon(black_box(&mut buf));
        })
        .ns_per_iter;
    let mut bank_planes = GrngBank::for_chip(&chip);
    let planes = suite
        .bench_throughput("bank fill_epsilon_planes (plane-major)", cells as f64, || {
            bank_planes.fill_epsilon_planes(black_box(&mut buf));
        })
        .ns_per_iter;
    let mut bank_legacy = GrngBank::for_chip(&chip);
    let legacy = suite
        .bench_throughput("bank fill_epsilon_legacy (AoS walk)", cells as f64, || {
            bank_legacy.fill_epsilon_legacy(black_box(&mut buf));
        })
        .ns_per_iter;
    // SIMD arm vs forced-scalar arm of the identical block fill (ISSUE 6:
    // vectorized xoshiro sweep + dispatched normalize; the ziggurat
    // finish stays scalar on both arms).
    let mut bank_scalar = GrngBank::for_chip(&chip);
    let block_scalar = {
        let _scalar = ForcedLevelGuard::new(SimdLevel::Scalar);
        suite
            .bench_throughput("bank fill_epsilon_planes (forced scalar)", cells as f64, || {
                bank_scalar.fill_epsilon_planes(black_box(&mut buf));
            })
            .ns_per_iter
    };
    let mut bank_simd = GrngBank::for_chip(&chip);
    let block_simd = {
        let _vector = ForcedLevelGuard::new(detected_level());
        suite
            .bench_throughput("bank fill_epsilon_planes (SIMD)", cells as f64, || {
                bank_simd.fill_epsilon_planes(black_box(&mut buf));
            })
            .ns_per_iter
    };
    let gsa_per_s = cells as f64 / block.max(1e-9);
    let speedup_block_vs_legacy = legacy / block.max(1e-9);
    let speedup_planes_vs_legacy = legacy / planes.max(1e-9);
    let speedup_simd_vs_scalar = block_scalar / block_simd.max(1e-9);
    suite.note(
        "block speedup vs legacy",
        format!("{speedup_block_vs_legacy:.2}x"),
    );
    suite.note("block software rate", format!("{gsa_per_s:.4} GSa/s"));
    suite.note(
        "SIMD speedup (plane fill, vs forced scalar)",
        format!("{speedup_simd_vs_scalar:.2}x at {}", detected_level()),
    );
    let quick = std::env::args().any(|a| a == "--quick");
    let source = if quick {
        "benches/grng.rs --quick (calibrated, release profile)"
    } else {
        "benches/grng.rs (calibrated, release profile)"
    };
    write_grng_fill_report(
        &repo_root_artifact("BENCH_grng_fill.json"),
        source,
        chip.tile.rows,
        chip.tile.words_per_row,
        &[
            GrngFillCase::new("block_soa", block, cells),
            GrngFillCase::new("block_soa_planes", planes, cells),
            GrngFillCase::new("legacy_aos", legacy, cells),
            GrngFillCase::new("block_soa_planes_forced_scalar", block_scalar, cells),
            GrngFillCase::new("block_soa_planes_simd", block_simd, cells),
        ],
        &[
            ("gsa_per_s", gsa_per_s),
            ("speedup_block_vs_legacy", speedup_block_vs_legacy),
            ("speedup_planes_vs_legacy", speedup_planes_vs_legacy),
            ("speedup_simd_vs_scalar", speedup_simd_vs_scalar),
        ],
    );

    // --- Fig. 8 ---
    let rep = experiments::run_characterization(&cfg, 2500, 42, true);
    suite.note("fig8.qq_r (paper 0.9967)", format!("{:.4}", rep.quality.qq_r));
    suite.note(
        "fig8.pulse_sd_ns (paper ~1.0)",
        format!("{:.3}", rep.quality.width_sd_s * 1e9),
    );
    suite.note(
        "fig8.latency_ns (paper ~69)",
        format!("{:.1}", rep.quality.mean_latency_s * 1e9),
    );
    suite.note(
        "fig8.energy_fj (paper 360)",
        format!("{:.0}", rep.quality.mean_energy_j * 1e15),
    );

    // --- Fig. 9 ---
    let pts = experiments::run_bias_sweep(&cfg, &fig9::default_biases(), 200, 7);
    println!("\n{}", fig9::render(&pts));
    let first = &pts[0];
    let last = &pts[pts.len() - 1];
    suite.note(
        "fig9.latency_range_ns",
        format!(
            "{:.1} → {:.1}",
            first.model_latency_s * 1e9,
            last.model_latency_s * 1e9
        ),
    );
    suite.note(
        "fig9.sigma_range_ns",
        format!(
            "{:.2} → {:.2}",
            first.model_sigma_s * 1e9,
            last.model_sigma_s * 1e9
        ),
    );

    // --- Tab. I ---
    let temps = [28.0, 40.0, 50.0, 60.0];
    let rows = experiments::run_temp_sweep(&cfg, &temps, 2500, 11);
    println!("{}", tab1::render(&rows));
    suite.note(
        "tab1.latency_ratio_28_60 (paper 2.49)",
        format!("{:.2}", rows[0].latency_s / rows[3].latency_s),
    );
    suite.note(
        "tab1.sigma_ratio_60_28 (paper 2.62)",
        format!("{:.2}", rows[3].width_sd_s / rows[0].width_sd_s),
    );
    suite.note(
        "tab1.qq_r_60C (paper 0.0736 — collapse)",
        format!("{:.3}", rows[3].qq_r),
    );

    suite.finish();
}
