//! Bench: GRNG subsystem — regenerates Fig. 8 (characterization),
//! Fig. 9 (bias sweep) and Tab. I (temperature sweep), plus wallclock
//! throughput of the two simulation modes.

use bnn_cim::config::GrngConfig;
use bnn_cim::experiments::{self, fig9, tab1};
use bnn_cim::grng::GrngCell;
use bnn_cim::util::bench::{black_box, Suite};

fn main() {
    let mut suite = Suite::new("grng (Fig. 8, Fig. 9, Tab. I)");
    suite.header();
    let cfg = GrngConfig::default();

    // --- wallclock throughput of the two sampling modes ---
    let mut cell = GrngCell::ideal(&cfg, 1);
    suite.bench_throughput("sample_fast (closed form)", 1.0, || {
        black_box(cell.eps_fast());
    });
    let mut cell2 = GrngCell::ideal(&cfg, 2);
    suite.bench_throughput("sample_circuit (stochastic ODE)", 1.0, || {
        black_box(cell2.sample_circuit());
    });

    // --- Fig. 8 ---
    let rep = experiments::run_characterization(&cfg, 2500, 42, true);
    suite.note("fig8.qq_r (paper 0.9967)", format!("{:.4}", rep.quality.qq_r));
    suite.note(
        "fig8.pulse_sd_ns (paper ~1.0)",
        format!("{:.3}", rep.quality.width_sd_s * 1e9),
    );
    suite.note(
        "fig8.latency_ns (paper ~69)",
        format!("{:.1}", rep.quality.mean_latency_s * 1e9),
    );
    suite.note(
        "fig8.energy_fj (paper 360)",
        format!("{:.0}", rep.quality.mean_energy_j * 1e15),
    );

    // --- Fig. 9 ---
    let pts = experiments::run_bias_sweep(&cfg, &fig9::default_biases(), 200, 7);
    println!("\n{}", fig9::render(&pts));
    let first = &pts[0];
    let last = &pts[pts.len() - 1];
    suite.note(
        "fig9.latency_range_ns",
        format!(
            "{:.1} → {:.1}",
            first.model_latency_s * 1e9,
            last.model_latency_s * 1e9
        ),
    );
    suite.note(
        "fig9.sigma_range_ns",
        format!(
            "{:.2} → {:.2}",
            first.model_sigma_s * 1e9,
            last.model_sigma_s * 1e9
        ),
    );

    // --- Tab. I ---
    let temps = [28.0, 40.0, 50.0, 60.0];
    let rows = experiments::run_temp_sweep(&cfg, &temps, 2500, 11);
    println!("{}", tab1::render(&rows));
    suite.note(
        "tab1.latency_ratio_28_60 (paper 2.49)",
        format!("{:.2}", rows[0].latency_s / rows[3].latency_s),
    );
    suite.note(
        "tab1.sigma_ratio_60_28 (paper 2.62)",
        format!("{:.2}", rows[3].width_sd_s / rows[0].width_sd_s),
    );
    suite.note(
        "tab1.qq_r_60C (paper 0.0736 — collapse)",
        format!("{:.3}", rows[3].qq_r),
    );

    suite.finish();
}
