//! Bench: elastic serving — the copy-on-calibrate shared tile state and
//! the autoscaler built on it (DESIGN.md §10).
//!
//! Three measurements, written machine-readably to `BENCH_elastic.json`
//! at the repo root:
//!
//! 1. **Footprint split** — bytes in the Arc-shared immutable layer
//!    (μ digit planes, σ masks, IDAC/ADC calibration, head mapping) vs
//!    bytes of per-replica private state (ε buffers, scratch, ledgers).
//!    The whole point of copy-on-calibrate is that the private slice is
//!    tiny, so replicas are nearly free.
//! 2. **Replica boot vs full boot** — growing the replica pool by one
//!    (`set_replicas`: Arc::clone + stream reseed) against a cold
//!    `CimEngine::for_shard` bring-up (weights, mapping, calibration).
//!    The ratio is the headline `replica_boot_speedup` the CI gate
//!    tracks across PRs.
//! 3. **Throughput around a scale event** — an identical pre-queued
//!    burst through an elastic pool (mc_workers 1 → ceiling 4) and a
//!    pinned pool (elastic off, mc_workers = 1), with the scale
//!    counters proving the autoscaler actually engaged.

use bnn_cim::client::{Config, Coordinator, Infer};
use bnn_cim::config::Backend;
use bnn_cim::data::SyntheticPerson;
use bnn_cim::runtime::{CimEngine, InferenceEngine};
use bnn_cim::util::bench::{black_box, is_calibrated_report, repo_root_artifact, Suite};
use bnn_cim::util::json::Json;
use std::time::{Duration, Instant};

fn chip_cfg(quick: bool, mc: usize) -> Config {
    let mut cfg = Config::default();
    cfg.server.backend = Backend::Cim;
    cfg.model.mc_samples = mc;
    if quick {
        // Smoke scale: small tiles keep CI's bring-up measurements fast
        // without changing what is being compared (both sides of every
        // ratio shrink together).
        cfg.chip.tile.rows = 16;
        cfg.chip.tile.words_per_row = 4;
    }
    cfg
}

/// Drive a pre-queued burst and return (req/s, scale_up, scale_down,
/// peak replicas gauge observed at the end of the drain).
fn run_burst(cfg: &Config, n_req: usize) -> (f64, u64, u64, usize) {
    let mut cfg = cfg.clone();
    cfg.server.queue_capacity = cfg.server.queue_capacity.max(n_req + 8);
    let coord = Coordinator::builder(cfg.clone()).start().expect("boot cim pool");
    let gen = SyntheticPerson::new(cfg.model.image_side, 7);
    let imgs: Vec<Vec<f32>> = (0..n_req as u64).map(|i| gen.sample(i).pixels).collect();
    let t0 = Instant::now();
    let tickets = coord
        .submit_many(imgs.into_iter().map(Infer::new))
        .expect("queue sized for full load");
    for t in tickets {
        t.wait_timeout(Duration::from_secs(600)).expect("response");
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    let replicas = m.per_shard.iter().map(|s| s.replicas_active).max().unwrap_or(0);
    coord.shutdown();
    (n_req as f64 / dt.max(1e-9), m.scale_up, m.scale_down, replicas)
}

fn main() {
    let mut suite = Suite::new("elastic (shared tile state, replica boot, autoscaler)");
    suite.header();
    let quick = std::env::args().any(|a| a == "--quick");
    let mc = if quick { 8 } else { 32 };
    let cfg = chip_cfg(quick, mc);

    // 1. Footprint split at a 4-replica pool.
    let mut engine = CimEngine::for_shard(&cfg, 0);
    engine.set_replicas(4);
    let bytes_shared = engine.bytes_shared();
    let bytes_private = engine.bytes_private();
    let bytes_private_per_replica = bytes_private / engine.replica_count().max(1);
    suite.note(
        "footprint (4 replicas)",
        format!(
            "{} B shared (Arc'd planes/masks/calibration) vs {} B private \
             ({} B/replica: ε buffers + scratch + ledger)",
            bytes_shared, bytes_private, bytes_private_per_replica
        ),
    );

    // 2. Full bring-up vs replica growth.
    let boot_iters = if quick { 1 } else { 3 };
    let t0 = Instant::now();
    for _ in 0..boot_iters {
        black_box(CimEngine::for_shard(&cfg, 0));
    }
    let full_boot_us = t0.elapsed().as_secs_f64() * 1e6 / boot_iters as f64;

    // Repeatedly shrink to 1 and regrow: every grow step is one
    // `make_replica` (Arc::clone + deterministic stream reseed), the
    // operation the elastic scaler pays per scale-up.
    let (grow, reps) = if quick { (4usize, 2usize) } else { (8, 8) };
    let t0 = Instant::now();
    for _ in 0..reps {
        engine.set_replicas(1);
        engine.set_replicas(1 + grow);
    }
    let replica_boot_us = t0.elapsed().as_secs_f64() * 1e6 / (reps * grow) as f64;
    let replica_boot_speedup = full_boot_us / replica_boot_us.max(1e-9);
    suite.note(
        "boot latency",
        format!(
            "full bring-up {:.0} µs vs replica grow {:.2} µs — {:.0}x",
            full_boot_us, replica_boot_us, replica_boot_speedup
        ),
    );
    drop(engine);

    // 3. Throughput around a scale event: same burst, elastic vs pinned.
    let n_req = if quick { 24 } else { 64 };
    let mut serve_cfg = cfg.clone();
    serve_cfg.server.workers = 1;
    serve_cfg.server.mc_workers = 1;
    serve_cfg.server.min_mc_workers = 1;
    serve_cfg.server.max_mc_workers = 4;
    serve_cfg.server.max_batch = 2;
    serve_cfg.server.batch_deadline_ms = 0.5;

    serve_cfg.server.elastic = false;
    let _ = run_burst(&serve_cfg, n_req / 4); // warm page cache/allocator
    let (pinned_rps, _, _, _) = run_burst(&serve_cfg, n_req);

    serve_cfg.server.elastic = true;
    let (elastic_rps, scale_up, scale_down, peak_replicas) = run_burst(&serve_cfg, n_req);
    suite.note(
        "scale event",
        format!(
            "{:.1} req/s elastic (scale_up={}, scale_down={}, peak replicas={}) \
             vs {:.1} req/s pinned at mc_workers=1 ({} req, T={})",
            elastic_rps, scale_up, scale_down, peak_replicas, pinned_rps, n_req, mc
        ),
    );

    let mut scale_event = Json::obj();
    scale_event
        .set("requests", Json::Num(n_req as f64))
        .set("elastic_req_per_s", Json::Num(elastic_rps))
        .set("pinned_req_per_s", Json::Num(pinned_rps))
        .set("scale_up", Json::Num(scale_up as f64))
        .set("scale_down", Json::Num(scale_down as f64))
        .set("peak_replicas", Json::Num(peak_replicas as f64));

    // A --quick run is smoke-scale: it must not replace an existing
    // calibrated report (same contract as BENCH_serving.json).
    let root = repo_root_artifact("BENCH_elastic.json");
    if quick && is_calibrated_report(&root) {
        println!("  keeping calibrated {}", root.display());
    } else {
        let source = if quick {
            "benches/elastic.rs --quick (smoke-scale)"
        } else {
            "benches/elastic.rs (calibrated, release profile)"
        };
        suite.write_report(
            &root,
            vec![
                ("source", Json::Str(source.to_string())),
                ("replica_boot_speedup", Json::Num(replica_boot_speedup)),
                ("full_boot_us", Json::Num(full_boot_us)),
                ("replica_boot_us", Json::Num(replica_boot_us)),
                ("bytes_shared", Json::Num(bytes_shared as f64)),
                ("bytes_private", Json::Num(bytes_private as f64)),
                ("bytes_private_per_replica", Json::Num(bytes_private_per_replica as f64)),
                ("scale_event", scale_event),
            ],
        );
        println!("  wrote {}", root.display());
    }
    suite.finish();
}
