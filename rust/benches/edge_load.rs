//! Bench: `edge_load` — the network edge under open-loop offered load.
//!
//! Boots a full coordinator (sim backend) plus the HTTP edge on an
//! ephemeral loopback port, then drives it the way real traffic arrives:
//! an *open-loop* schedule (request i is due at `t0 + i/rate` whether or
//! not earlier requests finished — no accidental self-throttling) with a
//! heavy-tailed `mc_samples` mix (mostly cheap, a few expensive). Each
//! offered rate is one sweep point; the report is the measured load
//! curve: completed rps, p50/p99 latency, and the admission counters
//! (shed / degraded / escalated) as overload sets in.
//!
//! Rates are calibrated against the server's own measured closed-loop
//! capacity, so the sweep brackets saturation on any host: below it the
//! edge admits everything, above it the shed/degrade/escalate machine
//! carries the overflow. `--quick` runs two points (0.5× and 3×
//! capacity) at CI scale; results land in `BENCH_edge.json` at the repo
//! root (`scripts/bench_gate.py` gates on them in the edge-smoke job).

use bnn_cim::client::{Backend, Config, Coordinator, EdgeServer};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::edge::MiniClient;
use bnn_cim::util::bench::{is_calibrated_report, repo_root_artifact, Suite};
use bnn_cim::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Heavy-tail fidelity mix, deterministic by request index: 80% cheap
/// (mc=4), 15% medium (mc=16), 5% heavy (mc=64).
fn mc_mix(i: usize) -> usize {
    match i % 20 {
        0..=15 => 4,
        16..=18 => 16,
        _ => 64,
    }
}

fn request_body(pixels_json: &str, mc: usize) -> String {
    format!("{{\"pixels\":{pixels_json},\"mc_samples\":{mc}}}")
}

#[derive(Default, Clone, Debug)]
struct PointTally {
    completed: u64,
    shed: u64,
    degraded: u64,
    escalated: u64,
    errors: u64,
    latencies_ms: Vec<f64>,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Drive one open-loop point at `rate` req/s for `window` seconds.
fn run_point(
    addr: std::net::SocketAddr,
    pixels_json: &str,
    rate: f64,
    window: Duration,
    clients: usize,
    timeout: Duration,
) -> PointTally {
    let tally = Arc::new(Mutex::new(PointTally::default()));
    let next = Arc::new(AtomicUsize::new(0));
    let total = (rate * window.as_secs_f64()).ceil() as usize;
    let t0 = Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|_| {
            let tally = Arc::clone(&tally);
            let next = Arc::clone(&next);
            let pixels_json = pixels_json.to_string();
            std::thread::spawn(move || {
                let mut conn = MiniClient::connect(addr, timeout).ok();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        return;
                    }
                    // Open-loop: request i is due at t0 + i/rate.
                    let due = Duration::from_secs_f64(i as f64 / rate);
                    if let Some(wait) = due.checked_sub(t0.elapsed()) {
                        std::thread::sleep(wait);
                    }
                    let body = request_body(&pixels_json, mc_mix(i));
                    let sent = Instant::now();
                    // Reconnect once if the pooled connection went away
                    // (server closed an idle keep-alive, earlier error).
                    let result = match conn.as_mut() {
                        Some(c) => c.request("POST", "/v1/infer", Some(&body)),
                        None => Err(std::io::ErrorKind::NotConnected.into()),
                    };
                    let result = match result {
                        Ok(r) => Ok(r),
                        Err(_) => {
                            conn = MiniClient::connect(addr, timeout).ok();
                            match conn.as_mut() {
                                Some(c) => c.request("POST", "/v1/infer", Some(&body)),
                                None => Err(std::io::ErrorKind::NotConnected.into()),
                            }
                        }
                    };
                    let mut t = tally.lock().unwrap();
                    match result {
                        Ok((200, resp)) => {
                            t.completed += 1;
                            t.latencies_ms.push(sent.elapsed().as_secs_f64() * 1e3);
                            // Cheap flag scan — the wire encoder emits
                            // these exact tokens.
                            if resp.contains("\"degraded\":true") {
                                t.degraded += 1;
                            }
                            if resp.contains("\"escalated\":true") {
                                t.escalated += 1;
                            }
                        }
                        Ok((429, _)) => t.shed += 1,
                        Ok(_) | Err(_) => {
                            t.errors += 1;
                            conn = None; // force reconnect next round
                        }
                    }
                }
            })
        })
        .collect();
    for th in threads {
        let _ = th.join();
    }
    Arc::into_inner(tally).unwrap().into_inner().unwrap()
}

fn main() {
    let mut suite = Suite::new("edge_load (HTTP edge: open-loop offered load vs admission)");
    suite.header();
    let quick = std::env::args().any(|a| a == "--quick");

    let mut cfg = Config::default();
    cfg.server.backend = Backend::Sim;
    cfg.server.workers = 2;
    cfg.server.mc_workers = 1;
    cfg.server.max_batch = 8;
    cfg.server.batch_deadline_ms = 0.5;
    // Small queue so the load curve actually bends at bench scale.
    cfg.server.queue_capacity = 32;
    cfg.server.request_timeout_ms = 5000.0;
    cfg.model.mc_samples = 8;
    // Low deferral threshold: plenty of uncertain verdicts, so degraded
    // passes exercise the escalation path, not just the cheap exit.
    cfg.model.defer_threshold = 0.05;
    cfg.server.edge_degrade_load = 0.3;
    cfg.server.edge_shed_load = 0.85;
    cfg.server.edge_degraded_mc_samples = 2;
    cfg.server.edge_threads = 8;

    let coord = Arc::new(
        Coordinator::builder(cfg.clone())
            .start()
            .expect("coordinator boot"),
    );
    let edge = EdgeServer::bind("127.0.0.1:0", Arc::clone(&coord)).expect("edge bind");
    let addr = edge.local_addr();
    let timeout = Duration::from_secs(10);

    let gen = SyntheticPerson::new(cfg.model.image_side, 2024);
    let pixels = gen.sample(0).pixels;
    let mut pixels_json = String::from("[");
    for (i, p) in pixels.iter().enumerate() {
        if i > 0 {
            pixels_json.push(',');
        }
        pixels_json.push_str(&format!("{p}"));
    }
    pixels_json.push(']');

    // Closed-loop calibration: sequential requests over one connection
    // measure the per-request service capacity this host can sustain.
    let mut conn = MiniClient::connect(addr, timeout).expect("calibration connect");
    let cal_start = Instant::now();
    let mut cal_done = 0u64;
    while cal_start.elapsed() < Duration::from_millis(if quick { 300 } else { 1000 }) {
        let body = request_body(&pixels_json, 4);
        if conn.request("POST", "/v1/infer", Some(&body)).is_err() {
            conn = MiniClient::connect(addr, timeout).expect("calibration reconnect");
        }
        cal_done += 1;
    }
    let capacity_rps = (cal_done as f64 / cal_start.elapsed().as_secs_f64()).max(1.0);
    suite.note(
        "calibration",
        format!("closed-loop capacity ≈ {capacity_rps:.0} req/s (single connection)"),
    );

    let multipliers: &[f64] = if quick {
        &[0.5, 3.0]
    } else {
        &[0.5, 1.0, 2.0, 4.0]
    };
    let window = Duration::from_secs_f64(if quick { 1.5 } else { 4.0 });
    let clients = 16;

    let mut points: Vec<Json> = Vec::new();
    let mut peak_completed_rps = 0.0f64;
    let mut overload: Option<Json> = None;
    for &mult in multipliers {
        let offered = capacity_rps * mult;
        let t = run_point(addr, &pixels_json, offered, window, clients, timeout);
        let mut lat = t.latencies_ms.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let achieved = t.completed as f64 / window.as_secs_f64();
        peak_completed_rps = peak_completed_rps.max(achieved);
        let p50 = pct(&lat, 0.50);
        let p99 = pct(&lat, 0.99);
        let p99_bounded = p99 <= cfg.server.request_timeout_ms;
        // Live throughput counters from the server's own metrics route.
        let (gop_per_s, gsa_per_s) = match MiniClient::connect(addr, timeout)
            .and_then(|mut c| c.request("GET", "/v1/metrics", None))
        {
            Ok((200, body)) => match Json::parse(&body) {
                Ok(doc) => (
                    doc.get("gop_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    doc.get("epsilon_gsa_per_s")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0),
                ),
                Err(_) => (0.0, 0.0),
            },
            _ => (0.0, 0.0),
        };
        suite.note(
            &format!("offered {offered:.0} rps ({mult}x capacity)"),
            format!(
                "completed {achieved:.0} rps, p50 {p50:.1} ms, p99 {p99:.1} ms, shed {} / \
                 degraded {} / escalated {} / errors {}",
                t.shed, t.degraded, t.escalated, t.errors
            ),
        );
        let point = Json::Obj(
            [
                ("offered_rps".to_string(), Json::Num(offered)),
                ("achieved_rps".to_string(), Json::Num(achieved)),
                ("completed".to_string(), Json::Num(t.completed as f64)),
                ("shed".to_string(), Json::Num(t.shed as f64)),
                ("degraded".to_string(), Json::Num(t.degraded as f64)),
                ("escalated".to_string(), Json::Num(t.escalated as f64)),
                ("errors".to_string(), Json::Num(t.errors as f64)),
                ("p50_ms".to_string(), Json::Num(p50)),
                ("p99_ms".to_string(), Json::Num(p99)),
                ("p99_bounded".to_string(), Json::Bool(p99_bounded)),
                ("gop_per_s".to_string(), Json::Num(gop_per_s)),
                ("epsilon_gsa_per_s".to_string(), Json::Num(gsa_per_s)),
            ]
            .into_iter()
            .collect(),
        );
        if mult > 1.0 {
            overload = Some(point.clone());
        }
        points.push(point);
    }

    edge.shutdown();
    drop(coord);

    let root = repo_root_artifact("BENCH_edge.json");
    if quick && is_calibrated_report(&root) {
        println!("  keeping calibrated {}", root.display());
    } else {
        let source = if quick {
            "benches/edge_load.rs --quick (smoke-scale)"
        } else {
            "benches/edge_load.rs (calibrated, release profile)"
        };
        let mut extra = vec![
            ("source", Json::Str(source.to_string())),
            ("suite", Json::Str("edge".to_string())),
            ("capacity_rps", Json::Num(capacity_rps)),
            ("peak_completed_rps", Json::Num(peak_completed_rps)),
            (
                "request_timeout_ms",
                Json::Num(cfg.server.request_timeout_ms),
            ),
            ("points", Json::Arr(points)),
        ];
        if let Some(o) = overload {
            extra.push(("overload", o));
        }
        suite.write_report(&root, extra);
        println!("  wrote {}", root.display());
    }
    suite.finish();
}
