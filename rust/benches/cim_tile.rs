//! Bench: CIM tile simulator — MVM latency by mode, calibration cost,
//! multi-tile array scaling. (Simulator wallclock; the *hardware* timing
//! model is reported by nn_throughput/comparison.)

use bnn_cim::cim::{calibrate, CimTile, MvmOptions, TileArray};
use bnn_cim::config::ChipConfig;
use bnn_cim::util::bench::{black_box, Suite};
use bnn_cim::util::rng::{Pcg64, Rng64};

fn main() {
    let mut suite = Suite::new("cim_tile");
    suite.header();
    let chip = ChipConfig::default();
    let mut tile = CimTile::new(&chip);
    let rep = {
        let t0 = std::time::Instant::now();
        let r = calibrate(&mut tile, 16, 64).unwrap();
        suite.note("calibration wallclock", format!("{:.2?}", t0.elapsed()));
        r
    };
    suite.note("calibration residual rms", format!("{:.3}", rep.grng_residual_rms));
    suite.note(
        "calibration energy (paper 3.6 nJ)",
        format!("{:.2} nJ", rep.energy_j * 1e9),
    );

    let mut rng = Pcg64::new(3);
    let n = chip.tile.rows * chip.tile.words_per_row;
    let mu: Vec<f64> = (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) * 200.0).collect();
    let sg: Vec<f64> = (0..n).map(|_| rng.next_f64() * 12.0).collect();
    tile.program_matrix(&mu, &sg);
    let x: Vec<u8> = (0..chip.tile.rows).map(|_| rng.next_below(16) as u8).collect();

    let ops = chip.tile.ops_per_mvm() as f64;
    suite.bench_throughput("tile mvm (bayesian, fresh ε)", ops, || {
        black_box(tile.mvm(&x, MvmOptions::default()));
    });
    suite.bench_throughput("tile mvm (bayesian, held ε)", ops, || {
        black_box(tile.mvm(
            &x,
            MvmOptions {
                refresh_epsilon: false,
                ..Default::default()
            },
        ));
    });
    suite.bench_throughput("tile mvm (μ only)", ops, || {
        black_box(tile.mvm(
            &x,
            MvmOptions {
                bayesian: false,
                ..Default::default()
            },
        ));
    });
    suite.bench_throughput("tile mvm reference (digital)", ops, || {
        black_box(tile.mvm_reference(&x, true));
    });

    // Array scaling: a 64→32 layer (4 tiles).
    let mut arr = TileArray::new(&chip, 64, 32);
    arr.program_matrix(&vec![100.0; 64 * 32], &vec![6.0; 64 * 32]);
    let x64: Vec<u8> = (0..64).map(|_| rng.next_below(16) as u8).collect();
    suite.bench_throughput("array 64x32 mvm (4 tiles)", 64.0 * 32.0 * 2.0, || {
        black_box(arr.mvm(&x64, MvmOptions::default()));
    });

    suite.finish();
}
