//! # bnn-cim
//!
//! Reproduction of *"A 65 nm Bayesian Neural Network Accelerator with
//! 360 fJ/Sample In-Word GRNG for AI Uncertainty Estimation"* (CS.AR 2025)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — behavioral chip simulator (GRNG circuit, CIM
//!   tile, energy/area model), quantized BNN inference engine, uncertainty
//!   math, and a serving coordinator that executes AOT-compiled XLA
//!   artifacts via PJRT.
//! - **L2 (`python/compile/model.py`)** — JAX partial-Bayesian MobileNet,
//!   trained and lowered to HLO text at build time.
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels for the decomposed
//!   Bayesian MVM and the in-kernel counter-based GRNG.
//!
//! Serving callers should start at [`client`] — the versioned API v1
//! surface (builder, typed tickets, one error type) that the CLI,
//! examples, and benches all route through; DESIGN.md §7 documents the
//! migration from the pre-v1 constructors.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod arch;
pub mod error;
pub mod util;

pub use error::{Error, Result};

pub mod config;
pub mod grng;
pub mod cim;
pub mod energy;
pub mod bayes;
pub mod data;
pub mod nn;
pub mod runtime;
pub mod fault;
pub mod coordinator;
pub mod client;
pub mod edge;
pub mod experiments;
