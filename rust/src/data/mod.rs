//! Synthetic person-detection dataset (INRIA-person substitute).
//!
//! The paper evaluates uncertainty on the INRIA person dataset — real
//! pedestrian photos we cannot ship. The substitute is a procedural
//! binary-classification task with the same *functional* properties the
//! experiments need (DESIGN.md substitution table):
//!
//! - **person**: a vertically-elongated articulated figure (head, torso,
//!   legs) at random position/scale/contrast over textured clutter;
//! - **background**: the same clutter statistics without the figure
//!   (plus person-*like* distractors: vertical poles, blobs — so the task
//!   is learnable but not trivial);
//! - **OOD** split: textures, inverted images, and pure noise — inputs
//!   from outside the training distribution whose predictive entropy the
//!   BNN should raise (Fig. 10).
//!
//! The same procedure (same parameters) is implemented in
//! `python/compile/dataset.py` for build-time training; the two need not
//! be bit-identical — every experiment draws fresh samples from the same
//! distribution.

pub mod generator;

pub use generator::{Dataset, OodKind, Sample, SyntheticPerson};

/// Class labels.
pub const BACKGROUND: usize = 0;
pub const PERSON: usize = 1;
