//! Procedural image generator for the synthetic person dataset.
//!
//! Images are `side × side` grayscale in [0, 1], row-major.

use crate::util::rng::{Pcg64, Rng64};

/// One labeled sample.
#[derive(Clone, Debug)]
pub struct Sample {
    pub pixels: Vec<f32>,
    pub label: usize,
    /// Out-of-distribution marker (None = in-distribution).
    pub ood: Option<OodKind>,
}

/// OOD generators (Fig. 10's out-of-distribution arm).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OodKind {
    /// Partially visible pedestrian (single body part) — the genuinely
    /// ambiguous OOD of the safety-critical story.
    Fragment,
    /// Regular stripe/checker textures.
    Texture,
    /// Contrast-inverted in-distribution images.
    Inverted,
    /// Statistics-matched structure-free noise.
    Noise,
}

/// A materialized dataset split.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub side: usize,
    pub samples: Vec<Sample>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// The generator. Every `Sample` is produced from `(seed, index)` alone,
/// so datasets are reproducible and parallelizable.
#[derive(Clone, Debug)]
pub struct SyntheticPerson {
    pub side: usize,
    pub seed: u64,
}

impl SyntheticPerson {
    pub fn new(side: usize, seed: u64) -> Self {
        assert!(side >= 16, "images smaller than 16px lose the figure");
        Self { side, seed }
    }

    fn rng_for(&self, index: u64, stream: u64) -> Pcg64 {
        Pcg64::with_stream(self.seed ^ index.wrapping_mul(0x9E3779B97F4A7C15), stream)
    }

    /// Generate sample `index` of the in-distribution split; even indices
    /// are background, odd are person (balanced classes).
    pub fn sample(&self, index: u64) -> Sample {
        let label = (index % 2) as usize;
        let mut rng = self.rng_for(index, 0x1D);
        let mut img = self.clutter(&mut rng);
        if label == super::PERSON {
            self.draw_person(&mut img, &mut rng);
        } else if rng.next_bool(0.5) {
            self.draw_distractor(&mut img, &mut rng);
        }
        self.post(&mut img, &mut rng);
        Sample {
            pixels: img,
            label,
            ood: None,
        }
    }

    /// Generate OOD sample `index` of the given kind.
    pub fn ood_sample(&self, index: u64, kind: OodKind) -> Sample {
        let mut rng = self.rng_for(index | 0x8000_0000_0000_0000, 0x0D);
        let img = match kind {
            OodKind::Fragment => {
                let mut img = self.clutter(&mut rng);
                self.draw_fragment(&mut img, &mut rng);
                self.post(&mut img, &mut rng);
                img
            }
            OodKind::Texture => self.texture(&mut rng),
            OodKind::Inverted => {
                let base = self.sample(index);
                base.pixels.iter().map(|&p| 1.0 - p).collect()
            }
            // Statistics-matched noise: N(0.5, 0.15) clipped — structure-
            // free but not brightness-extreme.
            OodKind::Noise => (0..self.side * self.side)
                .map(|_| (0.5 + 0.15 * rng.next_gaussian() as f32).clamp(0.0, 1.0))
                .collect(),
        };
        Sample {
            pixels: img,
            label: super::BACKGROUND, // label is meaningless for OOD
            ood: Some(kind),
        }
    }

    /// Materialize a split of n in-distribution samples starting at
    /// `offset` (train/val/test splits use disjoint offsets).
    pub fn split(&self, offset: u64, n: usize) -> Dataset {
        Dataset {
            side: self.side,
            samples: (0..n as u64).map(|i| self.sample(offset + i)).collect(),
        }
    }

    /// Materialize a mixed OOD split (equal thirds of each kind).
    pub fn ood_split(&self, offset: u64, n: usize) -> Dataset {
        let kinds = [
            OodKind::Fragment,
            OodKind::Texture,
            OodKind::Inverted,
            OodKind::Noise,
        ];
        Dataset {
            side: self.side,
            samples: (0..n as u64)
                .map(|i| self.ood_sample(offset + i, kinds[(i % kinds.len() as u64) as usize]))
                .collect(),
        }
    }

    // ------------------------------------------------------------------
    // drawing primitives
    // ------------------------------------------------------------------

    fn clutter(&self, rng: &mut Pcg64) -> Vec<f32> {
        let s = self.side;
        let mut img = vec![0.0f32; s * s];
        // Smooth background gradient.
        let gx = (rng.next_f32() - 0.5) * 0.4;
        let gy = (rng.next_f32() - 0.5) * 0.4;
        let base = 0.35 + 0.3 * rng.next_f32();
        for y in 0..s {
            for x in 0..s {
                img[y * s + x] =
                    base + gx * (x as f32 / s as f32 - 0.5) + gy * (y as f32 / s as f32 - 0.5);
            }
        }
        // Random rectangles (buildings / clutter).
        let n_rects = 2 + rng.next_below(4) as usize;
        for _ in 0..n_rects {
            let w = 2 + rng.next_below((s / 3) as u64) as usize;
            let h = 2 + rng.next_below((s / 3) as u64) as usize;
            let x0 = rng.next_below((s - w) as u64) as usize;
            let y0 = rng.next_below((s - h) as u64) as usize;
            let v = 0.2 + 0.6 * rng.next_f32();
            let alpha = 0.3 + 0.5 * rng.next_f32();
            for y in y0..y0 + h {
                for x in x0..x0 + w {
                    let p = &mut img[y * s + x];
                    *p = *p * (1.0 - alpha) + v * alpha;
                }
            }
        }
        img
    }

    /// Draw the articulated person figure.
    fn draw_person(&self, img: &mut [f32], rng: &mut Pcg64) {
        let s = self.side as f32;
        // Figure geometry (normalized units).
        let height = 0.5 + 0.3 * rng.next_f32(); // figure height / image
        let cx = 0.25 + 0.5 * rng.next_f32(); // center x
        let top = 0.05 + (0.9 - height) * rng.next_f32(); // top y
        let contrast = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
        let tone = 0.35 * (0.6 + 0.4 * rng.next_f32()) * contrast;

        let head_r = height * 0.11;
        let torso_w = height * 0.16;
        let torso_h = height * 0.42;
        let leg_w = torso_w * 0.38;
        let leg_h = height * 0.38;
        let lean = (rng.next_f32() - 0.5) * 0.06;

        let mut paint = |x0: f32, y0: f32, x1: f32, y1: f32, v: f32| {
            let (xa, xb) = ((x0 * s) as i64, (x1 * s) as i64);
            let (ya, yb) = ((y0 * s) as i64, (y1 * s) as i64);
            for y in ya.max(0)..yb.min(self.side as i64) {
                for x in xa.max(0)..xb.min(self.side as i64) {
                    let p = &mut img[y as usize * self.side + x as usize];
                    *p = (*p + v).clamp(0.0, 1.0);
                }
            }
        };
        // Head (as a small box; at 32px circles and boxes are equivalent).
        paint(
            cx - head_r,
            top,
            cx + head_r,
            top + 2.0 * head_r,
            tone * 1.1,
        );
        // Torso.
        let torso_top = top + 2.0 * head_r + 0.01;
        paint(
            cx - torso_w / 2.0,
            torso_top,
            cx + torso_w / 2.0,
            torso_top + torso_h,
            tone,
        );
        // Legs (two, slightly apart, with lean).
        let leg_top = torso_top + torso_h;
        let gap = torso_w * 0.18;
        paint(
            cx - torso_w / 2.0 + lean,
            leg_top,
            cx - torso_w / 2.0 + leg_w + lean,
            leg_top + leg_h,
            tone * 0.95,
        );
        paint(
            cx + torso_w / 2.0 - leg_w - lean,
            leg_top,
            cx + torso_w / 2.0 - lean,
            leg_top + leg_h,
            tone * 0.95,
        );
        let _ = gap;
    }

    /// One body part of the person figure (head / torso / legs) — the
    /// Fragment OOD kind.
    fn draw_fragment(&self, img: &mut [f32], rng: &mut Pcg64) {
        let s = self.side as f32;
        let height = 0.5 + 0.3 * rng.next_f32();
        let cx = 0.25 + 0.5 * rng.next_f32();
        let top = 0.05 + (0.9 - height) * rng.next_f32();
        let contrast = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
        let tone = 0.35 * (0.6 + 0.4 * rng.next_f32()) * contrast;
        let head_r = height * 0.11;
        let torso_w = height * 0.16;
        let torso_h = height * 0.42;
        let mut paint = |x0: f32, y0: f32, x1: f32, y1: f32, v: f32| {
            let (xa, xb) = ((x0 * s) as i64, (x1 * s) as i64);
            let (ya, yb) = ((y0 * s) as i64, (y1 * s) as i64);
            for y in ya.max(0)..yb.min(self.side as i64) {
                for x in xa.max(0)..xb.min(self.side as i64) {
                    let p = &mut img[y as usize * self.side + x as usize];
                    *p = (*p + v).clamp(0.0, 1.0);
                }
            }
        };
        match rng.next_below(3) {
            0 => paint(cx - head_r, top, cx + head_r, top + 2.0 * head_r, tone * 1.1),
            1 => paint(
                cx - torso_w / 2.0,
                top,
                cx + torso_w / 2.0,
                top + torso_h,
                tone,
            ),
            _ => {
                let leg_w = torso_w * 0.38;
                let leg_h = height * 0.38;
                paint(
                    cx - torso_w / 2.0,
                    top,
                    cx - torso_w / 2.0 + leg_w,
                    top + leg_h,
                    tone * 0.95,
                );
                paint(
                    cx + torso_w / 2.0 - leg_w,
                    top,
                    cx + torso_w / 2.0,
                    top + leg_h,
                    tone * 0.95,
                );
            }
        }
    }

    /// Person-like distractor (pole / blob) in background images.
    fn draw_distractor(&self, img: &mut [f32], rng: &mut Pcg64) {
        let s = self.side;
        let tone = (0.3 + 0.4 * rng.next_f32()) * if rng.next_bool(0.5) { 1.0 } else { -1.0 };
        if rng.next_bool(0.5) {
            // Vertical pole: right aspect, no articulation.
            let w = 1 + rng.next_below(2) as usize;
            let h = s / 2 + rng.next_below((s / 3) as u64) as usize;
            let x0 = rng.next_below((s - w) as u64) as usize;
            let y0 = rng.next_below((s - h).max(1) as u64) as usize;
            for y in y0..(y0 + h).min(s) {
                for x in x0..x0 + w {
                    let p = &mut img[y * s + x];
                    *p = (*p + tone as f32).clamp(0.0, 1.0);
                }
            }
        } else {
            // Square blob: wrong aspect.
            let w = s / 4 + rng.next_below((s / 4) as u64) as usize;
            let x0 = rng.next_below((s - w) as u64) as usize;
            let y0 = rng.next_below((s - w) as u64) as usize;
            for y in y0..y0 + w {
                for x in x0..x0 + w {
                    let p = &mut img[y * s + x];
                    *p = (*p + tone as f32 * 0.8).clamp(0.0, 1.0);
                }
            }
        }
    }

    /// OOD textures keep first-order statistics close to the training
    /// distribution (mean ≈ 0.5, moderate contrast): out-of-distribution
    /// *structure*, not saturating brightness — otherwise the feature
    /// extractor rails and margins explode, which is not what natural
    /// OOD images (the INRIA analogue) do.
    fn texture(&self, rng: &mut Pcg64) -> Vec<f32> {
        let s = self.side;
        let period = 2 + rng.next_below(5) as usize;
        let checker = rng.next_bool(0.5);
        let mid = 0.4 + 0.2 * rng.next_f32();
        let amp = 0.08 + 0.1 * rng.next_f32();
        let mut img: Vec<f32> = (0..s * s)
            .map(|i| {
                let (x, y) = (i % s, i / s);
                let v = if checker {
                    ((x / period) + (y / period)) % 2
                } else {
                    (x / period) % 2
                };
                if v == 0 {
                    mid - amp
                } else {
                    mid + amp
                }
            })
            .collect();
        for p in img.iter_mut() {
            *p = (*p + 0.03 * rng.next_gaussian() as f32).clamp(0.0, 1.0);
        }
        img
    }

    /// Sensor noise + clamp.
    fn post(&self, img: &mut [f32], rng: &mut Pcg64) {
        for p in img.iter_mut() {
            *p = (*p + 0.03 * rng.next_gaussian() as f32).clamp(0.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn deterministic_generation() {
        let g = SyntheticPerson::new(32, 42);
        let a = g.sample(7);
        let b = g.sample(7);
        assert_eq!(a.pixels, b.pixels);
        let c = g.sample(8);
        assert_ne!(a.pixels, c.pixels);
        let g2 = SyntheticPerson::new(32, 43);
        assert_ne!(a.pixels, g2.sample(7).pixels);
    }

    #[test]
    fn balanced_labels_and_bounds() {
        let g = SyntheticPerson::new(32, 1);
        let ds = g.split(0, 100);
        let persons = ds.samples.iter().filter(|s| s.label == 1).count();
        assert_eq!(persons, 50);
        for s in &ds.samples {
            assert_eq!(s.pixels.len(), 32 * 32);
            for &p in &s.pixels {
                assert!((0.0..=1.0).contains(&p));
            }
        }
    }

    #[test]
    fn person_figure_is_vertically_elongated() {
        // Unit-test the generator directly: paint a figure on a flat
        // canvas and check the changed region has person-like aspect.
        let g = SyntheticPerson::new(32, 5);
        for seed_idx in 0..20u64 {
            let mut rng = crate::util::rng::Pcg64::with_stream(seed_idx, 0xFACE);
            let mut img = vec![0.5f32; 32 * 32];
            g.draw_person(&mut img, &mut rng);
            let (mut x0, mut x1, mut y0, mut y1) = (32usize, 0usize, 32usize, 0usize);
            let mut changed = 0usize;
            for y in 0..32 {
                for x in 0..32 {
                    if (img[y * 32 + x] - 0.5).abs() > 0.05 {
                        changed += 1;
                        x0 = x0.min(x);
                        x1 = x1.max(x);
                        y0 = y0.min(y);
                        y1 = y1.max(y);
                    }
                }
            }
            assert!(changed > 20, "figure must paint pixels (got {changed})");
            let h = (y1 - y0 + 1) as f64;
            let w = (x1 - x0 + 1) as f64;
            assert!(
                h / w > 1.4,
                "figure must be vertically elongated: h={h} w={w}"
            );
        }
    }

    #[test]
    fn class_pixel_statistics_are_close() {
        // Trivial first-moment shortcuts must NOT separate the classes —
        // the task should require shape, not brightness.
        let g = SyntheticPerson::new(32, 6);
        let mut p_mean = Summary::new();
        let mut b_mean = Summary::new();
        for i in 0..300 {
            let s = g.sample(i);
            let m = s.pixels.iter().map(|&p| p as f64).sum::<f64>() / 1024.0;
            if s.label == 1 {
                p_mean.push(m);
            } else {
                b_mean.push(m);
            }
        }
        let gap = (p_mean.mean() - b_mean.mean()).abs();
        assert!(
            gap < 0.05,
            "class mean-brightness gap {gap:.4} should be small (no trivial cue)"
        );
    }

    #[test]
    fn ood_kinds_generate() {
        let g = SyntheticPerson::new(32, 9);
        let ood = g.ood_split(0, 12);
        assert_eq!(ood.len(), 12);
        let kinds: Vec<_> = ood.samples.iter().map(|s| s.ood.unwrap()).collect();
        assert!(kinds.contains(&OodKind::Fragment));
        assert!(kinds.contains(&OodKind::Texture));
        assert!(kinds.contains(&OodKind::Inverted));
        assert!(kinds.contains(&OodKind::Noise));
        // Inverted really inverts.
        let base = g.sample(1);
        let inv = g.ood_sample(1, OodKind::Inverted);
        for (a, b) in base.pixels.iter().zip(inv.pixels.iter()) {
            assert!((a + b - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn splits_are_disjoint_by_offset() {
        let g = SyntheticPerson::new(32, 2);
        let train = g.split(0, 10);
        let test = g.split(10, 10);
        for (a, b) in train.samples.iter().zip(test.samples.iter()) {
            assert_ne!(a.pixels, b.pixels);
        }
    }
}
