//! The partial-Bayesian MobileNet-mini model (§III-A): a deterministic
//! depthwise-separable feature extractor + a Bayesian FC classifier head.
//!
//! Weights load from `artifacts/weights.json` (written by
//! `python/compile/train.py`); [`Model::random`] builds an untrained model
//! for tests and benches that must not depend on artifacts.

use crate::bayes::{aggregate_mc, softmax, McPrediction};
use crate::config::ChipConfig;
use crate::error::{Error, Result};
use crate::nn::bayes_dense::BayesDense;
use crate::nn::layers;
use crate::nn::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::{Rng64, Xoshiro256};
use std::path::Path;

/// One feature-extractor layer.
#[derive(Clone)]
pub enum FeatLayer {
    /// Standard conv (weights HWIO) + bias + ReLU6.
    Conv {
        w: Tensor,
        b: Vec<f32>,
        stride: usize,
    },
    /// Depthwise conv (weights HWC) + bias + ReLU6.
    Depthwise {
        w: Tensor,
        b: Vec<f32>,
        stride: usize,
    },
    /// Global average pool.
    Gap,
}

/// Full model: features + Bayesian head + deterministic comparison head.
///
/// Cloning a *mapped* model is cheap on the head side: each
/// `BayesDense`'s weight/calibration layer lives behind `Arc`s
/// (copy-on-calibrate — see `cim::tile`), so the clone shares that
/// storage and copies only stream state, ε scratch, and the (small)
/// feature-extractor tensors. `runtime::SharedModelCache` leans on this
/// to make supervisor respawns reuse the boot-time calibration.
#[derive(Clone)]
pub struct Model {
    pub features: Vec<FeatLayer>,
    /// Bayesian classifier head (the chip's CIM layers).
    pub head: Vec<BayesDense>,
    /// Deterministic head trained without VI (the "standard NN" arm of
    /// Fig. 10–11).
    pub det_head: Vec<(Vec<f32>, Vec<f32>, usize, usize, bool)>,
    pub classes: usize,
    pub feature_dim: usize,
    pub image_side: usize,
    /// Activation range fed to the quantizer (ReLU6 ⇒ 6.0).
    pub act_max: f32,
}

impl Model {
    // ------------------------------------------------------------------
    // Construction
    // ------------------------------------------------------------------

    /// Load from a weights JSON artifact.
    pub fn load(path: &Path) -> Result<Model> {
        let doc = Json::read_file(path).map_err(|e| Error::Model(e.to_string()))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<Model> {
        let meta = doc
            .get("meta")
            .ok_or_else(|| Error::Model("missing 'meta'".into()))?;
        let classes = meta
            .get("classes")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| Error::Model("meta.classes missing".into()))?;
        let side = meta
            .get("side")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| Error::Model("meta.side missing".into()))?;
        let feature_dim = meta
            .get("feature_dim")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| Error::Model("meta.feature_dim missing".into()))?;
        let act_max = meta
            .get("act_max")
            .and_then(|v| v.as_f64())
            .unwrap_or(6.0) as f32;

        let mut features = Vec::new();
        for (i, l) in doc
            .get("features")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Model("missing 'features'".into()))?
            .iter()
            .enumerate()
        {
            let kind = l
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| Error::Model(format!("features[{i}].kind missing")))?;
            match kind {
                "gap" => features.push(FeatLayer::Gap),
                "conv" | "dw" => {
                    let shape = l
                        .get("w_shape")
                        .and_then(|v| v.as_usize_vec())
                        .ok_or_else(|| Error::Model(format!("features[{i}].w_shape")))?;
                    let w = l
                        .get("w")
                        .and_then(|v| v.as_f32_vec())
                        .ok_or_else(|| Error::Model(format!("features[{i}].w")))?;
                    let b = l
                        .get("b")
                        .and_then(|v| v.as_f32_vec())
                        .ok_or_else(|| Error::Model(format!("features[{i}].b")))?;
                    let stride = l.get("stride").and_then(|v| v.as_usize()).unwrap_or(1);
                    let t = Tensor::new(&shape, w);
                    if kind == "conv" {
                        features.push(FeatLayer::Conv { w: t, b, stride });
                    } else {
                        features.push(FeatLayer::Depthwise { w: t, b, stride });
                    }
                }
                other => {
                    return Err(Error::Model(format!("unknown feature layer kind '{other}'")))
                }
            }
        }

        let mut head = Vec::new();
        for (i, l) in doc
            .at(&["head", "layers"])
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Model("missing 'head.layers'".into()))?
            .iter()
            .enumerate()
        {
            let in_dim = l.get("in").and_then(|v| v.as_usize()).unwrap_or(0);
            let out_dim = l.get("out").and_then(|v| v.as_usize()).unwrap_or(0);
            let mu = l
                .get("mu")
                .and_then(|v| v.as_f32_vec())
                .ok_or_else(|| Error::Model(format!("head[{i}].mu")))?;
            let sigma = l
                .get("sigma")
                .and_then(|v| v.as_f32_vec())
                .ok_or_else(|| Error::Model(format!("head[{i}].sigma")))?;
            let bias = l
                .get("bias")
                .and_then(|v| v.as_f32_vec())
                .ok_or_else(|| Error::Model(format!("head[{i}].bias")))?;
            let relu = l.get("relu").and_then(|v| v.as_bool()).unwrap_or(false);
            head.push(BayesDense::new(
                in_dim,
                out_dim,
                mu,
                sigma,
                bias,
                relu,
                0xBA7E5 + i as u64,
            ));
        }

        let mut det_head = Vec::new();
        if let Some(layers) = doc.at(&["det_head", "layers"]).and_then(|v| v.as_arr()) {
            for (i, l) in layers.iter().enumerate() {
                let in_dim = l.get("in").and_then(|v| v.as_usize()).unwrap_or(0);
                let out_dim = l.get("out").and_then(|v| v.as_usize()).unwrap_or(0);
                let w = l
                    .get("w")
                    .and_then(|v| v.as_f32_vec())
                    .ok_or_else(|| Error::Model(format!("det_head[{i}].w")))?;
                let bias = l
                    .get("bias")
                    .and_then(|v| v.as_f32_vec())
                    .ok_or_else(|| Error::Model(format!("det_head[{i}].bias")))?;
                let relu = l.get("relu").and_then(|v| v.as_bool()).unwrap_or(false);
                det_head.push((w, bias, in_dim, out_dim, relu));
            }
        }

        Ok(Model {
            features,
            head,
            det_head,
            classes,
            feature_dim,
            image_side: side,
            act_max,
        })
    }

    /// Random (untrained) model with the canonical architecture —
    /// conv(1→8,s2) dw(8) pw(8→16,s2) dw(16) pw(16→32,s2) dw(32)
    /// pw(32→64) gap → head 64→32→classes.
    pub fn random(side: usize, classes: usize, seed: u64) -> Model {
        let mut rng = Xoshiro256::new(seed);
        let mut conv = |kh: usize, kw: usize, cin: usize, cout: usize, stride: usize| {
            let fan_in = (kh * kw * cin) as f64;
            let std = (2.0 / fan_in).sqrt();
            let w: Vec<f32> = (0..kh * kw * cin * cout)
                .map(|_| (rng.next_gaussian() * std) as f32)
                .collect();
            FeatLayer::Conv {
                w: Tensor::new(&[kh, kw, cin, cout], w),
                b: vec![0.0; cout],
                stride,
            }
        };
        let mut rng2 = Xoshiro256::new(seed ^ 1);
        let mut dw = |c: usize, stride: usize| {
            let std = (2.0 / 9.0f64).sqrt();
            let w: Vec<f32> = (0..9 * c)
                .map(|_| (rng2.next_gaussian() * std) as f32)
                .collect();
            FeatLayer::Depthwise {
                w: Tensor::new(&[3, 3, c], w),
                b: vec![0.0; c],
                stride,
            }
        };
        let features = vec![
            conv(3, 3, 1, 8, 2),
            dw(8, 1),
            conv(1, 1, 8, 16, 2),
            dw(16, 1),
            conv(1, 1, 16, 32, 2),
            dw(32, 1),
            conv(1, 1, 32, 64, 1),
            FeatLayer::Gap,
        ];
        let head = vec![
            BayesDense::random(64, 32, true, seed ^ 2),
            BayesDense::random(32, classes, false, seed ^ 3),
        ];
        let mut rng3 = Xoshiro256::new(seed ^ 4);
        let mut det = |in_dim: usize, out_dim: usize, relu: bool| {
            let std = (2.0 / in_dim as f64).sqrt();
            let w: Vec<f32> = (0..in_dim * out_dim)
                .map(|_| (rng3.next_gaussian() * std) as f32)
                .collect();
            (w, vec![0.0; out_dim], in_dim, out_dim, relu)
        };
        let det_head = vec![det(64, 32, true), det(32, classes, false)];
        Model {
            features,
            head,
            det_head,
            classes,
            feature_dim: 64,
            image_side: side,
            act_max: 6.0,
        }
    }

    // ------------------------------------------------------------------
    // Forward passes
    // ------------------------------------------------------------------

    /// Run the deterministic feature extractor on one image.
    pub fn forward_features(&self, pixels: &[f32]) -> Vec<f32> {
        assert_eq!(pixels.len(), self.image_side * self.image_side);
        let mut t = Tensor::new(&[self.image_side, self.image_side, 1], pixels.to_vec());
        for layer in &self.features {
            t = match layer {
                FeatLayer::Conv { w, b, stride } => {
                    layers::relu6(layers::conv2d(&t, w, b, *stride))
                }
                FeatLayer::Depthwise { w, b, stride } => {
                    layers::relu6(layers::depthwise_conv(&t, w, b, *stride))
                }
                FeatLayer::Gap => layers::global_avg_pool(&t),
            };
        }
        t.data
    }

    /// Map the Bayesian head onto CIM hardware.
    pub fn map_head_to_hardware(&mut self, chip: &ChipConfig) {
        let act_max = self.act_max;
        for layer in &mut self.head {
            layer.map_to_hardware(chip, act_max);
        }
    }

    pub fn head_is_mapped(&self) -> bool {
        self.head.iter().all(|l| l.is_mapped())
    }

    /// Eagerly build every mapped head layer's SoA plane caches so that
    /// MC replicas cloned afterwards share them through their `Arc`s (a
    /// replica "boot" is then an `Arc::clone` + stream reseed — O(ε
    /// buffers), not O(weights)). Call after
    /// [`Model::map_head_to_hardware`], before replica fan-out.
    pub fn warm_head_planes(&mut self) {
        for layer in &mut self.head {
            layer.warm_planes();
        }
    }

    /// Bytes of `Arc`-shared head state (weights + static die planes),
    /// counted once per model however many replicas share it.
    pub fn head_bytes_shared(&self) -> usize {
        self.head.iter().map(|l| l.bytes_shared()).sum()
    }

    /// Bytes one replica of the head owns privately (ε buffers, RNG and
    /// ADC-noise streams, scratch).
    pub fn head_bytes_private(&self) -> usize {
        self.head.iter().map(|l| l.bytes_private()).sum()
    }

    /// Aggregate energy ledger across every mapped head layer's tiles
    /// (empty if the head is unmapped). Non-destructive: repeated reads
    /// return the same cumulative totals.
    pub fn head_ledger(&self) -> crate::energy::EnergyLedger {
        let mut total = crate::energy::EnergyLedger::new();
        for layer in &self.head {
            total.absorb(&layer.ledger());
        }
        total
    }

    /// Zero the mapped head layers' energy ledgers (drop bring-up costs
    /// before metering serving traffic).
    pub fn reset_head_ledgers(&mut self) {
        for layer in &mut self.head {
            layer.reset_ledgers();
        }
    }

    /// One MC sample through the Bayesian head (hardware sim).
    pub fn head_sample_hw(&mut self, features: &[f32]) -> Vec<f64> {
        head_sample_layers(&mut self.head, features)
    }

    /// `t` hardware MC samples of the same features — the batched fast
    /// path. The first head layer (whose input is shared by every sample)
    /// runs through [`BayesDense::forward_hw_mc`], amortizing IDAC drives,
    /// plane caches and ledger deposits across the batch; deeper layers
    /// see per-sample activations and run per sample. Sample `s` is
    /// bit-identical to the `s`-th of `t` sequential
    /// [`Model::head_sample_hw`] calls (each layer's tile streams are
    /// consumed in the same sample order either way).
    pub fn head_samples_hw(&mut self, features: &[f32], t: usize) -> Vec<Vec<f64>> {
        head_sample_layers_mc(&mut self.head, features, t)
    }

    /// One MC sample through the Bayesian head (float reference).
    pub fn head_sample_ref(&mut self, features: &[f32]) -> Vec<f64> {
        let mut x = features.to_vec();
        for layer in &mut self.head {
            x = layer.forward_ref(&x);
        }
        softmax(&x.iter().map(|&v| v as f64).collect::<Vec<_>>())
    }

    /// Deterministic-head prediction (the standard-NN arm).
    pub fn predict_det(&self, features: &[f32]) -> Vec<f64> {
        let mut x = features.to_vec();
        for (w, b, in_dim, out_dim, relu) in &self.det_head {
            assert_eq!(x.len(), *in_dim);
            x = layers::dense(&x, w, b, *out_dim);
            if *relu {
                for v in x.iter_mut() {
                    *v = v.max(0.0);
                }
            }
        }
        softmax(&x.iter().map(|&v| v as f64).collect::<Vec<_>>())
    }

    /// Full Bayesian inference: features once, then T MC head samples
    /// (the hardware arm takes the batched [`Model::head_samples_hw`]
    /// fast path — bit-identical to T sequential samples).
    pub fn predict_bayes(&mut self, pixels: &[f32], t: usize, hw: bool) -> McPrediction {
        let features = self.forward_features(pixels);
        let samples: Vec<Vec<f64>> = if hw {
            self.head_samples_hw(&features, t)
        } else {
            (0..t).map(|_| self.head_sample_ref(&features)).collect()
        };
        aggregate_mc(&samples)
    }

    /// μ-only prediction through the Bayesian head (ablation: BNN weights
    /// without sampling).
    pub fn predict_mean(&self, pixels: &[f32]) -> Vec<f64> {
        let features = self.forward_features(pixels);
        let mut x = features;
        for layer in &self.head {
            x = layer.forward_mean(&x);
        }
        softmax(&x.iter().map(|&v| v as f64).collect::<Vec<_>>())
    }
}

/// One MC sample through a stack of Bayesian layers (hardware sim).
/// Free function so MC-parallel engine replicas — plain `Vec<BayesDense>`
/// clones with reseeded streams — share the exact sampling code of
/// [`Model::head_sample_hw`].
pub fn head_sample_layers(layers: &mut [BayesDense], features: &[f32]) -> Vec<f64> {
    let mut x = features.to_vec();
    for layer in layers.iter_mut() {
        x = layer.forward_hw(&x, true);
    }
    softmax(&x.iter().map(|&v| v as f64).collect::<Vec<_>>())
}

/// `t` MC samples of the same features through a stack of Bayesian
/// layers — the batched fast path behind [`Model::head_samples_hw`] and
/// the cim engine's MC fan-out. The first layer (shared input across
/// samples) runs through `BayesDense::forward_hw_mc`, which amortizes
/// activation quantization, IDAC drives, plane caches and ledger deposits
/// and — at `t >= 4` on full-size banks — double-buffers ε generation
/// against the MVM;
/// deeper layers see per-sample activations and run per sample. Sample
/// `s` is bit-identical to the `s`-th of `t` sequential
/// [`head_sample_layers`] calls (each layer's tile streams advance in the
/// same sample order either way).
pub fn head_sample_layers_mc(
    layers: &mut [BayesDense],
    features: &[f32],
    t: usize,
) -> Vec<Vec<f64>> {
    let Some((first, rest)) = layers.split_first_mut() else {
        let logits: Vec<f64> = features.iter().map(|&v| v as f64).collect();
        return (0..t).map(|_| softmax(&logits)).collect();
    };
    let mut acts = first.forward_hw_mc(features, t, true);
    for layer in rest.iter_mut() {
        for a in acts.iter_mut() {
            *a = layer.forward_hw(a, true);
        }
    }
    acts.iter()
        .map(|x| softmax(&x.iter().map(|&v| v as f64).collect::<Vec<_>>()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_model_shapes() {
        let m = Model::random(32, 2, 1);
        let px = vec![0.5f32; 32 * 32];
        let f = m.forward_features(&px);
        assert_eq!(f.len(), 64);
        let p = m.predict_det(&f);
        assert_eq!(p.len(), 2);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bayes_prediction_aggregates() {
        let mut m = Model::random(32, 2, 2);
        let px = vec![0.5f32; 32 * 32];
        let pred = m.predict_bayes(&px, 8, false);
        assert_eq!(pred.t, 8);
        assert_eq!(pred.probs.len(), 2);
        assert!(pred.entropy >= 0.0);
        assert!(pred.confidence > 0.0 && pred.confidence <= 1.0);
    }

    #[test]
    fn json_roundtrip_minimal() {
        // Build a tiny model JSON by hand and load it.
        let doc = Json::parse(
            r#"{
            "meta": {"classes": 2, "side": 16, "feature_dim": 4, "act_max": 6.0},
            "features": [
                {"kind": "conv", "stride": 2,
                 "w_shape": [1, 1, 1, 4],
                 "w": [0.1, -0.2, 0.3, 0.4], "b": [0, 0, 0, 0]},
                {"kind": "gap"}
            ],
            "head": {"layers": [
                {"in": 4, "out": 2, "relu": false,
                 "mu": [0.1, 0.2, 0.3, -0.1, 0.0, 0.5, -0.5, 0.2],
                 "sigma": [0.01, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01],
                 "bias": [0.0, 0.0]}
            ]},
            "det_head": {"layers": [
                {"in": 4, "out": 2, "relu": false,
                 "w": [0.1, 0.2, 0.3, -0.1, 0.0, 0.5, -0.5, 0.2],
                 "bias": [0.0, 0.0]}
            ]}
        }"#,
        )
        .unwrap();
        let mut m = Model::from_json(&doc).unwrap();
        assert_eq!(m.classes, 2);
        assert_eq!(m.head.len(), 1);
        let px = vec![0.3f32; 16 * 16];
        let pred = m.predict_bayes(&px, 4, false);
        assert_eq!(pred.probs.len(), 2);
    }

    #[test]
    fn missing_fields_rejected() {
        let doc = Json::parse(r#"{"meta": {"classes": 2}}"#).unwrap();
        assert!(Model::from_json(&doc).is_err());
    }

    #[test]
    fn batched_head_samples_match_sequential_bitwise() {
        let mut chip = ChipConfig::default();
        chip.tile.rows = 16;
        chip.tile.words_per_row = 4;
        let mut batched = Model::random(16, 2, 5);
        let mut serial = Model::random(16, 2, 5);
        batched.map_head_to_hardware(&chip);
        serial.map_head_to_hardware(&chip);
        let px = vec![0.5f32; 16 * 16];
        let f = batched.forward_features(&px);
        let t = 4;
        let ys = batched.head_samples_hw(&f, t);
        assert_eq!(ys.len(), t);
        for y in &ys {
            assert_eq!(y, &serial.head_sample_hw(&f));
        }
    }

    #[test]
    fn mean_prediction_deterministic() {
        let m = Model::random(32, 2, 7);
        let px = vec![0.25f32; 32 * 32];
        assert_eq!(m.predict_mean(&px), m.predict_mean(&px));
    }
}
