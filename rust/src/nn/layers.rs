//! Deterministic NN layers (the non-Bayesian feature extractor path).
//!
//! These run the MobileNet-style backbone natively in Rust — the fallback
//! / reference implementation of what the PJRT runtime executes from the
//! AOT-compiled artifact. Layout: HWC, weights HWIO (matching the JAX
//! model in `python/compile/model.py` so exported weights drop in).

use crate::nn::tensor::Tensor;

/// Standard 2-D convolution, stride `s`, SAME padding, weights HWIO.
pub fn conv2d(input: &Tensor, weights: &Tensor, bias: &[f32], stride: usize) -> Tensor {
    assert_eq!(input.shape.len(), 3, "conv2d expects HWC input");
    assert_eq!(weights.shape.len(), 4, "conv2d expects HWIO weights");
    let (h, w, cin) = (input.shape[0], input.shape[1], input.shape[2]);
    let (kh, kw, wcin, cout) = (
        weights.shape[0],
        weights.shape[1],
        weights.shape[2],
        weights.shape[3],
    );
    assert_eq!(cin, wcin, "channel mismatch");
    assert_eq!(bias.len(), cout);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let mut out = Tensor::zeros(&[oh, ow, cout]);
    // SAME padding offsets (TF convention).
    let pad_h = ((oh - 1) * stride + kh).saturating_sub(h) / 2;
    let pad_w = ((ow - 1) * stride + kw).saturating_sub(w) / 2;
    for oy in 0..oh {
        for ox in 0..ow {
            for co in 0..cout {
                let mut acc = bias[co];
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad_w as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        for ci in 0..cin {
                            acc += input.at3(iy as usize, ix as usize, ci)
                                * weights.data[((ky * kw + kx) * cin + ci) * cout + co];
                        }
                    }
                }
                *out.at3_mut(oy, ox, co) = acc;
            }
        }
    }
    out
}

/// Depthwise 3×3 convolution, stride `s`, SAME padding, weights HWC
/// (one filter per channel) — the MobileNet workhorse.
pub fn depthwise_conv(input: &Tensor, weights: &Tensor, bias: &[f32], stride: usize) -> Tensor {
    assert_eq!(input.shape.len(), 3);
    assert_eq!(weights.shape.len(), 3, "depthwise expects HWC weights");
    let (h, w, c) = (input.shape[0], input.shape[1], input.shape[2]);
    let (kh, kw, wc) = (weights.shape[0], weights.shape[1], weights.shape[2]);
    assert_eq!(c, wc, "channel mismatch");
    assert_eq!(bias.len(), c);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad_h = ((oh - 1) * stride + kh).saturating_sub(h) / 2;
    let pad_w = ((ow - 1) * stride + kw).saturating_sub(w) / 2;
    let mut out = Tensor::zeros(&[oh, ow, c]);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut acc = bias[ch];
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad_w as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        acc += input.at3(iy as usize, ix as usize, ch)
                            * weights.data[(ky * kw + kx) * c + ch];
                    }
                }
                *out.at3_mut(oy, ox, ch) = acc;
            }
        }
    }
    out
}

/// ReLU6 (MobileNet's bounded activation — important here because the
/// 4-bit activation quantizer needs a bounded range).
pub fn relu6(mut t: Tensor) -> Tensor {
    for v in t.data.iter_mut() {
        *v = v.clamp(0.0, 6.0);
    }
    t
}

/// Global average pooling: HWC → C.
pub fn global_avg_pool(input: &Tensor) -> Tensor {
    assert_eq!(input.shape.len(), 3);
    let (h, w, c) = (input.shape[0], input.shape[1], input.shape[2]);
    let mut out = vec![0.0f32; c];
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                out[ch] += input.at3(y, x, ch);
            }
        }
    }
    let norm = 1.0 / (h * w) as f32;
    for v in out.iter_mut() {
        *v *= norm;
    }
    Tensor::new(&[c], out)
}

/// Dense layer: y = W·x + b, weights [in × out] row-major.
pub fn dense(x: &[f32], weights: &[f32], bias: &[f32], out_dim: usize) -> Vec<f32> {
    let in_dim = x.len();
    assert_eq!(weights.len(), in_dim * out_dim);
    assert_eq!(bias.len(), out_dim);
    let mut y = bias.to_vec();
    for i in 0..in_dim {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &weights[i * out_dim..(i + 1) * out_dim];
        for (o, &wv) in y.iter_mut().zip(row.iter()) {
            *o += xi * wv;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv2d_identity_kernel() {
        // 1×1 kernel with weight 1 reproduces the input.
        let input = Tensor::new(&[2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(&[1, 1, 1, 1], vec![1.0]);
        let out = conv2d(&input, &w, &[0.0], 1);
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn conv2d_stride_and_padding_shape() {
        let input = Tensor::zeros(&[32, 32, 3]);
        let w = Tensor::zeros(&[3, 3, 3, 8]);
        let out = conv2d(&input, &w, &[0.0; 8], 2);
        assert_eq!(out.shape, vec![16, 16, 8]);
    }

    #[test]
    fn conv2d_known_sum() {
        // 3×3 all-ones kernel over all-ones 3×3 input, stride 1:
        // center output = 9, corner = 4 (SAME padding).
        let input = Tensor::new(&[3, 3, 1], vec![1.0; 9]);
        let w = Tensor::new(&[3, 3, 1, 1], vec![1.0; 9]);
        let out = conv2d(&input, &w, &[0.0], 1);
        assert_eq!(out.at3(1, 1, 0), 9.0);
        assert_eq!(out.at3(0, 0, 0), 4.0);
    }

    #[test]
    fn depthwise_keeps_channels_separate() {
        // Channel 0 kernel zero, channel 1 kernel identity-ish.
        let mut input = Tensor::zeros(&[3, 3, 2]);
        for y in 0..3 {
            for x in 0..3 {
                *input.at3_mut(y, x, 0) = 1.0;
                *input.at3_mut(y, x, 1) = 2.0;
            }
        }
        let mut w = Tensor::zeros(&[3, 3, 2]);
        w.data[(1 * 3 + 1) * 2 + 1] = 1.0; // center tap, channel 1
        let out = depthwise_conv(&input, &w, &[0.0, 0.0], 1);
        assert_eq!(out.at3(1, 1, 0), 0.0);
        assert_eq!(out.at3(1, 1, 1), 2.0);
    }

    #[test]
    fn relu6_clamps() {
        let t = Tensor::new(&[3], vec![-1.0, 3.0, 9.0]);
        assert_eq!(relu6(t).data, vec![0.0, 3.0, 6.0]);
    }

    #[test]
    fn gap_averages() {
        let input = Tensor::new(&[2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let out = global_avg_pool(&input);
        assert_eq!(out.data, vec![2.5]);
    }

    #[test]
    fn dense_matches_manual() {
        // W = [[1,2],[3,4]] (in=2, out=2), x = [1, 10], b = [0.5, -0.5]
        let y = dense(&[1.0, 10.0], &[1.0, 2.0, 3.0, 4.0], &[0.5, -0.5], 2);
        assert_eq!(y, vec![1.0 + 30.0 + 0.5, 2.0 + 40.0 - 0.5]);
    }
}
