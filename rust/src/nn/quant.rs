//! Activation quantization: float features → 4-bit IDAC input codes.
//!
//! The CIM tile consumes unsigned codes (the IDAC drives a wordline
//! voltage), so activations are quantized asymmetrically over [0, amax].
//! ReLU6 upstream guarantees non-negative bounded activations.

/// Quantizer for a bounded non-negative activation range.
#[derive(Clone, Copy, Debug)]
pub struct ActQuantizer {
    pub bits: usize,
    /// Float value of one code step.
    pub step: f32,
}

impl ActQuantizer {
    /// Build for activations in [0, amax].
    pub fn new(bits: usize, amax: f32) -> Self {
        assert!(bits >= 1 && bits <= 8);
        assert!(amax > 0.0);
        let levels = (1u32 << bits) - 1;
        Self {
            bits,
            step: amax / levels as f32,
        }
    }

    pub fn max_code(&self) -> u8 {
        ((1u32 << self.bits) - 1) as u8
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> u8 {
        let code = (x / self.step).round();
        code.clamp(0.0, self.max_code() as f32) as u8
    }

    #[inline]
    pub fn dequantize(&self, code: u8) -> f32 {
        code as f32 * self.step
    }

    pub fn quantize_vec(&self, xs: &[f32]) -> Vec<u8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Mean-squared quantization error over a batch (diagnostics).
    pub fn mse(&self, xs: &[f32]) -> f64 {
        xs.iter()
            .map(|&x| {
                let e = x - self.dequantize(self.quantize(x));
                (e * e) as f64
            })
            .sum::<f64>()
            / xs.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_on_grid() {
        let q = ActQuantizer::new(4, 6.0);
        assert_eq!(q.max_code(), 15);
        for code in 0..=15u8 {
            assert_eq!(q.quantize(q.dequantize(code)), code);
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let q = ActQuantizer::new(4, 6.0);
        assert_eq!(q.quantize(-1.0), 0);
        assert_eq!(q.quantize(100.0), 15);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let q = ActQuantizer::new(4, 6.0);
        for i in 0..100 {
            let x = i as f32 * 0.06;
            let err = (x - q.dequantize(q.quantize(x))).abs();
            assert!(err <= q.step / 2.0 + 1e-6);
        }
    }

    #[test]
    fn more_bits_less_mse() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.006) % 6.0).collect();
        let q4 = ActQuantizer::new(4, 6.0);
        let q2 = ActQuantizer::new(2, 6.0);
        assert!(q4.mse(&xs) < q2.mse(&xs) / 4.0);
    }
}
