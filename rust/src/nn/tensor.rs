//! Minimal dense tensor (HWC layout for images, flat for vectors).

/// A dense f32 tensor with explicit shape. Images use HWC layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// HWC accessor for 3-D tensors.
    #[inline]
    pub fn at3(&self, y: usize, x: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 3);
        let (_h, w, ch) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(y * w + x) * ch + c]
    }

    #[inline]
    pub fn at3_mut(&mut self, y: usize, x: usize, c: usize) -> &mut f32 {
        debug_assert_eq!(self.shape.len(), 3);
        let (_h, w, ch) = (self.shape[0], self.shape[1], self.shape[2]);
        &mut self.data[(y * w + x) * ch + c]
    }

    /// Flatten into a 1-D tensor (moves data).
    pub fn flatten(mut self) -> Tensor {
        let n = self.data.len();
        self.shape = vec![n];
        self
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_check() {
        let t = Tensor::new(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn bad_shape_panics() {
        let _ = Tensor::new(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn hwc_indexing() {
        let mut t = Tensor::zeros(&[2, 2, 3]);
        *t.at3_mut(1, 0, 2) = 5.0;
        assert_eq!(t.at3(1, 0, 2), 5.0);
        // position in flat data: (y*W + x)*C + c = (1*2+0)*3+2 = 8
        assert_eq!(t.data[8], 5.0);
    }

    #[test]
    fn flatten_preserves_data() {
        let t = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let f = t.flatten();
        assert_eq!(f.shape, vec![4]);
        assert_eq!(f.data, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
