//! The partial-Bayesian dense layer (§III-A, Eq. 4–5).
//!
//! Weight decomposition w = μ + σ·ε, executed three ways:
//!
//! - [`BayesDense::forward_hw`] — on the simulated CIM tile array
//!   (quantized inputs, in-word GRNG ε, analog non-idealities): the
//!   paper's chip.
//! - [`BayesDense::forward_ref`] — float reference with software ε
//!   (what the chip approximates).
//! - [`BayesDense::forward_mean`] — deterministic μ-only pass.

use crate::cim::{MvmOptions, TileArray, WeightScale};
use crate::config::ChipConfig;
use crate::nn::quant::ActQuantizer;
use crate::util::rng::{Rng64, Xoshiro256};
use std::sync::Arc;

/// One Bayesian FC layer.
///
/// `Clone` shares the immutable layer — float weights behind `Arc`s and
/// the mapped (calibrated) tile arrays' static planes — and copies only
/// the stream state (RNG positions, ε buffers, scratch, ledgers). An
/// MC-parallel replica is a clone followed by
/// [`BayesDense::reseed_streams`]: same die, independent sample streams,
/// O(ε buffers + streams) private bytes instead of O(weights).
#[derive(Clone)]
pub struct BayesDense {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Posterior means, row-major [in × out] (shared across replicas).
    pub mu: Arc<Vec<f32>>,
    /// Posterior standard deviations (≥ 0), row-major [in × out]
    /// (shared across replicas).
    pub sigma: Arc<Vec<f32>>,
    pub bias: Arc<Vec<f32>>,
    /// ReLU after this layer?
    pub relu: bool,
    /// Hardware mapping (lazy: built on first `forward_hw`).
    hw: Option<HwMapping>,
    rng: Xoshiro256,
}

#[derive(Clone)]
struct HwMapping {
    array: TileArray,
    scale: WeightScale,
    act_q: ActQuantizer,
}

impl BayesDense {
    pub fn new(
        in_dim: usize,
        out_dim: usize,
        mu: Vec<f32>,
        sigma: Vec<f32>,
        bias: Vec<f32>,
        relu: bool,
        seed: u64,
    ) -> Self {
        assert_eq!(mu.len(), in_dim * out_dim);
        assert_eq!(sigma.len(), in_dim * out_dim);
        assert_eq!(bias.len(), out_dim);
        assert!(sigma.iter().all(|&s| s >= 0.0), "σ must be non-negative");
        Self {
            in_dim,
            out_dim,
            mu: Arc::new(mu),
            sigma: Arc::new(sigma),
            bias: Arc::new(bias),
            relu,
            hw: None,
            rng: Xoshiro256::new(seed ^ 0xBA7E5),
        }
    }

    /// Random layer for tests (He-scaled μ, small σ).
    pub fn random(in_dim: usize, out_dim: usize, relu: bool, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let std = (2.0 / in_dim as f64).sqrt();
        let mu = (0..in_dim * out_dim)
            .map(|_| (rng.next_gaussian() * std) as f32)
            .collect();
        let sigma = (0..in_dim * out_dim)
            .map(|_| (rng.next_f64() * 0.3 * std) as f32)
            .collect();
        let bias = vec![0.0; out_dim];
        Self::new(in_dim, out_dim, mu, sigma, bias, relu, seed)
    }

    /// Map the layer onto CIM tiles with the given chip config and
    /// activation range, and calibrate (the chip's bring-up procedure).
    pub fn map_to_hardware(&mut self, chip: &ChipConfig, act_max: f32) {
        let mu_abs_max = self.mu.iter().fold(0.0f32, |a, &b| a.max(b.abs())) as f64;
        let sigma_max = self.sigma.iter().fold(0.0f32, |a, &b| a.max(b)) as f64;
        let scale = WeightScale::fit(
            mu_abs_max,
            sigma_max,
            chip.tile.mu_bits as u8,
            chip.tile.sigma_bits as u8,
        );
        let mut array = TileArray::new(chip, self.in_dim, self.out_dim);
        for t in array.tiles_mut() {
            // Bring-up calibration per tile (ADC offsets + GRNG ε₀).
            let _ = crate::cim::calibrate(t, 16, 32);
        }
        let mu_fixed: Vec<f64> = self
            .mu
            .iter()
            .map(|&m| (m as f64 * scale.mu_scale))
            .collect();
        let sigma_fixed: Vec<f64> = self
            .sigma
            .iter()
            .map(|&s| (s as f64 * scale.sigma_scale))
            .collect();
        array.program_matrix(&mu_fixed, &sigma_fixed);
        self.hw = Some(HwMapping {
            array,
            scale,
            act_q: ActQuantizer::new(chip.idac.bits, act_max),
        });
    }

    pub fn is_mapped(&self) -> bool {
        self.hw.is_some()
    }

    /// Hardware-simulated forward pass (one MC sample: fresh ε).
    pub fn forward_hw(&mut self, x: &[f32], bayesian: bool) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim);
        let hw = self
            .hw
            .as_mut()
            .expect("call map_to_hardware before forward_hw");
        let codes = hw.act_q.quantize_vec(x);
        let opts = MvmOptions {
            bayesian,
            refresh_epsilon: true,
            ideal_analog: false,
        };
        let y_fixed = hw.array.mvm(&codes, opts);
        // Recombine the two paths with their own scales (reduction-logic
        // shifts), then convert codes → float activations.
        let k_mu = hw.act_q.step as f64 / hw.scale.mu_scale;
        let k_sigma = hw.act_q.step as f64 / hw.scale.sigma_scale;
        finish_activation(&y_fixed, k_mu, k_sigma, &self.bias, self.relu)
    }

    /// `t` hardware MC samples of the *same* input — the batched fast
    /// path: activation quantization, IDAC drives, SoA plane caches and
    /// ledger deposits are amortized across the batch via
    /// [`TileArray::mvm_batch`], while ε is refreshed per sample (and,
    /// for `t >= 4` on full-size banks, generated on a producer thread
    /// in parallel with the previous sample's MVM — the tiles'
    /// double-buffered ε pipeline).
    /// Sample `s` is bit-identical to the `s`-th of `t` sequential
    /// [`BayesDense::forward_hw`] calls.
    pub fn forward_hw_mc(&mut self, x: &[f32], t: usize, bayesian: bool) -> Vec<Vec<f32>> {
        assert_eq!(x.len(), self.in_dim);
        let hw = self
            .hw
            .as_mut()
            .expect("call map_to_hardware before forward_hw_mc");
        let codes = hw.act_q.quantize_vec(x);
        let opts = MvmOptions {
            bayesian,
            refresh_epsilon: true,
            ideal_analog: false,
        };
        let k_mu = hw.act_q.step as f64 / hw.scale.mu_scale;
        let k_sigma = hw.act_q.step as f64 / hw.scale.sigma_scale;
        let results = hw.array.mvm_batch(&codes, t, opts);
        results
            .iter()
            .map(|y_fixed| finish_activation(y_fixed, k_mu, k_sigma, &self.bias, self.relu))
            .collect()
    }

    /// Reseed this layer's stochastic streams — the software ε RNG and,
    /// when mapped, every tile's GRNG/ADC-noise streams — from `seed`.
    /// Static die state (calibration, offsets, programmed words) is kept.
    pub fn reseed_streams(&mut self, seed: u64) {
        self.rng = Xoshiro256::new(seed ^ 0xBA7E5);
        if let Some(hw) = self.hw.as_mut() {
            hw.array.reseed_streams(seed ^ 0x4D43_5EED);
        }
    }

    /// Float reference forward pass with software ε ~ N(0,1).
    pub fn forward_ref(&mut self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim);
        // `to_vec`, not `clone`: cloning the `Arc` would alias the shared
        // bias vector and the += below would copy-on-write every call.
        let mut y = self.bias.to_vec();
        for i in 0..self.in_dim {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for o in 0..self.out_dim {
                let idx = i * self.out_dim + o;
                let eps = self.rng.next_gaussian() as f32;
                y[o] += xi * (self.mu[idx] + self.sigma[idx] * eps);
            }
        }
        if self.relu {
            for v in y.iter_mut() {
                *v = v.max(0.0);
            }
        }
        y
    }

    /// Deterministic μ-only forward pass.
    pub fn forward_mean(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim);
        let mut y = self.bias.to_vec();
        for i in 0..self.in_dim {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for o in 0..self.out_dim {
                y[o] += xi * self.mu[i * self.out_dim + o];
            }
        }
        if self.relu {
            for v in y.iter_mut() {
                *v = v.max(0.0);
            }
        }
        y
    }

    /// Aggregate energy ledger from the mapped tiles (empty if unmapped).
    pub fn ledger(&self) -> crate::energy::EnergyLedger {
        self.hw
            .as_ref()
            .map(|hw| hw.array.ledger())
            .unwrap_or_default()
    }

    /// Zero the mapped tiles' energy ledgers (e.g. to drop bring-up
    /// programming/calibration costs before metering serving traffic).
    pub fn reset_ledgers(&mut self) {
        if let Some(hw) = self.hw.as_mut() {
            hw.array.reset_ledgers();
        }
    }

    /// Mutable access to the mapped tile array (fidelity tests and
    /// hardware diagnostics; `None` until `map_to_hardware`).
    pub fn hw_array_mut(&mut self) -> Option<&mut TileArray> {
        self.hw.as_mut().map(|hw| &mut hw.array)
    }

    /// Eagerly build the mapped tiles' SoA plane caches so replica clones
    /// share them (no-op when unmapped). Call once after
    /// [`BayesDense::map_to_hardware`], before replica fan-out.
    pub fn warm_planes(&mut self) {
        if let Some(hw) = self.hw.as_mut() {
            hw.array.warm_planes();
        }
    }

    /// Bytes of `Arc`-shared state: float weights plus the mapped tiles'
    /// static die planes. Counted once per model.
    pub fn bytes_shared(&self) -> usize {
        (self.mu.len() + self.sigma.len() + self.bias.len()) * std::mem::size_of::<f32>()
            + self.hw.as_ref().map_or(0, |hw| hw.array.bytes_shared())
    }

    /// Bytes each replica owns privately (RNG state + the mapped tiles'
    /// ε buffers, noise streams, and scratch).
    pub fn bytes_private(&self) -> usize {
        std::mem::size_of::<Xoshiro256>()
            + self.hw.as_ref().map_or(0, |hw| hw.array.bytes_private())
    }

    /// True when `other` is a replica sharing this layer's immutable
    /// state by pointer identity (weights and, when mapped, every tile's
    /// static planes).
    pub fn shares_statics_with(&self, other: &BayesDense) -> bool {
        Arc::ptr_eq(&self.mu, &other.mu)
            && Arc::ptr_eq(&self.sigma, &other.sigma)
            && Arc::ptr_eq(&self.bias, &other.bias)
            && match (&self.hw, &other.hw) {
                (Some(a), Some(b)) => a.array.shares_statics_with(&b.array),
                (None, None) => true,
                _ => false,
            }
    }
}

/// Recombine a fixed-point MVM result into float activations (reduction
/// shifts → bias add → optional ReLU). The single post-MVM pipeline
/// shared by `forward_hw` and `forward_hw_mc`, so the batched and
/// sequential paths cannot drift apart.
fn finish_activation(
    y_fixed: &crate::cim::tile::MvmResult,
    k_mu: f64,
    k_sigma: f64,
    bias: &[f32],
    relu: bool,
) -> Vec<f32> {
    let combined = y_fixed.combined_scaled(k_mu, k_sigma);
    let mut y: Vec<f32> = combined
        .iter()
        .zip(bias.iter())
        .map(|(&v, &b)| v as f32 + b)
        .collect();
    if relu {
        for v in y.iter_mut() {
            *v = v.max(0.0);
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{pearson, Summary};

    fn small_chip() -> ChipConfig {
        let mut chip = ChipConfig::default();
        chip.tile.rows = 16;
        chip.tile.words_per_row = 4;
        chip
    }

    #[test]
    fn hw_tracks_mean_path_when_sigma_zero() {
        let mut layer = BayesDense::random(16, 4, false, 3);
        Arc::make_mut(&mut layer.sigma).iter_mut().for_each(|s| *s = 0.0);
        layer.map_to_hardware(&small_chip(), 6.0);
        let mut rng = Xoshiro256::new(9);
        let mut hw_out = Vec::new();
        let mut ref_out = Vec::new();
        for _ in 0..16 {
            let x: Vec<f32> = (0..16).map(|_| rng.next_f32() * 6.0).collect();
            hw_out.extend(layer.forward_hw(&x, true));
            ref_out.extend(layer.forward_mean(&x));
        }
        let r = pearson(
            &hw_out.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &ref_out.iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        assert!(r > 0.97, "hw vs mean r={r}");
    }

    #[test]
    fn hw_variance_matches_posterior_scale() {
        let mut layer = BayesDense::random(16, 4, false, 5);
        layer.map_to_hardware(&small_chip(), 6.0);
        let x: Vec<f32> = (0..16).map(|i| (i % 7) as f32 * 0.8).collect();
        // Hardware MC samples.
        let hw: Vec<f64> = (0..200)
            .map(|_| layer.forward_hw(&x, true)[1] as f64)
            .collect();
        // Reference MC samples.
        let rf: Vec<f64> = (0..200).map(|_| layer.forward_ref(&x)[1] as f64).collect();
        let s_hw = Summary::from_slice(&hw);
        let s_rf = Summary::from_slice(&rf);
        // Means should agree within combined error.
        let tol = 4.0 * (s_hw.sem() + s_rf.sem()) + 0.1 * s_rf.std().max(0.05);
        assert!(
            (s_hw.mean() - s_rf.mean()).abs() < tol.max(0.15),
            "hw mean {} vs ref mean {}",
            s_hw.mean(),
            s_rf.mean()
        );
        // Variance ratio within 2× (analog chain adds some noise).
        let ratio = s_hw.std() / s_rf.std().max(1e-9);
        assert!(
            (0.5..2.5).contains(&ratio),
            "σ ratio hw/ref = {ratio} ({} vs {})",
            s_hw.std(),
            s_rf.std()
        );
    }

    #[test]
    fn forward_hw_mc_matches_sequential_bitwise() {
        let mut batched = BayesDense::random(16, 4, true, 19);
        let mut serial = BayesDense::random(16, 4, true, 19);
        batched.map_to_hardware(&small_chip(), 6.0);
        serial.map_to_hardware(&small_chip(), 6.0);
        let x: Vec<f32> = (0..16).map(|i| (i % 5) as f32 * 1.1).collect();
        let t = 7;
        let ys = batched.forward_hw_mc(&x, t, true);
        assert_eq!(ys.len(), t);
        for y in &ys {
            assert_eq!(y, &serial.forward_hw(&x, true));
        }
    }

    #[test]
    fn reseeded_replica_keeps_statics_changes_samples() {
        let mut a = BayesDense::random(16, 4, false, 23);
        a.map_to_hardware(&small_chip(), 6.0);
        let mut b = a.clone();
        b.reseed_streams(0x5A5A);
        let x = vec![1.5f32; 16];
        // μ-only passes share the static die (ADC noise differs, so
        // compare the deterministic mean path instead).
        assert_eq!(a.forward_mean(&x), b.forward_mean(&x));
        // Bayesian samples diverge (independent ε streams).
        let yb = b.forward_hw(&x, true);
        assert_ne!(a.forward_hw(&x, true), yb);
        // Replica construction is deterministic (reseed resets streams).
        let mut c = a.clone();
        c.reseed_streams(0x5A5A);
        assert_eq!(yb, c.forward_hw(&x, true));
    }

    #[test]
    fn replica_clone_shares_weights_and_planes() {
        let mut a = BayesDense::random(16, 4, false, 29);
        a.map_to_hardware(&small_chip(), 6.0);
        a.warm_planes();
        let mut b = a.clone();
        b.reseed_streams(0x1CE);
        // The replica's clone cost is stream-sized, not weight-sized, and
        // the shared layer is identical by pointer, not just by value.
        assert!(a.shares_statics_with(&b));
        assert!(
            b.bytes_private() < a.bytes_shared(),
            "private {} must stay below shared {}",
            b.bytes_private(),
            a.bytes_shared()
        );
        let x = vec![1.5f32; 16];
        assert_eq!(a.forward_mean(&x), b.forward_mean(&x));
    }

    #[test]
    fn deterministic_pass_has_no_variance() {
        let mut layer = BayesDense::random(16, 4, false, 7);
        layer.map_to_hardware(&small_chip(), 6.0);
        let x = vec![1.0f32; 16];
        let a = layer.forward_mean(&x);
        let b = layer.forward_mean(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn relu_applied() {
        let mut layer = BayesDense::random(8, 4, true, 11);
        let x = vec![1.0f32; 8];
        let y = layer.forward_ref(&x);
        assert!(y.iter().all(|&v| v >= 0.0));
    }

    #[test]
    #[should_panic(expected = "map_to_hardware")]
    fn unmapped_hw_forward_panics() {
        let mut layer = BayesDense::random(8, 4, false, 13);
        let _ = layer.forward_hw(&vec![0.0; 8], true);
    }
}
