//! Quantized neural-network engine: the Rust-native reference path for
//! the partial-Bayesian MobileNet (feature extractor, Bayesian head on
//! the CIM simulator, activation quantization).

pub mod bayes_dense;
pub mod layers;
pub mod model;
pub mod quant;
pub mod tensor;

pub use bayes_dense::BayesDense;
pub use model::{FeatLayer, Model};
pub use quant::ActQuantizer;
pub use tensor::Tensor;
