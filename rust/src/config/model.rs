//! Model / inference configuration.

use super::{f64_field, string_field, usize_field};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Partial-Bayesian model configuration (§III-A: Bayesian weights only in
/// the final FC layers; feature extractor stays deterministic).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Directory containing AOT artifacts (HLO text + weights JSON).
    pub artifacts_dir: String,
    /// Monte-Carlo forward passes per inference.
    pub mc_samples: usize,
    /// Activation (input) precision \[bits\] — matches the IDAC.
    pub input_bits: usize,
    /// μ weight precision \[bits\].
    pub mu_bits: usize,
    /// σ weight precision \[bits\].
    pub sigma_bits: usize,
    /// Entropy threshold above which a classification is deferred
    /// (Fig. 11-right sweeps 0.0–0.6; default mid-range).
    pub defer_threshold: f64,
    /// Number of classes.
    pub classes: usize,
    /// Input image side (synthetic person dataset is square grayscale).
    pub image_side: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            mc_samples: 32,
            input_bits: 4,
            mu_bits: 8,
            sigma_bits: 4,
            defer_threshold: 0.45,
            classes: 2,
            image_side: 32,
        }
    }
}

impl ModelConfig {
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        string_field(doc, "artifacts_dir", &mut self.artifacts_dir)?;
        usize_field(doc, "mc_samples", &mut self.mc_samples)?;
        usize_field(doc, "input_bits", &mut self.input_bits)?;
        usize_field(doc, "mu_bits", &mut self.mu_bits)?;
        usize_field(doc, "sigma_bits", &mut self.sigma_bits)?;
        f64_field(doc, "defer_threshold", &mut self.defer_threshold)?;
        usize_field(doc, "classes", &mut self.classes)?;
        usize_field(doc, "image_side", &mut self.image_side)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.mc_samples == 0 {
            return Err(Error::Config("model: mc_samples must be > 0".into()));
        }
        if self.classes < 2 {
            return Err(Error::Config("model: classes must be >= 2".into()));
        }
        if !(0.0..=10.0).contains(&self.defer_threshold) {
            return Err(Error::Config(
                "model: defer_threshold must be in [0, 10]".into(),
            ));
        }
        if self.input_bits == 0 || self.mu_bits == 0 || self.sigma_bits == 0 {
            return Err(Error::Config("model: bit widths must be > 0".into()));
        }
        Ok(())
    }
}
