//! Serving / coordinator configuration.

use super::{bool_field, f64_field, string_field, u64_field, usize_field};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Which `InferenceEngine` the coordinator boots per shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust deterministic stand-in (`runtime::SimEngine`); ε is an
    /// external input supplied by per-shard GRNG-bank sources.
    Sim,
    /// Behavioral chip model (`runtime::CimEngine`): head MVMs on
    /// simulated CIM tiles with in-word ε and live energy ledgers.
    Cim,
    /// AOT-compiled XLA artifacts over PJRT (feature `pjrt`); ε is an
    /// external input, as with `Sim`.
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sim" => Ok(Backend::Sim),
            "cim" => Ok(Backend::Cim),
            "pjrt" => Ok(Backend::Pjrt),
            other => Err(Error::Config(format!(
                "server.backend must be one of sim | cim | pjrt, got '{other}'"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Cim => "cim",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Coordinator (L3 serving engine) configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Engine backend booted per shard (`serve --backend` overrides).
    /// Default stays `pjrt`, the historical `Coordinator::start` path.
    pub backend: Backend,
    /// Maximum requests fused into one batched executable call.
    pub max_batch: usize,
    /// Batching deadline \[ms\]: a partial batch is dispatched after this.
    pub batch_deadline_ms: f64,
    /// Request queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
    /// Shard workers: each owns an engine plus an independent per-shard
    /// GRNG bank (ε source) seeded via a SplitMix64 split of `die_seed`.
    pub workers: usize,
    /// Upper bound on per-request `mc_samples`; larger requests are
    /// rejected at submit so one request cannot inflate the MC pass count
    /// of the whole fused batch.
    pub max_mc_samples: usize,
    /// MC-parallel replicas per `cim` engine: each shard's engine clones
    /// its calibrated head arrays this many times with split ε/noise
    /// streams and fans batch slots (independent MC passes) across them
    /// on scoped threads. Part of the determinism contract: replay is
    /// bit-identical for a fixed `(die_seed, workers, mc_workers)` — a
    /// *fixed* default (never host CPU count) keeps replay portable.
    pub mc_workers: usize,
    /// Elastic capacity: when true the dispatcher autoscales each
    /// shard's MC-replica pool between `min_mc_workers` and
    /// `max_mc_workers` against queue depth, and idle shard workers
    /// steal queued batches from overloaded peers. Replica clones share
    /// the calibrated weight/calibration layer behind `Arc`s, so a scale
    /// event costs O(ε buffers), not O(weights). Default OFF: the static
    /// pool keeps the bit-identical replay contract on
    /// `(die_seed, workers, mc_workers)`. With elasticity ON the result
    /// *distribution* is unchanged (every replica stream is a fixed
    /// function of its index) but slot→replica assignment follows load,
    /// so replay is banded, not bitwise — see DESIGN.md §10.
    pub elastic: bool,
    /// Elastic floor for the per-shard MC-replica pool (≥ 1).
    pub min_mc_workers: usize,
    /// Elastic ceiling for the per-shard MC-replica pool
    /// (≥ `mc_workers` ≥ `min_mc_workers`).
    pub max_mc_workers: usize,
    /// Per-request deadline \[ms\]; exceeded requests are rejected.
    pub request_timeout_ms: f64,
    /// Network-edge listen address (`host:port`; port 0 = ephemeral).
    /// Empty string (the default) means no edge: in-process serving only.
    /// `serve --listen` overrides.
    pub listen: String,
    /// Edge HTTP worker threads (connections served concurrently).
    pub edge_threads: usize,
    /// Load fraction (`queue_depth / queue_capacity`) at or above which
    /// the edge degrades requests to `edge_degraded_mc_samples` cheap
    /// passes and lets the `UncertaintyReport` verdict decide escalation.
    pub edge_degrade_load: f64,
    /// Load fraction at or above which the edge sheds requests outright
    /// (429 + `Retry-After`). Must be ≥ `edge_degrade_load`.
    pub edge_shed_load: f64,
    /// MC passes used for a degraded (cheap) admission pass.
    pub edge_degraded_mc_samples: usize,
    /// `Retry-After` hint \[ms\] sent with shed (429) responses.
    pub edge_retry_after_ms: u64,
    /// Largest accepted request body \[bytes\] (413 beyond this).
    pub edge_max_body_bytes: usize,
    /// How many times a request recovered from a failed shard is
    /// redelivered before the client sees `ServeError::ShardFailed`.
    /// Inference is pure, so redelivery is safe; the deadline carried by
    /// the request still bounds the total time budget across retries.
    pub retry_budget: usize,
    /// How many times the supervisor respawns a crashed shard worker
    /// before declaring the shard `dead` (0 = never respawn). Each
    /// respawn re-seeds the shard from its original deterministic
    /// `shard_die_seed` split.
    pub shard_restart_limit: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            backend: Backend::Pjrt,
            max_batch: 16,
            batch_deadline_ms: 2.0,
            queue_capacity: 256,
            workers: 1,
            max_mc_samples: 256,
            mc_workers: 4,
            elastic: false,
            min_mc_workers: 1,
            max_mc_workers: 8,
            request_timeout_ms: 1000.0,
            listen: String::new(),
            edge_threads: 4,
            edge_degrade_load: 0.6,
            edge_shed_load: 0.9,
            edge_degraded_mc_samples: 4,
            edge_retry_after_ms: 250,
            edge_max_body_bytes: 8 << 20,
            retry_budget: 1,
            shard_restart_limit: 8,
        }
    }
}

impl ServerConfig {
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        if let Some(v) = doc.get("backend") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("field 'backend' must be a string".into()))?;
            self.backend = Backend::parse(s)?;
        }
        usize_field(doc, "max_batch", &mut self.max_batch)?;
        f64_field(doc, "batch_deadline_ms", &mut self.batch_deadline_ms)?;
        usize_field(doc, "queue_capacity", &mut self.queue_capacity)?;
        usize_field(doc, "workers", &mut self.workers)?;
        usize_field(doc, "max_mc_samples", &mut self.max_mc_samples)?;
        usize_field(doc, "mc_workers", &mut self.mc_workers)?;
        bool_field(doc, "elastic", &mut self.elastic)?;
        usize_field(doc, "min_mc_workers", &mut self.min_mc_workers)?;
        usize_field(doc, "max_mc_workers", &mut self.max_mc_workers)?;
        f64_field(doc, "request_timeout_ms", &mut self.request_timeout_ms)?;
        string_field(doc, "listen", &mut self.listen)?;
        usize_field(doc, "edge_threads", &mut self.edge_threads)?;
        f64_field(doc, "edge_degrade_load", &mut self.edge_degrade_load)?;
        f64_field(doc, "edge_shed_load", &mut self.edge_shed_load)?;
        usize_field(
            doc,
            "edge_degraded_mc_samples",
            &mut self.edge_degraded_mc_samples,
        )?;
        u64_field(doc, "edge_retry_after_ms", &mut self.edge_retry_after_ms)?;
        usize_field(doc, "edge_max_body_bytes", &mut self.edge_max_body_bytes)?;
        usize_field(doc, "retry_budget", &mut self.retry_budget)?;
        usize_field(doc, "shard_restart_limit", &mut self.shard_restart_limit)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            return Err(Error::Config("server: max_batch must be > 0".into()));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Config("server: queue_capacity must be > 0".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("server: workers must be > 0".into()));
        }
        if self.max_mc_samples == 0 {
            return Err(Error::Config("server: max_mc_samples must be > 0".into()));
        }
        if self.mc_workers == 0 {
            return Err(Error::Config("server: mc_workers must be > 0".into()));
        }
        if self.min_mc_workers == 0
            || self.min_mc_workers > self.mc_workers
            || self.mc_workers > self.max_mc_workers
        {
            return Err(Error::Config(
                "server: need 1 <= min_mc_workers <= mc_workers <= max_mc_workers".into(),
            ));
        }
        if self.batch_deadline_ms < 0.0 || self.request_timeout_ms <= 0.0 {
            return Err(Error::Config("server: invalid timeouts".into()));
        }
        if self.edge_threads == 0 {
            return Err(Error::Config("server: edge_threads must be > 0".into()));
        }
        // 0.0 thresholds are legal (degrade/shed everything — used by
        // overload tests); the invariant is only the band ordering.
        if !self.edge_degrade_load.is_finite()
            || !self.edge_shed_load.is_finite()
            || self.edge_degrade_load < 0.0
            || self.edge_shed_load < self.edge_degrade_load
        {
            return Err(Error::Config(
                "server: edge loads must satisfy 0 <= edge_degrade_load <= edge_shed_load".into(),
            ));
        }
        if self.edge_degraded_mc_samples == 0 || self.edge_degraded_mc_samples > self.max_mc_samples
        {
            return Err(Error::Config(
                "server: edge_degraded_mc_samples must be in [1, max_mc_samples]".into(),
            ));
        }
        if self.edge_max_body_bytes == 0 {
            return Err(Error::Config(
                "server: edge_max_body_bytes must be > 0".into(),
            ));
        }
        Ok(())
    }
}
