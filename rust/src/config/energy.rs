//! Per-operation energy and area tables (65 nm), plus technology scaling.
//!
//! The fabricated chip reports aggregate numbers (Tab. II, Fig. 12); our
//! simulator regenerates them from per-operation costs. Values are
//! Horowitz-style estimates for a commercial 65 nm node, tuned so the
//! defaults land on the paper's headline figures:
//!   - 360 fJ/GRNG sample (from the GRNG physics model, not this table)
//!   - 672 fJ/Op NN efficiency over a 64×8 MVM
//!   - 0.45 mm² total area with SRAM ≈ 48 % of tile area (Fig. 12)
//!   - SRAM > 63 % of tile energy per MVM (Fig. 12)

use super::f64_field;
use crate::error::Result;
use crate::util::json::Json;

/// The prototype's technology node \[nm\].
pub const TECH_NODE_NM: f64 = 65.0;

/// Per-operation energies \[J\]. "One MVM" means the single-cycle 64-row
/// parallel operation of §III-B.
#[derive(Clone, Debug)]
pub struct EnergyTable {
    /// SRAM cell read contribution during one MVM, per cell \[J\]
    /// (bitline discharge share of one 8T cell conducting for the
    /// integration window).
    pub sram_cell_read_j: f64,
    /// SRAM cell write \[J\] (used during programming / calibration).
    pub sram_cell_write_j: f64,
    /// Bitline precharge per column per MVM \[J\] (C_BL · V_DD²).
    pub bitline_precharge_j: f64,
    /// Digital reduction logic per output word per MVM \[J\].
    pub reduction_word_j: f64,
    /// Transmission-gate / switch overhead per σε word per MVM \[J\].
    pub switch_word_j: f64,
    /// Leakage power of the tile \[W\] (counted against MVM time).
    pub tile_leakage_w: f64,
    /// Host-side DRAM access per byte \[J\] — used for the conventional-BNN
    /// comparison in Fig. 2 (weights streamed per sample).
    pub dram_access_per_byte_j: f64,
    /// Generic digital 8-bit MAC at 65 nm \[J\] — baseline NN cost model.
    pub digital_mac8_j: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        Self {
            // Analog current-mode read: E ≈ I_cell·V_DD·t_window — much
            // larger than a digital read. 64·8·20 cells/tile; calibrated
            // so SRAM is >63 % of MVM energy (Fig. 12) and total lands on
            // 672 fJ/Op (Tab. II).
            sram_cell_read_j: 42.0e-15,
            sram_cell_write_j: 1.8e-15,
            bitline_precharge_j: 2.2e-15,
            reduction_word_j: 18.0e-15,
            switch_word_j: 2.5e-15,
            tile_leakage_w: 35.0e-6,
            dram_access_per_byte_j: 20.0e-12,
            digital_mac8_j: 250.0e-15,
        }
    }
}

impl EnergyTable {
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        f64_field(doc, "sram_cell_read_j", &mut self.sram_cell_read_j)?;
        f64_field(doc, "sram_cell_write_j", &mut self.sram_cell_write_j)?;
        f64_field(doc, "bitline_precharge_j", &mut self.bitline_precharge_j)?;
        f64_field(doc, "reduction_word_j", &mut self.reduction_word_j)?;
        f64_field(doc, "switch_word_j", &mut self.switch_word_j)?;
        f64_field(doc, "tile_leakage_w", &mut self.tile_leakage_w)?;
        f64_field(doc, "dram_access_per_byte_j", &mut self.dram_access_per_byte_j)?;
        f64_field(doc, "digital_mac8_j", &mut self.digital_mac8_j)?;
        Ok(())
    }
}

/// Component areas [mm²] at 65 nm for one tile plus chip-level overhead.
#[derive(Clone, Debug)]
pub struct AreaTable {
    /// One 8T SRAM cell [mm²] (65 nm 8T ≈ 0.95 µm² incl. wiring share).
    pub sram_cell_mm2: f64,
    /// One GRNG cell incl. fringe caps above it [mm²] (caps stacked on
    /// top per §III-C, so only transistor area counts).
    pub grng_cell_mm2: f64,
    /// One 6-bit SAR ADC, pitch-matched slice [mm²].
    pub adc_mm2: f64,
    /// One row IDAC [mm²].
    pub idac_mm2: f64,
    /// Reduction + calibration digital logic per tile [mm²].
    pub reduction_mm2: f64,
    /// Chip-level overhead outside the tile (IO ring, buffers, control)
    /// [mm²] — brings total die to 0.45 mm².
    pub chip_overhead_mm2: f64,
}

impl Default for AreaTable {
    fn default() -> Self {
        Self {
            // Tile area target: SRAM ≈ 48 % of tile (Fig. 12).
            // 10240 cells · 0.95 µm² = 0.00973 mm²  → tile ≈ 0.0203 mm².
            sram_cell_mm2: 0.95e-6,
            // 512 GRNG cells: SOTA area efficiency — 11.4 GSa/s/mm² norm.
            // target: 512 cells ≈ 0.0045 mm² → 8.8 µm²/cell.
            grng_cell_mm2: 8.8e-6,
            // 96 ADCs ≈ 0.0038 mm² → 40 µm² each (shared controller).
            adc_mm2: 40.0e-6,
            // 64 IDACs ≈ 0.0013 mm².
            idac_mm2: 20.0e-6,
            reduction_mm2: 0.0008,
            // Total die 0.45 mm²; tile ≈ 0.0203 mm² → overhead ≈ 0.43 mm²
            // (IO pads, decap, test mux — Fig. 6 die shot is mostly pads).
            chip_overhead_mm2: 0.4297,
        }
    }
}

impl AreaTable {
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        f64_field(doc, "sram_cell_mm2", &mut self.sram_cell_mm2)?;
        f64_field(doc, "grng_cell_mm2", &mut self.grng_cell_mm2)?;
        f64_field(doc, "adc_mm2", &mut self.adc_mm2)?;
        f64_field(doc, "idac_mm2", &mut self.idac_mm2)?;
        f64_field(doc, "reduction_mm2", &mut self.reduction_mm2)?;
        f64_field(doc, "chip_overhead_mm2", &mut self.chip_overhead_mm2)?;
        Ok(())
    }
}

/// Technology scaling from 65 nm to `target_nm` (Tab. II footnote scales
/// to 22 nm). Classic Dennard-ish rules as used for such cross-node
/// comparisons: area ∝ λ², energy ∝ λ·V² (V also drops), delay ∝ λ.
#[derive(Clone, Copy, Debug)]
pub struct TechScale {
    pub from_nm: f64,
    pub to_nm: f64,
}

impl TechScale {
    pub fn to_22nm() -> Self {
        Self {
            from_nm: TECH_NODE_NM,
            to_nm: 22.0,
        }
    }

    fn lambda(&self) -> f64 {
        self.to_nm / self.from_nm
    }

    /// Area scales with λ².
    pub fn area(&self, mm2: f64) -> f64 {
        mm2 * self.lambda().powi(2)
    }

    /// Throughput scales with 1/λ (delay ∝ λ).
    pub fn throughput(&self, per_s: f64) -> f64 {
        per_s / self.lambda()
    }

    /// Energy per op scales ≈ λ · (V_to/V_from)²; with V 1.2→0.8 V.
    pub fn energy(&self, joules: f64) -> f64 {
        let v_scale: f64 = 0.8 / 1.2;
        joules * self.lambda() * v_scale.powi(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_directions() {
        let s = TechScale::to_22nm();
        assert!(s.area(1.0) < 0.2, "area should shrink a lot");
        assert!(s.throughput(1.0) > 2.5, "throughput should rise ~3x");
        assert!(s.energy(1.0) < 0.2, "energy should shrink");
    }

    #[test]
    fn paper_scaled_throughput_consistent() {
        // Tab. II: RNG Tput 5.12 GSa/s → 28.0 GSa/s scaled to 22 nm.
        // Our rule gives 5.12 / (22/65) = 15.1 GSa/s from delay alone;
        // the paper also scales parallelism per area. Normalized per mm²:
        // 11.4 → 62.3 GSa/s/mm²: ratio 5.46. area⁻¹·delay⁻¹ = (65/22)³ ≈ 25.8
        // — the paper is more conservative; we only check monotonicity here
        // and report both rules in the comparison bench.
        let s = TechScale::to_22nm();
        let scaled = s.throughput(5.12e9);
        assert!(scaled > 5.12e9);
    }

    #[test]
    fn default_tile_area_shares() {
        // SRAM should be ≈ 48 % of tile area with default geometry
        // (64×8 words × (2·8+4) cells).
        let a = AreaTable::default();
        let sram = 64.0 * 8.0 * 20.0 * a.sram_cell_mm2;
        let grng = 512.0 * a.grng_cell_mm2;
        let adc = 96.0 * a.adc_mm2;
        let idac = 64.0 * a.idac_mm2;
        let tile = sram + grng + adc + idac + a.reduction_mm2;
        let share = sram / tile;
        assert!(
            (0.40..=0.56).contains(&share),
            "SRAM tile-area share {share:.3} out of range"
        );
    }
}
