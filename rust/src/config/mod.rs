//! Typed configuration system.
//!
//! Every subsystem is parameterized by a config struct whose `Default`
//! matches the fabricated 65 nm prototype described in the paper
//! (§III–IV): 1 fF fringe caps, V_DD = 1.2 V, V_R = 180 mV typical bias,
//! 64×8-word tiles with 8-bit μ / 4-bit σ words, 4-bit IDAC inputs and
//! 6-bit SAR ADCs. Configs load from TOML files (see `configs/`) and every
//! field can be overridden; `validate()` enforces physical sanity.

mod chip;
pub mod energy;
mod model;
mod server;

pub use chip::{AdcConfig, ChipConfig, GrngConfig, IdacConfig, TileConfig};
pub use energy::{AreaTable, EnergyTable, TECH_NODE_NM};
pub use model::ModelConfig;
pub use server::{Backend, ServerConfig};

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::toml;
use std::path::Path;

/// Root configuration: everything needed to instantiate the full system.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub chip: ChipConfig,
    pub model: ModelConfig,
    pub server: ServerConfig,
    /// Deterministic fault-injection schedule (`[faults]`); inert by
    /// default — see [`crate::fault`].
    pub faults: crate::fault::FaultPlan,
}

impl Config {
    /// Load from a TOML file, overriding defaults field by field.
    pub fn from_toml_file(path: &Path) -> Result<Config> {
        let doc = toml::read_file(path).map_err(|e| Error::Config(e.to_string()))?;
        Self::from_json(&doc)
    }

    pub fn from_toml_str(text: &str) -> Result<Config> {
        let doc = toml::parse(text)?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(chip) = doc.get("chip") {
            cfg.chip.apply_json(chip)?;
        }
        if let Some(model) = doc.get("model") {
            cfg.model.apply_json(model)?;
        }
        if let Some(server) = doc.get("server") {
            cfg.server.apply_json(server)?;
        }
        if let Some(faults) = doc.get("faults") {
            cfg.faults.apply_json(faults)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        self.chip.validate()?;
        self.model.validate()?;
        self.server.validate()?;
        self.faults.validate()?;
        if self.model.mc_samples > self.server.max_mc_samples {
            return Err(Error::Config(format!(
                "model.mc_samples ({}) exceeds server.max_mc_samples ({})",
                self.model.mc_samples, self.server.max_mc_samples
            )));
        }
        Ok(())
    }
}

/// Helper: read an f64 field if present.
pub(crate) fn f64_field(doc: &Json, key: &str, target: &mut f64) -> Result<()> {
    if let Some(v) = doc.get(key) {
        *target = v
            .as_f64()
            .ok_or_else(|| Error::Config(format!("field '{key}' must be a number")))?;
    }
    Ok(())
}

pub(crate) fn usize_field(doc: &Json, key: &str, target: &mut usize) -> Result<()> {
    if let Some(v) = doc.get(key) {
        *target = v
            .as_usize()
            .ok_or_else(|| Error::Config(format!("field '{key}' must be a non-negative integer")))?;
    }
    Ok(())
}

pub(crate) fn u64_field(doc: &Json, key: &str, target: &mut u64) -> Result<()> {
    if let Some(v) = doc.get(key) {
        *target = v
            .as_i64()
            .filter(|&x| x >= 0)
            .ok_or_else(|| Error::Config(format!("field '{key}' must be a non-negative integer")))?
            as u64;
    }
    Ok(())
}

pub(crate) fn bool_field(doc: &Json, key: &str, target: &mut bool) -> Result<()> {
    if let Some(v) = doc.get(key) {
        *target = v
            .as_bool()
            .ok_or_else(|| Error::Config(format!("field '{key}' must be a boolean")))?;
    }
    Ok(())
}

pub(crate) fn string_field(doc: &Json, key: &str, target: &mut String) -> Result<()> {
    if let Some(v) = doc.get(key) {
        *target = v
            .as_str()
            .ok_or_else(|| Error::Config(format!("field '{key}' must be a string")))?
            .to_string();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let cfg = Config::from_toml_str(
            r#"
[chip.grng]
bias_v = 0.15
temp_c = 40.0

[chip.tile]
rows = 32

[model]
mc_samples = 16

[server]
max_batch = 8
mc_workers = 3
"#,
        )
        .unwrap();
        assert_eq!(cfg.chip.grng.bias_v, 0.15);
        assert_eq!(cfg.chip.grng.temp_c, 40.0);
        assert_eq!(cfg.chip.tile.rows, 32);
        assert_eq!(cfg.model.mc_samples, 16);
        assert_eq!(cfg.server.max_batch, 8);
        assert_eq!(cfg.server.mc_workers, 3);
        // untouched fields keep defaults
        assert_eq!(cfg.chip.tile.words_per_row, 8);
        assert!(Config::from_toml_str("[server]\nmc_workers = 0\n").is_err());
    }

    #[test]
    fn edge_knobs_parse_and_validate() {
        let cfg = Config::from_toml_str(
            r#"
[server]
listen = "127.0.0.1:8080"
edge_threads = 2
edge_degrade_load = 0.5
edge_shed_load = 0.8
edge_degraded_mc_samples = 2
edge_retry_after_ms = 100
"#,
        )
        .unwrap();
        assert_eq!(cfg.server.listen, "127.0.0.1:8080");
        assert_eq!(cfg.server.edge_threads, 2);
        assert_eq!(cfg.server.edge_degrade_load, 0.5);
        assert_eq!(cfg.server.edge_shed_load, 0.8);
        assert_eq!(cfg.server.edge_degraded_mc_samples, 2);
        assert_eq!(cfg.server.edge_retry_after_ms, 100);
        // Defaults: no edge unless a listen address is configured.
        assert!(Config::default().server.listen.is_empty());
        // Band ordering is the invariant: shed < degrade is rejected.
        assert!(Config::from_toml_str(
            "[server]\nedge_degrade_load = 0.9\nedge_shed_load = 0.5\n"
        )
        .is_err());
        // Degraded passes must stay within the hard mc_samples bound.
        assert!(Config::from_toml_str(
            "[server]\nmax_mc_samples = 8\nedge_degraded_mc_samples = 16\n"
        )
        .is_err());
        // 0.0 thresholds are legal: degrade/shed-everything test modes.
        Config::from_toml_str("[server]\nedge_degrade_load = 0.0\nedge_shed_load = 0.0\n")
            .unwrap();
    }

    #[test]
    fn backend_parses_and_rejects() {
        assert_eq!(Config::default().server.backend, Backend::Pjrt);
        let cfg = Config::from_toml_str("[server]\nbackend = \"cim\"\n").unwrap();
        assert_eq!(cfg.server.backend, Backend::Cim);
        let cfg = Config::from_toml_str("[server]\nbackend = \"sim\"\n").unwrap();
        assert_eq!(cfg.server.backend, Backend::Sim);
        assert!(Config::from_toml_str("[server]\nbackend = \"gpu\"\n").is_err());
        assert_eq!(Backend::parse("PJRT").unwrap(), Backend::Pjrt);
        assert_eq!(Backend::Cim.name(), "cim");
    }

    #[test]
    fn invalid_config_rejected() {
        let r = Config::from_toml_str("[chip.grng]\nvdd = -1.0\n");
        assert!(r.is_err());
        let r = Config::from_toml_str("[chip.adc]\nbits = 0\n");
        assert!(r.is_err());
        let r = Config::from_toml_str("[chip.grng]\nbias_v = \"hi\"\n");
        assert!(r.is_err());
    }
}
