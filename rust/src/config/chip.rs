//! Chip-level configuration: GRNG cell, CIM tile, data converters.
//!
//! Defaults reproduce the fabricated prototype of the paper (65 nm,
//! Fig. 3–6): the calibration constants were fit so that at the typical
//! operating point (V_R = 180 mV, 28 °C) the simulated GRNG lands on the
//! paper's measured numbers — 1.0 ns pulse-width σ, 69 ns average latency,
//! 360 fJ/Sample (§IV-A, Fig. 9).

use super::{bool_field, f64_field, usize_field, u64_field};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// GRNG cell configuration (Fig. 4 circuit).
#[derive(Clone, Debug)]
pub struct GrngConfig {
    /// Supply voltage \[V\]. 65 nm nominal.
    pub vdd: f64,
    /// Inverter switching threshold V_Thr \[V\].
    pub v_thr: f64,
    /// Discharge capacitor C_p = C_n \[F\] (metal fringe, ~1 fF).
    pub cap_f: f64,
    /// Gate bias V_R on the discharge transistors \[V\]. Typical 0.18 V.
    pub bias_v: f64,
    /// Ambient temperature [°C].
    pub temp_c: f64,
    /// Subthreshold leakage prefactor I_0 \[A\] (fit: 69 ns latency @ 180 mV).
    pub i0_a: f64,
    /// NMOS threshold voltage V_th at 25 °C \[V\].
    pub v_th: f64,
    /// Threshold temperature coefficient [V/K] (negative).
    pub v_th_tc: f64,
    /// Subthreshold slope factor n (~1.5 for 65 nm).
    pub subthreshold_n: f64,
    /// Relative σ of per-cell current mismatch (ΔI/I per branch).
    pub mismatch_rel_sigma: f64,
    /// Shot-noise scale κ (1.0 = ideal 2qI white noise).
    pub noise_scale: f64,
    /// RTN/flicker relative amplitude a₀ at 28 °C and μ_T = τ_ref
    /// (σ_rtn/μ_T = a(T)·(μ_T/τ_ref)^p — fitted to Tab. I).
    pub rtn_rel_amplitude: f64,
    /// RTN latency exponent p (superlinear growth of low-freq noise).
    pub rtn_exponent: f64,
    /// RTN amplitude temperature scale \[K\]: a(T) = a₀·exp((T−T₀)/scale).
    pub rtn_t_scale_k: f64,
    /// RTN reference time constant τ_ref \[s\].
    pub rtn_tau_s: f64,
    /// Outlier (DFF mis-reset / trap burst) probability at 28 °C.
    /// Thermally activated with a sharp onset: ≈0.3 at 60 °C where the
    /// measured Q-Q r-value collapses (Tab. I), negligible at ≤50 °C.
    pub outlier_p0: f64,
    /// Outlier probability temperature scale \[K\] (Tab. I: Q–Q r-value
    /// collapses at 60 °C).
    pub outlier_t_scale_k: f64,
    /// Outlier magnitude, in units of the nominal pulse σ.
    pub outlier_magnitude: f64,
    /// Inverter short-circuit energy coefficient [J·A] — E_inv = k/I_L.
    /// (Crossing window ∝ C/I_L, so slower discharge burns more.)
    pub inverter_sc_coeff: f64,
    /// Fixed per-sample digital energy: DFF reset + latch \[J\].
    pub dff_energy_j: f64,
    /// DFF minimum reset window \[s\]; pulses shorter than this risk a
    /// mis-reset that produces an outlier sample (observed as the Q–Q
    /// r-value collapse at 60 °C, Tab. I).
    pub dff_reset_window_s: f64,
    /// Euler–Maruyama timestep for the full circuit sim, as a fraction of
    /// the mean crossing time (adaptive: dt = μ_T · sim_dt_frac).
    pub sim_dt_frac: f64,
    /// Pulse-width → ε normalization \[s\]: pulse widths are divided by this
    /// to produce ε. `0.0` = auto-calibrate to the closed-form pulse σ at
    /// the configured operating point (what the chip's IDAC-bias tuning
    /// achieves, §IV-A).
    pub sigma_unit_s: f64,
}

impl Default for GrngConfig {
    fn default() -> Self {
        Self {
            vdd: 1.2,
            v_thr: 0.6,
            cap_f: 1.0e-15,
            bias_v: 0.18,
            temp_c: 28.0,
            // Fit: I_L(0.18 V, 28 °C) ≈ 8.7 nA so μ_T = C·(VDD−VThr)/I_L ≈ 69 ns
            i0_a: 8.95e-6,
            v_th: 0.45,
            // The fabricated chip's latency tracks temperature *less*
            // steeply than unbiased subthreshold theory (ratio 2.49× over
            // 28→60 °C, Tab. I); the thermal-voltage term alone already
            // yields ≈3.3×, so the ΔVth/ΔT shift is absorbed into the
            // effective model (set to 0 here; the V_R bias generator of
            // the testbench partially tracks V_th).
            v_th_tc: 0.0,
            subthreshold_n: 1.5,
            // Careful common-centroid layout + the matched fringe caps of
            // [27] keep branch mismatch small enough that uncalibrated
            // ε₀ offsets stay within a few σ (they must not saturate the
            // σε-path ADCs; the Eq. 8–10 calibration removes the rest).
            mismatch_rel_sigma: 0.02,
            noise_scale: 0.85,
            // Fitted to Tab. I: pulse σ 197 ns @ 1.93 µs latency (28 °C);
            // the 515 ns @ 60 °C row is reproduced by RTN growth (×1.8)
            // compounded with the outlier-burst variance (×1.44).
            rtn_rel_amplitude: 0.015,
            rtn_exponent: 0.7,
            rtn_t_scale_k: 12.6,
            rtn_tau_s: 2.0e-7,
            outlier_p0: 1.7e-9,
            outlier_t_scale_k: 2.0,
            outlier_magnitude: 6.0,
            // E_inv = coeff / I_L ; fit so total ≈ 360 fJ @ 180 mV:
            // 360 fJ − 2·C·VDD² (2.9 fJ) − DFF (4 fJ) ≈ 353 fJ → coeff ≈ 353e-15 · 8.7e-9
            inverter_sc_coeff: 3.07e-21,
            dff_energy_j: 4.0e-15,
            dff_reset_window_s: 2.0e-9,
            sim_dt_frac: 1.0 / 400.0,
            sigma_unit_s: 0.0,
        }
    }
}

impl GrngConfig {
    pub fn temp_k(&self) -> f64 {
        self.temp_c + 273.15
    }

    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        f64_field(doc, "vdd", &mut self.vdd)?;
        f64_field(doc, "v_thr", &mut self.v_thr)?;
        f64_field(doc, "cap_f", &mut self.cap_f)?;
        f64_field(doc, "bias_v", &mut self.bias_v)?;
        f64_field(doc, "temp_c", &mut self.temp_c)?;
        f64_field(doc, "i0_a", &mut self.i0_a)?;
        f64_field(doc, "v_th", &mut self.v_th)?;
        f64_field(doc, "v_th_tc", &mut self.v_th_tc)?;
        f64_field(doc, "subthreshold_n", &mut self.subthreshold_n)?;
        f64_field(doc, "mismatch_rel_sigma", &mut self.mismatch_rel_sigma)?;
        f64_field(doc, "noise_scale", &mut self.noise_scale)?;
        f64_field(doc, "rtn_rel_amplitude", &mut self.rtn_rel_amplitude)?;
        f64_field(doc, "rtn_exponent", &mut self.rtn_exponent)?;
        f64_field(doc, "rtn_t_scale_k", &mut self.rtn_t_scale_k)?;
        f64_field(doc, "rtn_tau_s", &mut self.rtn_tau_s)?;
        f64_field(doc, "outlier_p0", &mut self.outlier_p0)?;
        f64_field(doc, "outlier_t_scale_k", &mut self.outlier_t_scale_k)?;
        f64_field(doc, "outlier_magnitude", &mut self.outlier_magnitude)?;
        f64_field(doc, "inverter_sc_coeff", &mut self.inverter_sc_coeff)?;
        f64_field(doc, "dff_energy_j", &mut self.dff_energy_j)?;
        f64_field(doc, "dff_reset_window_s", &mut self.dff_reset_window_s)?;
        f64_field(doc, "sim_dt_frac", &mut self.sim_dt_frac)?;
        f64_field(doc, "sigma_unit_s", &mut self.sigma_unit_s)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        let check = |ok: bool, msg: &str| {
            if ok {
                Ok(())
            } else {
                Err(Error::Config(format!("grng: {msg}")))
            }
        };
        check(self.vdd > 0.0, "vdd must be positive")?;
        check(
            self.v_thr > 0.0 && self.v_thr < self.vdd,
            "v_thr must lie in (0, vdd)",
        )?;
        check(self.cap_f > 0.0, "cap_f must be positive")?;
        check(
            self.bias_v >= 0.0 && self.bias_v < self.vdd,
            "bias_v must lie in [0, vdd)",
        )?;
        check(self.temp_c > -273.15, "temp_c below absolute zero")?;
        check(self.i0_a > 0.0, "i0_a must be positive")?;
        check(self.subthreshold_n >= 1.0, "subthreshold_n must be >= 1")?;
        check(
            self.sim_dt_frac > 0.0 && self.sim_dt_frac < 0.1,
            "sim_dt_frac must be in (0, 0.1)",
        )?;
        check(self.sigma_unit_s >= 0.0, "sigma_unit_s must be >= 0 (0 = auto)")?;
        check(self.noise_scale > 0.0, "noise_scale must be positive")?;
        check(
            (0.0..1.0).contains(&self.outlier_p0),
            "outlier_p0 must be in [0, 1)",
        )?;
        check(self.rtn_exponent > 0.0, "rtn_exponent must be positive")?;
        Ok(())
    }
}

/// CIM tile geometry (Fig. 3): two subarrays (μ and σε) sharing input X.
#[derive(Clone, Debug)]
pub struct TileConfig {
    /// Number of rows (input vector length). Prototype: 64.
    pub rows: usize,
    /// Words per row (output vector width). Prototype: 8.
    pub words_per_row: usize,
    /// μ precision \[bits\] (differential: 2 SRAM cells/bit). Prototype: 8.
    pub mu_bits: usize,
    /// σ precision \[bits\] (single cell/bit; sign from GRNG). Prototype: 4.
    pub sigma_bits: usize,
    /// MVM clock frequency \[Hz\] — single-cycle MVM per §III-B.
    pub clock_hz: f64,
}

impl Default for TileConfig {
    fn default() -> Self {
        Self {
            rows: 64,
            words_per_row: 8,
            mu_bits: 8,
            sigma_bits: 4,
            // 102 GOp/s over 64×8×2 ops/MVM → ~100 MHz single-cycle MVM.
            clock_hz: 100.0e6,
        }
    }
}

impl TileConfig {
    /// Ops per MVM: one multiply + one add per (row, word).
    pub fn ops_per_mvm(&self) -> usize {
        self.rows * self.words_per_row * 2
    }

    /// Number of GRNG cells in the tile (one per σ word).
    pub fn grng_cells(&self) -> usize {
        self.rows * self.words_per_row
    }

    /// Total SRAM bits: μ differential (2 cells/bit) + σ single cell/bit.
    pub fn sram_cells(&self) -> usize {
        self.rows * self.words_per_row * (2 * self.mu_bits + self.sigma_bits)
    }

    /// Bit-columns needing ADCs: every μ bit and σ bit column.
    pub fn adc_count(&self) -> usize {
        self.words_per_row * (self.mu_bits + self.sigma_bits)
    }

    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        usize_field(doc, "rows", &mut self.rows)?;
        usize_field(doc, "words_per_row", &mut self.words_per_row)?;
        usize_field(doc, "mu_bits", &mut self.mu_bits)?;
        usize_field(doc, "sigma_bits", &mut self.sigma_bits)?;
        f64_field(doc, "clock_hz", &mut self.clock_hz)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.words_per_row == 0 {
            return Err(Error::Config("tile: rows/words_per_row must be > 0".into()));
        }
        if self.mu_bits == 0 || self.mu_bits > 16 {
            return Err(Error::Config("tile: mu_bits must be in 1..=16".into()));
        }
        if self.sigma_bits == 0 || self.sigma_bits > self.mu_bits {
            return Err(Error::Config(
                "tile: sigma_bits must be in 1..=mu_bits".into(),
            ));
        }
        if self.clock_hz <= 0.0 {
            return Err(Error::Config("tile: clock_hz must be positive".into()));
        }
        Ok(())
    }
}

/// Input current-DAC (IDAC) model: 4-bit digital input → wordline current.
#[derive(Clone, Debug)]
pub struct IdacConfig {
    /// Input precision \[bits\]. Prototype: 4.
    pub bits: usize,
    /// Full-scale cell current per LSB step \[A\].
    pub lsb_current_a: f64,
    /// Integral nonlinearity, relative (fraction of full scale).
    pub inl_rel: f64,
    /// Per-conversion energy \[J\].
    pub energy_j: f64,
}

impl Default for IdacConfig {
    fn default() -> Self {
        Self {
            bits: 4,
            lsb_current_a: 0.5e-6,
            inl_rel: 0.003,
            energy_j: 30.0e-15,
        }
    }
}

impl IdacConfig {
    pub fn levels(&self) -> usize {
        1 << self.bits
    }

    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        usize_field(doc, "bits", &mut self.bits)?;
        f64_field(doc, "lsb_current_a", &mut self.lsb_current_a)?;
        f64_field(doc, "inl_rel", &mut self.inl_rel)?;
        f64_field(doc, "energy_j", &mut self.energy_j)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.bits == 0 || self.bits > 12 {
            return Err(Error::Config("idac: bits must be in 1..=12".into()));
        }
        if self.lsb_current_a <= 0.0 {
            return Err(Error::Config("idac: lsb_current_a must be positive".into()));
        }
        Ok(())
    }
}

/// SAR ADC model (6-bit differential, shared synchronous controller).
#[derive(Clone, Debug)]
pub struct AdcConfig {
    /// Resolution \[bits\]. Prototype: 6.
    pub bits: usize,
    /// Input-referred offset σ, in LSBs (corrected by reduction logic).
    pub offset_lsb_sigma: f64,
    /// Input-referred noise σ, in LSBs (per conversion, uncorrectable).
    pub noise_lsb_sigma: f64,
    /// Per-conversion energy \[J\].
    pub energy_j: f64,
}

impl Default for AdcConfig {
    fn default() -> Self {
        Self {
            bits: 6,
            offset_lsb_sigma: 0.8,
            noise_lsb_sigma: 0.3,
            energy_j: 110.0e-15,
        }
    }
}

impl AdcConfig {
    pub fn levels(&self) -> i64 {
        1 << self.bits
    }

    /// Code range: differential ADC → signed output codes.
    pub fn code_range(&self) -> (i64, i64) {
        let half = self.levels() / 2;
        (-half, half - 1)
    }

    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        usize_field(doc, "bits", &mut self.bits)?;
        f64_field(doc, "offset_lsb_sigma", &mut self.offset_lsb_sigma)?;
        f64_field(doc, "noise_lsb_sigma", &mut self.noise_lsb_sigma)?;
        f64_field(doc, "energy_j", &mut self.energy_j)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.bits == 0 || self.bits > 14 {
            return Err(Error::Config("adc: bits must be in 1..=14".into()));
        }
        if self.offset_lsb_sigma < 0.0 || self.noise_lsb_sigma < 0.0 {
            return Err(Error::Config("adc: noise sigmas must be >= 0".into()));
        }
        Ok(())
    }
}

/// Full chip configuration.
#[derive(Clone, Debug, Default)]
pub struct ChipConfig {
    pub grng: GrngConfig,
    pub tile: TileConfig,
    pub idac: IdacConfig,
    pub adc: AdcConfig,
    pub energy: super::EnergyTable,
    pub area: super::AreaTable,
    /// Master seed for die-level variation (mismatch Monte Carlo).
    pub die_seed: u64,
    /// Use the fast closed-form GRNG sampler on the MVM path (the full
    /// ODE sim remains available for characterization).
    pub fast_grng: bool,
}

impl ChipConfig {
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        if let Some(g) = doc.get("grng") {
            self.grng.apply_json(g)?;
        }
        if let Some(t) = doc.get("tile") {
            self.tile.apply_json(t)?;
        }
        if let Some(i) = doc.get("idac") {
            self.idac.apply_json(i)?;
        }
        if let Some(a) = doc.get("adc") {
            self.adc.apply_json(a)?;
        }
        if let Some(e) = doc.get("energy") {
            self.energy.apply_json(e)?;
        }
        if let Some(ar) = doc.get("area") {
            self.area.apply_json(ar)?;
        }
        u64_field(doc, "die_seed", &mut self.die_seed)?;
        bool_field(doc, "fast_grng", &mut self.fast_grng)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        self.grng.validate()?;
        self.tile.validate()?;
        self.idac.validate()?;
        self.adc.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_arithmetic() {
        let t = TileConfig::default();
        assert_eq!(t.ops_per_mvm(), 1024);
        assert_eq!(t.grng_cells(), 512);
        assert_eq!(t.sram_cells(), 64 * 8 * 20);
        assert_eq!(t.adc_count(), 8 * 12);
    }

    #[test]
    fn adc_code_range_signed() {
        let a = AdcConfig::default();
        assert_eq!(a.code_range(), (-32, 31));
    }

    #[test]
    fn grng_defaults_sane() {
        let g = GrngConfig::default();
        g.validate().unwrap();
        assert!((g.temp_k() - 301.15).abs() < 1e-9);
    }

    #[test]
    fn validation_catches_bad_sigma_bits() {
        let mut t = TileConfig::default();
        t.sigma_bits = 9; // > mu_bits
        assert!(t.validate().is_err());
    }
}
