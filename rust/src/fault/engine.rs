//! [`FaultyEngine`] — an [`InferenceEngine`] decorator that executes a
//! [`FaultPlan`] deterministically.

use crate::error::{Error, Result};
use crate::fault::FaultPlan;
use crate::fault::ALL_SHARDS;
use crate::grng::bank::shard_die_seed;
use crate::runtime::{EngineEnergyReport, EpsilonMode, InferenceEngine, Manifest};
use crate::util::rng::{Rng64, SplitMix64};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Wraps any engine and injects the plan's faults around `run` calls;
/// all other [`InferenceEngine`] methods delegate untouched, so
/// manifests, execution counters, ε ownership, and energy ledgers read
/// exactly as the inner engine reports them.
///
/// The fault stream is `SplitMix64(shard_die_seed(plan.seed, shard))`
/// advanced by `incarnation` splits — the same discipline the ε banks
/// use for die seeds — so every (plan, shard, incarnation) triple
/// replays its jitter draws and corrupted bits identically, and a
/// respawned worker gets a fresh, deterministic stream rather than
/// rewinding the dead one's.
pub struct FaultyEngine {
    inner: Box<dyn InferenceEngine>,
    plan: FaultPlan,
    shard: usize,
    incarnation: u64,
    runs: u64,
    rng: SplitMix64,
}

impl FaultyEngine {
    pub fn new(
        inner: Box<dyn InferenceEngine>,
        plan: FaultPlan,
        shard: usize,
        incarnation: u64,
    ) -> Self {
        let mut root = SplitMix64::new(shard_die_seed(plan.seed, shard));
        root.jump(incarnation);
        let rng = SplitMix64::new(root.split());
        Self {
            inner,
            plan,
            shard,
            incarnation,
            runs: 0,
            rng,
        }
    }

    /// The crash fault is armed only on a shard's first incarnation:
    /// a respawned engine re-counting to `panic_at_run` would die again
    /// at the same run and recovery could never converge.
    fn panic_armed(&self) -> bool {
        self.plan.panic_at_run > 0
            && self.incarnation == 0
            && (self.plan.panic_shard == ALL_SHARDS
                || self.plan.panic_shard == self.shard as u64)
    }

    fn stall(&mut self) {
        let mut total_ms = self.plan.stall_ms;
        if self.plan.stall_jitter_ms > 0.0 {
            // Uniform [0,1) from the top 53 bits of the fault stream.
            let u01 = (self.rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            total_ms += self.plan.stall_jitter_ms * u01;
        }
        if total_ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(total_ms / 1e3));
        }
    }

    /// SEU bit flips confined to mantissa/sign bits (a single upset
    /// perturbs the sample without minting inf/NaN), then the droop
    /// offset across every word.
    fn corrupt(&mut self, buf: &mut [f32]) {
        if !buf.is_empty() {
            for _ in 0..self.plan.eps_bit_flips {
                let idx = (self.rng.next_u64() % buf.len() as u64) as usize;
                let pick = (self.rng.next_u64() % 24) as u32;
                let bit = if pick == 23 { 31 } else { pick };
                buf[idx] = f32::from_bits(buf[idx].to_bits() ^ (1u32 << bit));
            }
        }
        if self.plan.adc_offset_step != 0.0 {
            let step = self.plan.adc_offset_step as f32;
            for v in buf.iter_mut() {
                *v += step;
            }
        }
    }
}

impl InferenceEngine for FaultyEngine {
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>> {
        self.runs += 1;
        self.stall();
        if self.panic_armed() && self.runs == self.plan.panic_at_run {
            panic!(
                "[fault-plan] injected panic: shard {} run {} (seed {:#x})",
                self.shard, self.runs, self.plan.seed
            );
        }
        if self.plan.error_every > 0 && self.runs % self.plan.error_every == 0 {
            return Err(Error::Coordinator(format!(
                "[fault-plan] injected transient error: shard {} run {} (incarnation {})",
                self.shard, self.runs, self.incarnation
            )));
        }
        // ε corruption rides the buffers crossing the engine boundary:
        // head calls of external-ε engines carry (features, ε1, ε2).
        if entry == "head" && inputs.len() >= 3 && self.plan.corrupts_epsilon() {
            let mut eps1 = inputs[1].0.to_vec();
            let mut eps2 = inputs[2].0.to_vec();
            self.corrupt(&mut eps1);
            self.corrupt(&mut eps2);
            let mut patched: Vec<(&[f32], &Vec<usize>)> = Vec::with_capacity(inputs.len());
            patched.push(inputs[0]);
            patched.push((&eps1[..], inputs[1].1));
            patched.push((&eps2[..], inputs[2].1));
            patched.extend(inputs.iter().skip(3).copied());
            return self.inner.run(entry, &patched);
        }
        self.inner.run(entry, inputs)
    }

    fn executions(&self) -> u64 {
        self.inner.executions()
    }

    fn name(&self) -> &'static str {
        "fault-injected"
    }

    fn epsilon_mode(&self) -> EpsilonMode {
        self.inner.epsilon_mode()
    }

    fn energy_report(&self) -> Option<EngineEnergyReport> {
        self.inner.energy_report()
    }

    // Elastic capacity passes straight through: the decorator injects
    // faults on the run path only, so scaling the wrapped engine's
    // replica pool (and reading its footprint split) must behave exactly
    // as it would bare.
    fn replica_count(&self) -> usize {
        self.inner.replica_count()
    }

    fn set_replicas(&mut self, n: usize) {
        self.inner.set_replicas(n);
    }

    fn bytes_shared(&self) -> usize {
        self.inner.bytes_shared()
    }

    fn bytes_private(&self) -> usize {
        self.inner.bytes_private()
    }
}

/// Wrap an engine factory so every shard's engine executes `plan`. The
/// closure tracks how many engines each shard index has been given
/// (its *incarnation*): the supervisor calls the factory again on
/// respawn, and the incarnation both disarms the one-shot crash fault
/// and advances the fault stream deterministically.
pub fn wrap_engine_factory(
    inner: crate::coordinator::EngineFactory,
    plan: FaultPlan,
) -> crate::coordinator::EngineFactory {
    // BTreeMap, not HashMap: fault/ is replay-pinned, and hash-seeded
    // iteration order must not leak into anything observable.
    let incarnations: Arc<Mutex<BTreeMap<usize, u64>>> = Arc::new(Mutex::new(BTreeMap::new()));
    Arc::new(move |shard| {
        let engine = inner(shard)?;
        let incarnation = {
            let mut map = incarnations.lock().unwrap_or_else(|p| p.into_inner());
            let slot = map.entry(shard).or_insert(0);
            let current = *slot;
            *slot += 1;
            current
        };
        let faulty = FaultyEngine::new(engine, plan.clone(), shard, incarnation);
        Ok(Box::new(faulty) as Box<dyn InferenceEngine>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::runtime::SimEngine;

    fn sim() -> Box<dyn InferenceEngine> {
        Box::new(SimEngine::from_config(&Config::default()))
    }

    fn head_inputs(manifest: &Manifest) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<Vec<usize>>) {
        let head = manifest.entry("head").expect("head entry").clone();
        let feats = vec![0.25f32; head.input_len(0)];
        let eps1 = vec![0.5f32; head.input_len(1)];
        let eps2 = vec![-0.5f32; head.input_len(2)];
        let shapes: Vec<Vec<usize>> = head.inputs.iter().map(|(_, s)| s.clone()).collect();
        (feats, eps1, eps2, shapes)
    }

    #[test]
    fn transient_errors_fire_on_schedule() {
        let plan = FaultPlan {
            error_every: 2,
            ..FaultPlan::default()
        };
        let mut engine = FaultyEngine::new(sim(), plan, 0, 0);
        let (feats, eps1, eps2, shapes) = head_inputs(&engine.manifest().clone());
        let inputs = [
            (&feats[..], &shapes[0]),
            (&eps1[..], &shapes[1]),
            (&eps2[..], &shapes[2]),
        ];
        assert!(engine.run("head", &inputs).is_ok(), "run 1 passes");
        assert!(engine.run("head", &inputs).is_err(), "run 2 injected");
        assert!(engine.run("head", &inputs).is_ok(), "run 3 passes");
        assert!(engine.run("head", &inputs).is_err(), "run 4 injected");
    }

    #[test]
    fn epsilon_corruption_is_deterministic_and_perturbs_output() {
        let plan = FaultPlan {
            eps_bit_flips: 4,
            adc_offset_step: 0.5,
            ..FaultPlan::default()
        };
        let run_once = |plan: &FaultPlan| {
            let mut engine = FaultyEngine::new(sim(), plan.clone(), 0, 0);
            let (feats, eps1, eps2, shapes) = head_inputs(&engine.manifest().clone());
            engine
                .run(
                    "head",
                    &[
                        (&feats[..], &shapes[0]),
                        (&eps1[..], &shapes[1]),
                        (&eps2[..], &shapes[2]),
                    ],
                )
                .unwrap()
        };
        let a = run_once(&plan);
        let b = run_once(&plan);
        assert_eq!(a, b, "same plan must replay bit-identically");
        let clean = run_once(&FaultPlan::default());
        assert_ne!(a, clean, "corruption must actually reach the head");
        assert!(a.iter().all(|v| v.is_finite()), "SEU model must not mint NaN/inf");
    }

    #[test]
    fn incarnations_disarm_the_panic_and_split_the_stream() {
        let plan = FaultPlan {
            panic_at_run: 1,
            ..FaultPlan::default()
        };
        // Incarnation 1 (a respawn) must not panic at the same run.
        let mut engine = FaultyEngine::new(sim(), plan.clone(), 0, 1);
        let (feats, eps1, eps2, shapes) = head_inputs(&engine.manifest().clone());
        engine
            .run(
                "head",
                &[
                    (&feats[..], &shapes[0]),
                    (&eps1[..], &shapes[1]),
                    (&eps2[..], &shapes[2]),
                ],
            )
            .unwrap();
        // And the factory wrapper counts incarnations per shard.
        let factory = wrap_engine_factory(
            Arc::new(|_shard| Ok(sim())),
            FaultPlan {
                panic_at_run: 1,
                ..FaultPlan::default()
            },
        );
        let _first = factory(0).unwrap(); // incarnation 0: armed
        let mut second = factory(0).unwrap(); // incarnation 1: disarmed
        let feats2 = vec![0.0f32; second.manifest().entry("features").unwrap().input_len(0)];
        let fshape = second.manifest().entry("features").unwrap().inputs[0].1.clone();
        second.run("features", &[(&feats2[..], &fshape)]).unwrap();
    }
}
