//! [`FaultPlan`] — the declarative description of a chaos run.

use crate::config::{f64_field, u64_field};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Sentinel for [`FaultPlan::panic_shard`]: arm the panic on every shard.
pub const ALL_SHARDS: u64 = u64::MAX;

/// A deterministic fault schedule. Every knob defaults to "off"; the
/// default plan is inert ([`FaultPlan::active`] is `false`) so configs
/// without a `[faults]` section serve exactly as before.
///
/// Fault taxonomy (DESIGN.md §9):
///
/// - **crash** — `panic_at_run`: the wrapped engine panics on its N-th
///   `run` call (features and head passes both count). Armed only on a
///   shard's *first* engine incarnation, so the supervisor's respawn is
///   not re-killed at the same count and recovery converges.
/// - **transient error** — `error_every`: every N-th `run` call returns
///   `Err` without executing (a correctable fault: the worker survives
///   and the batch is retried under the budget).
/// - **latency** — `stall_ms` + `stall_jitter_ms`: a hot-die / thermal
///   throttle model; every `run` sleeps `stall_ms` plus a uniform
///   `[0, stall_jitter_ms)` draw from the fault stream.
/// - **ε corruption** — `eps_bit_flips` and `adc_offset_step`: SEU bit
///   flips (mantissa/sign only, so a single upset never mints inf/NaN)
///   and a supply-droop offset step applied to the GRNG ε words feeding
///   the Bayesian head. External-ε engines only — the corruption rides
///   the ε buffers crossing the engine boundary; in-word engines draw ε
///   inside their tile arrays where a decorator cannot reach.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Root fault seed; split per shard with the same SplitMix64
    /// discipline as the ε die seeds, then per incarnation.
    pub seed: u64,
    /// Panic on the N-th engine `run` call (1-based; 0 = disabled).
    pub panic_at_run: u64,
    /// Restrict the panic to one shard index ([`ALL_SHARDS`] = every
    /// shard is armed).
    pub panic_shard: u64,
    /// Return a transient error on every N-th `run` call (0 = disabled).
    pub error_every: u64,
    /// Fixed stall before every `run` call \[ms\].
    pub stall_ms: f64,
    /// Additional uniform `[0, jitter)` stall \[ms\], drawn from the
    /// fault stream (deterministic per (seed, shard, incarnation, run)).
    pub stall_jitter_ms: f64,
    /// SEU model: bit flips injected per ε buffer per head call.
    pub eps_bit_flips: u64,
    /// Droop model: additive offset \[σ\] applied to every ε word.
    pub adc_offset_step: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0xFA_17,
            panic_at_run: 0,
            panic_shard: ALL_SHARDS,
            error_every: 0,
            stall_ms: 0.0,
            stall_jitter_ms: 0.0,
            eps_bit_flips: 0,
            adc_offset_step: 0.0,
        }
    }
}

impl FaultPlan {
    /// Whether any fault is configured; an inert plan never wraps the
    /// engine factory, so the zero-fault path costs nothing.
    pub fn active(&self) -> bool {
        self.panic_at_run > 0
            || self.error_every > 0
            || self.stall_ms > 0.0
            || self.stall_jitter_ms > 0.0
            || self.corrupts_epsilon()
    }

    /// Whether the plan perturbs the ε stream (bit flips or offset).
    pub fn corrupts_epsilon(&self) -> bool {
        self.eps_bit_flips > 0 || self.adc_offset_step != 0.0
    }

    /// Apply a `[faults]` TOML/JSON section field by field.
    pub(crate) fn apply_json(&mut self, doc: &Json) -> Result<()> {
        u64_field(doc, "seed", &mut self.seed)?;
        u64_field(doc, "panic_at_run", &mut self.panic_at_run)?;
        u64_field(doc, "panic_shard", &mut self.panic_shard)?;
        u64_field(doc, "error_every", &mut self.error_every)?;
        f64_field(doc, "stall_ms", &mut self.stall_ms)?;
        f64_field(doc, "stall_jitter_ms", &mut self.stall_jitter_ms)?;
        u64_field(doc, "eps_bit_flips", &mut self.eps_bit_flips)?;
        f64_field(doc, "adc_offset_step", &mut self.adc_offset_step)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("faults.stall_ms", self.stall_ms),
            ("faults.stall_jitter_ms", self.stall_jitter_ms),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(Error::Config(format!("{name} must be finite and >= 0, got {v}")));
            }
        }
        if !self.adc_offset_step.is_finite() {
            return Err(Error::Config(format!(
                "faults.adc_offset_step must be finite, got {}",
                self.adc_offset_step
            )));
        }
        Ok(())
    }

    /// Parse a compact `key=value,key=value` spec (the `BNN_CIM_FAULT_PLAN`
    /// environment variable and the CLI `--fault-plan` flag), starting
    /// from the inert default. Example:
    /// `seed=7,panic_at_run=3,panic_shard=0,stall_ms=1.5`.
    pub fn parse_spec(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for pair in spec.split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let (key, value) = pair
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("fault plan: expected key=value, got '{pair}'")))?;
            let (key, value) = (key.trim(), value.trim());
            let bad_u64 =
                || Error::Config(format!("fault plan: '{key}' must be a non-negative integer"));
            let bad_f64 = || Error::Config(format!("fault plan: '{key}' must be a number"));
            match key {
                "seed" => plan.seed = value.parse().map_err(|_| bad_u64())?,
                "panic_at_run" => plan.panic_at_run = value.parse().map_err(|_| bad_u64())?,
                "panic_shard" => plan.panic_shard = value.parse().map_err(|_| bad_u64())?,
                "error_every" => plan.error_every = value.parse().map_err(|_| bad_u64())?,
                "stall_ms" => plan.stall_ms = value.parse().map_err(|_| bad_f64())?,
                "stall_jitter_ms" => {
                    plan.stall_jitter_ms = value.parse().map_err(|_| bad_f64())?
                }
                "eps_bit_flips" => plan.eps_bit_flips = value.parse().map_err(|_| bad_u64())?,
                "adc_offset_step" => {
                    plan.adc_offset_step = value.parse().map_err(|_| bad_f64())?
                }
                other => {
                    return Err(Error::Config(format!("fault plan: unknown key '{other}'")))
                }
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// The `BNN_CIM_FAULT_PLAN` environment override, if set and
    /// non-empty. A malformed spec is an error, not a silent no-op — a
    /// chaos sweep that thinks it injected faults but didn't is worse
    /// than one that fails to start.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("BNN_CIM_FAULT_PLAN") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(Self::parse_spec(&spec)?)),
            _ => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let plan = FaultPlan::default();
        assert!(!plan.active());
        assert!(!plan.corrupts_epsilon());
        plan.validate().unwrap();
    }

    #[test]
    fn spec_parses_every_knob_and_rejects_junk() {
        let plan = FaultPlan::parse_spec(
            "seed=7, panic_at_run=3, panic_shard=0, error_every=10, \
             stall_ms=1.5, stall_jitter_ms=2.0, eps_bit_flips=4, adc_offset_step=-0.25",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.panic_at_run, 3);
        assert_eq!(plan.panic_shard, 0);
        assert_eq!(plan.error_every, 10);
        assert_eq!(plan.stall_ms, 1.5);
        assert_eq!(plan.stall_jitter_ms, 2.0);
        assert_eq!(plan.eps_bit_flips, 4);
        assert_eq!(plan.adc_offset_step, -0.25);
        assert!(plan.active());
        assert_eq!(FaultPlan::parse_spec("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse_spec("bogus_knob=1").is_err());
        assert!(FaultPlan::parse_spec("stall_ms").is_err());
        assert!(FaultPlan::parse_spec("stall_ms=-1").is_err());
        assert!(FaultPlan::parse_spec("panic_at_run=x").is_err());
    }

    #[test]
    fn toml_faults_section_parses() {
        let cfg = crate::config::Config::from_toml_str(
            "[faults]\nseed = 9\npanic_at_run = 2\nstall_ms = 0.5\n",
        )
        .unwrap();
        assert_eq!(cfg.faults.seed, 9);
        assert_eq!(cfg.faults.panic_at_run, 2);
        assert_eq!(cfg.faults.stall_ms, 0.5);
        assert!(cfg.faults.active());
        assert!(!crate::config::Config::default().faults.active());
        assert!(
            crate::config::Config::from_toml_str("[faults]\nstall_ms = -2.0\n").is_err(),
            "validate() must reject negative stalls"
        );
    }
}
