//! Deterministic fault injection for chaos testing the serving stack.
//!
//! The related in-memory-BNN literature (Bayes2IMC's conductance-drift
//! analysis, the FeFET GRNG's device-variation study) treats hardware
//! non-idealities as first-class design inputs. This module gives the
//! software stack the same capability: a [`FaultPlan`] describes *when*
//! and *how* things break, and [`FaultyEngine`] wraps any
//! [`InferenceEngine`](crate::runtime::InferenceEngine) to make them
//! break exactly then — worker panics at engine-run N, fixed/jittered
//! latency stalls, transient error returns, and hardware-grounded ε
//! corruptions (single-event-upset bit flips and ADC droop offsets in
//! the GRNG words).
//!
//! Everything is keyed off a SplitMix64-split fault seed
//! (`shard_die_seed(plan.seed, shard)`, the same split discipline the ε
//! banks use), so a chaos run replays bit-identically: same plan, same
//! workload → same stalls, same flipped bits, same panic, same recovery.
//!
//! A plan reaches the pool three ways, in increasing precedence:
//!
//! 1. `[faults]` section in the config TOML (`cfg.faults`);
//! 2. the `BNN_CIM_FAULT_PLAN` environment variable, a comma-separated
//!    `key=value` spec (e.g. `seed=7,panic_at_run=3,stall_ms=1.5`);
//! 3. [`CoordinatorBuilder::fault_plan`](crate::client::CoordinatorBuilder::fault_plan).
//!
//! The supervisor in `coordinator::supervisor` is the other half of the
//! story: it turns the injected deaths into restarts, retries, and typed
//! [`ServeError::ShardFailed`](crate::client::ServeError) outcomes
//! instead of hung tickets (DESIGN.md §9).

mod engine;
mod plan;

pub use engine::{wrap_engine_factory, FaultyEngine};
pub use plan::{FaultPlan, ALL_SHARDS};
