//! The execution engine: one PJRT CPU client + compiled executables per
//! entry point, with f32 literal marshaling.

use super::artifact::{ArtifactSpec, Manifest};
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A compiled entry point. Shape metadata is NOT duplicated here: the
/// manifest owns the single copy of every `ArtifactSpec` and `run`
/// validates against it by name.
pub struct LoadedEntry {
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime engine: owns the PJRT client and all executables.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    manifest: Manifest,
    entries: BTreeMap<String, LoadedEntry>,
    /// Executions performed (metrics).
    pub executions: u64,
}

impl Engine {
    /// Load every entry point in the manifest and compile it.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        let mut entries = BTreeMap::new();
        for (name, spec) in &manifest.entry_points {
            let entry = Self::compile_entry(&client, spec)?;
            entries.insert(name.clone(), entry);
        }
        Ok(Engine {
            client,
            manifest,
            entries,
            executions: 0,
        })
    }

    fn compile_entry(client: &xla::PjRtClient, spec: &ArtifactSpec) -> Result<LoadedEntry> {
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::Runtime(format!("parse {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {path}: {e}")))?;
        Ok(LoadedEntry { exe })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Execute an entry point with f32 inputs `(data, shape)`; returns the
    /// first output flattened to f32 (all our artifacts return 1-tuples).
    pub fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>> {
        let loaded = self
            .entries
            .get(entry)
            .ok_or_else(|| Error::Runtime(format!("unknown entry '{entry}'")))?;
        let spec = self.manifest.entry(entry)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "entry '{entry}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().enumerate() {
            let want: usize = spec.inputs[i].1.iter().product();
            if data.len() != want {
                return Err(Error::Runtime(format!(
                    "entry '{entry}' input {i} ('{}') expects {} elements, got {}",
                    spec.inputs[i].0,
                    want,
                    data.len()
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| Error::Runtime(format!("reshape input {i}: {e}")))?;
            literals.push(lit);
        }
        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute '{entry}': {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch '{entry}': {e}")))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let first = out
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple '{entry}': {e}")))?;
        self.executions += 1;
        first
            .to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("to_vec '{entry}': {e}")))
    }
}

impl super::InferenceEngine for Engine {
    fn manifest(&self) -> &Manifest {
        Engine::manifest(self)
    }

    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>> {
        Engine::run(self, entry, inputs)
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
