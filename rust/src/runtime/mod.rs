//! Execution engines behind the serving coordinator.
//!
//! Three backends implement [`InferenceEngine`]:
//!
//! - `Engine` (feature `pjrt`) — the real PJRT runtime: loads
//!   AOT-compiled HLO-text artifacts and executes them on the request
//!   path (Python never runs at serving time). Pipeline:
//!   `HloModuleProto::from_text_file` → `XlaComputation` →
//!   `PjRtClient::compile` → `PjRtLoadedExecutable::execute`. HLO *text*
//!   is the interchange format (jax ≥ 0.5 protos use 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects; the text parser reassigns ids
//!   — see /opt/xla-example/README.md and python/compile/aot.py).
//! - [`SimEngine`] — a deterministic pure-Rust stand-in with the same
//!   entry-point contract (`features` / `head` / `full`). It needs no
//!   artifacts and no toolchain, so the sharded coordinator, its tests,
//!   and `benches/sharded_serving.rs` exercise the full batching/ε path
//!   in every build.
//! - [`CimEngine`] — the behavioral chip model as a serving backend: the
//!   Bayesian head runs on simulated `cim::TileArray`s whose in-word GRNG
//!   banks generate ε *inside* the engine ([`EpsilonMode::InWord`]), and
//!   tile `EnergyLedger`s meter every MVM.
//!
//! Engines are *not* required to be `Send`: the coordinator constructs
//! one engine inside each shard-worker thread (PJRT handles are not
//! `Send`-safe by contract) and they never cross threads. Which backend
//! boots is a client-surface decision: `cfg.server.backend` or
//! `client::CoordinatorBuilder::backend`.

mod artifact;
mod cim_engine;
#[cfg(feature = "pjrt")]
mod executor;
mod sim;

pub use artifact::{ArtifactSpec, Manifest};
pub use cim_engine::{CimEngine, SharedModelCache};
#[cfg(feature = "pjrt")]
pub use executor::{Engine, LoadedEntry};
pub use sim::SimEngine;

use crate::error::Result;

/// Who produces the ε that the Bayesian head consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EpsilonMode {
    /// ε is an engine *input*: the coordinator fills buffers from a
    /// per-shard `EpsilonSource` and passes them to `run` alongside the
    /// features (the AOT-artifact and sim contracts).
    External,
    /// ε materializes inside the engine's memory arrays (in-word GRNG):
    /// `run("head", …)` takes features only, and the engine reports its
    /// own ε/energy counters via [`InferenceEngine::energy_report`].
    InWord,
}

impl EpsilonMode {
    /// Short tag for logs and error messages (also the vocabulary of
    /// `client::CoordinatorBuilder::epsilon`).
    pub fn name(&self) -> &'static str {
        match self {
            EpsilonMode::External => "external",
            EpsilonMode::InWord => "in-word",
        }
    }
}

/// Cumulative hardware-energy counters for engines that model the chip.
/// All values are absolute totals since engine construction (snapshots of
/// them must therefore never reset anything — see `coordinator::metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineEnergyReport {
    /// Total tile energy deposited so far \[J\].
    pub total_j: f64,
    /// GRNG component of `total_j` \[J\] (the fJ/Sample numerator).
    pub grng_j: f64,
    /// ε samples drawn by the in-word banks so far.
    pub grng_samples: u64,
    /// Per-tile MVMs executed so far.
    pub mvm_count: u64,
    /// MAC ops represented by those MVMs (the J/Op denominator).
    pub total_ops: u64,
}

/// A loaded inference backend: shape metadata plus entry-point execution.
pub trait InferenceEngine {
    /// Shape metadata for the loaded entry points.
    fn manifest(&self) -> &Manifest;

    /// Execute an entry point with f32 inputs `(data, shape)`; returns the
    /// first output flattened to f32 (all our artifacts return 1-tuples).
    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>>;

    /// Executions performed so far (metrics).
    fn executions(&self) -> u64;

    /// Backend tag for logs/metrics.
    fn name(&self) -> &'static str;

    /// Whether this engine consumes external ε inputs or generates ε in
    /// its own memory arrays. Default: the historical artifact contract.
    fn epsilon_mode(&self) -> EpsilonMode {
        EpsilonMode::External
    }

    /// Cumulative energy/ε counters for engines that model hardware;
    /// `None` for purely software backends.
    fn energy_report(&self) -> Option<EngineEnergyReport> {
        None
    }

    /// MC replicas currently instantiated inside this engine (1 for
    /// engines without replica parallelism).
    fn replica_count(&self) -> usize {
        1
    }

    /// Elastic capacity hook: grow or shrink the engine's MC replica pool
    /// to `n` (clamped to ≥ 1 by implementations). Growth must continue
    /// the engine's deterministic replica-seed sequence — replica `i`
    /// is the same stream whether it was born at boot or re-grown later —
    /// and shrink must not lose accumulated energy accounting. Default:
    /// no-op for engines without replicas.
    fn set_replicas(&mut self, n: usize) {
        let _ = n;
    }

    /// Bytes of model/calibration state this engine shares across its MC
    /// replicas behind `Arc`s (0 for backends without the split).
    fn bytes_shared(&self) -> usize {
        0
    }

    /// Bytes of per-replica private state (ε buffers, RNG streams,
    /// scratch) across all replicas (0 when not modeled).
    fn bytes_private(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn manifest_parses_if_present() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(Path::new("artifacts")).unwrap();
        assert!(m.batch > 0);
        for ep in ["features", "head", "full"] {
            assert!(m.entry_points.contains_key(ep), "missing {ep}");
        }
        let head = m.entry("head").unwrap();
        assert_eq!(head.inputs.len(), 3);
        assert_eq!(head.outputs[0].1[1], m.classes);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn engine_executes_head_artifact() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let mut engine = Engine::load(Path::new("artifacts")).unwrap();
        let m = engine.manifest().clone();
        let b = m.batch;
        let spec = m.entry("head").unwrap().clone();
        let feats = vec![0.5f32; b * m.feature_dim];
        let eps1 = vec![0.0f32; spec.input_len(1)];
        let eps2 = vec![0.0f32; spec.input_len(2)];
        let probs = engine
            .run(
                "head",
                &[
                    (&feats, &spec.inputs[0].1),
                    (&eps1, &spec.inputs[1].1),
                    (&eps2, &spec.inputs[2].1),
                ],
            )
            .unwrap();
        assert_eq!(probs.len(), b * m.classes);
        for row in probs.chunks(m.classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "softmax row sums to {sum}");
        }
        // With ε = 0 the pass is deterministic.
        let probs2 = engine
            .run(
                "head",
                &[
                    (&feats, &spec.inputs[0].1),
                    (&eps1, &spec.inputs[1].1),
                    (&eps2, &spec.inputs[2].1),
                ],
            )
            .unwrap();
        assert_eq!(probs, probs2);
        assert_eq!(engine.executions, 2);
    }
}
