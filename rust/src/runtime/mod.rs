//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them
//! on the request path (Python never runs at serving time).
//!
//! Pipeline: `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `PjRtLoadedExecutable::execute`. HLO *text* is
//! the interchange format (jax ≥ 0.5 protos use 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids — see
//! /opt/xla-example/README.md and python/compile/aot.py).

mod artifact;
mod executor;

pub use artifact::{ArtifactSpec, Manifest};
pub use executor::{Engine, LoadedEntry};

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn manifest_parses_if_present() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(Path::new("artifacts")).unwrap();
        assert!(m.batch > 0);
        for ep in ["features", "head", "full"] {
            assert!(m.entry_points.contains_key(ep), "missing {ep}");
        }
        let head = m.entry("head").unwrap();
        assert_eq!(head.inputs.len(), 3);
        assert_eq!(head.outputs[0].1[1], m.classes);
    }

    #[test]
    fn engine_executes_head_artifact() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let mut engine = Engine::load(Path::new("artifacts")).unwrap();
        let m = engine.manifest().clone();
        let b = m.batch;
        let spec = m.entry("head").unwrap().clone();
        let feats = vec![0.5f32; b * m.feature_dim];
        let eps1 = vec![0.0f32; spec.input_len(1)];
        let eps2 = vec![0.0f32; spec.input_len(2)];
        let probs = engine
            .run(
                "head",
                &[
                    (&feats, &spec.inputs[0].1),
                    (&eps1, &spec.inputs[1].1),
                    (&eps2, &spec.inputs[2].1),
                ],
            )
            .unwrap();
        assert_eq!(probs.len(), b * m.classes);
        for row in probs.chunks(m.classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "softmax row sums to {sum}");
        }
        // With ε = 0 the pass is deterministic.
        let probs2 = engine
            .run(
                "head",
                &[
                    (&feats, &spec.inputs[0].1),
                    (&eps1, &spec.inputs[1].1),
                    (&eps2, &spec.inputs[2].1),
                ],
            )
            .unwrap();
        assert_eq!(probs, probs2);
        assert_eq!(engine.executions, 2);
    }
}
