//! The behavioral chip model as a serving backend.
//!
//! `CimEngine` implements the coordinator's `InferenceEngine` contract by
//! mapping the Bayesian head onto simulated `cim::TileArray`s
//! (`Model::map_head_to_hardware`): μ/σ weights are quantized into the
//! differential/magnitude word encodings, every tile is bring-up
//! calibrated (Eq. 8–10), and each head MVM runs through the full analog
//! chain — IDAC drives, σε subarray, SAR ADCs, reduction logic — with ε
//! refreshed by the *in-word GRNG bank inside the engine*. This is the
//! chip's dataflow: the memory array that stores σ produces the
//! randomness the MVM consumes, so the engine declares
//! [`EpsilonMode::InWord`] and the coordinator supplies no external ε.
//!
//! The deterministic feature extractor runs in Rust
//! (`Model::forward_features`), mirroring the paper's partial-Bayesian
//! split (§III-A): only the FC head lives on CIM tiles.
//!
//! Determinism: weights derive from [`CIM_WEIGHT_SEED`] alone (shared by
//! every shard, like replicated PJRT engines), while the die — mismatch,
//! ADC/IDAC non-idealities, GRNG streams — derives from the shard's
//! `die_seed` split. Two engines built for the same `(cfg, shard)` replay
//! bit-identically.
//!
//! Energy: every MVM deposits joules into the tiles' `EnergyLedger`s;
//! [`CimEngine::energy_report`] exposes the cumulative totals (fJ/Sample,
//! J/Op numerators) without ever resetting them. Bring-up costs
//! (programming + calibration) are cleared at construction so the report
//! meters serving traffic only.

use super::artifact::{ArtifactSpec, Manifest};
use super::{EngineEnergyReport, EpsilonMode, InferenceEngine};
use crate::config::Config;
use crate::energy::Component;
use crate::error::{Error, Result};
use crate::grng::shard_chip;
use crate::nn::Model;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Weight seed shared by every shard of a simulated CIM deployment (the
/// "model weights" replicated across lanes; dies still differ per shard).
pub const CIM_WEIGHT_SEED: u64 = 0xC1BE_27F0_5EED_CA11;

/// Chip-model inference backend (no artifacts, no PJRT toolchain).
pub struct CimEngine {
    manifest: Manifest,
    model: Model,
    /// MAC ops represented by one per-tile MVM (J/Op denominator).
    ops_per_tile_mvm: u64,
    executions: u64,
}

impl CimEngine {
    /// Engine for shard `shard` of a serving pool: shared weights, an
    /// independent die (`shard_die_seed` split of `chip.die_seed`), and
    /// the head mapped + calibrated onto tile arrays.
    pub fn for_shard(cfg: &Config, shard: usize) -> Self {
        let chip = shard_chip(&cfg.chip, shard);
        let batch = cfg.server.max_batch.max(1);
        let side = cfg.model.image_side;
        let classes = cfg.model.classes;
        let mut model = Model::random(side, classes, CIM_WEIGHT_SEED);
        model.map_head_to_hardware(&chip);
        // Bring-up (programming + calibration) energy is a one-time cost;
        // zero the ledgers so energy_report meters serving traffic only.
        model.reset_head_ledgers();

        let feature_dim = model.feature_dim;
        let pixels = side * side;
        let spec = |name: &str,
                    inputs: Vec<(String, Vec<usize>)>,
                    outputs: Vec<(String, Vec<usize>)>| ArtifactSpec {
            file: PathBuf::from(format!("cim://{name}")),
            inputs,
            outputs,
        };
        let mut entry_points = BTreeMap::new();
        entry_points.insert(
            "features".to_string(),
            spec(
                "features",
                vec![("pixels".to_string(), vec![batch, pixels])],
                vec![("features".to_string(), vec![batch, feature_dim])],
            ),
        );
        // In-word ε: the head takes features only — no ε inputs exist in
        // this engine's contract (EpsilonMode::InWord).
        entry_points.insert(
            "head".to_string(),
            spec(
                "head",
                vec![("features".to_string(), vec![batch, feature_dim])],
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        entry_points.insert(
            "full".to_string(),
            spec(
                "full",
                vec![("pixels".to_string(), vec![batch, pixels])],
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        let manifest = Manifest {
            batch,
            side,
            feature_dim,
            classes,
            entry_points,
            dir: PathBuf::from("cim://"),
        };
        Self {
            manifest,
            model,
            ops_per_tile_mvm: chip.tile.ops_per_mvm() as u64,
            executions: 0,
        }
    }

    /// Engine matching a serving [`Config`] on the chip's own die
    /// (shard 0 keeps `die_seed` unsplit).
    pub fn from_config(cfg: &Config) -> Self {
        Self::for_shard(cfg, 0)
    }

    /// The mapped model (fidelity tests / hardware diagnostics).
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Mutable access to the mapped model (fidelity tests drive the tile
    /// arrays directly to compare MVMs against `mvm_reference`).
    pub fn model_mut(&mut self) -> &mut Model {
        &mut self.model
    }

    fn run_features(&self, images: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let p = self.manifest.side * self.manifest.side;
        let fdim = self.manifest.feature_dim;
        let mut out = Vec::with_capacity(b * fdim);
        for bi in 0..b {
            out.extend(self.model.forward_features(&images[bi * p..(bi + 1) * p]));
        }
        out
    }

    fn run_head(&mut self, feats: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let fdim = self.manifest.feature_dim;
        let c = self.manifest.classes;
        let mut out = Vec::with_capacity(b * c);
        for bi in 0..b {
            // One hardware MC pass per slot: each tile MVM refreshes ε
            // from its in-word bank, so every slot draws fresh randomness.
            // Padding slots execute too (the static-batch contract shared
            // with the AOT artifacts), so a fused call's energy covers the
            // whole array activation — fJ/Sample and J/Op stay normalized
            // because their denominators scale with the same passes.
            let probs = self.model.head_sample_hw(&feats[bi * fdim..(bi + 1) * fdim]);
            out.extend(probs.iter().map(|&v| v as f32));
        }
        out
    }
}

impl InferenceEngine for CimEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>> {
        let spec = self.manifest.entry(entry)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "entry '{entry}' expects {} inputs, got {} (in-word ε: the \
                 head takes features only)",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (data, _shape)) in inputs.iter().enumerate() {
            let want: usize = spec.inputs[i].1.iter().product();
            if data.len() != want {
                return Err(Error::Runtime(format!(
                    "entry '{entry}' input {i} ('{}') expects {} elements, got {}",
                    spec.inputs[i].0,
                    want,
                    data.len()
                )));
            }
        }
        let out = match entry {
            "features" => self.run_features(inputs[0].0),
            "head" => self.run_head(inputs[0].0),
            "full" => {
                let feats = self.run_features(inputs[0].0);
                self.run_head(&feats)
            }
            other => return Err(Error::Runtime(format!("unknown entry '{other}'"))),
        };
        self.executions += 1;
        Ok(out)
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn name(&self) -> &'static str {
        "cim"
    }

    fn epsilon_mode(&self) -> EpsilonMode {
        EpsilonMode::InWord
    }

    fn energy_report(&self) -> Option<EngineEnergyReport> {
        let ledger = self.model.head_ledger();
        Some(EngineEnergyReport {
            total_j: ledger.total_j(),
            grng_j: ledger.component_j(Component::Grng),
            grng_samples: ledger.grng_samples,
            mvm_count: ledger.mvm_count,
            total_ops: ledger.mvm_count * self.ops_per_tile_mvm,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small tiles keep bring-up calibration cheap in debug builds.
    fn tiny_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.chip.tile.rows = 16;
        cfg.chip.tile.words_per_row = 4;
        cfg.server.max_batch = 2;
        cfg
    }

    #[test]
    fn manifest_contract_declares_in_word_epsilon() {
        let cfg = tiny_cfg();
        let e = CimEngine::from_config(&cfg);
        assert_eq!(e.epsilon_mode(), EpsilonMode::InWord);
        let m = e.manifest();
        assert_eq!(m.batch, 2);
        assert_eq!(m.classes, cfg.model.classes);
        for ep in ["features", "head", "full"] {
            assert!(m.entry_points.contains_key(ep), "missing {ep}");
        }
        // The head consumes features only — ε never crosses the boundary.
        assert_eq!(m.entry("head").unwrap().inputs.len(), 1);
        assert_eq!(m.entry("full").unwrap().inputs.len(), 1);
    }

    #[test]
    fn head_produces_normalized_stochastic_probs_and_meters_energy() {
        let cfg = tiny_cfg();
        let mut e = CimEngine::from_config(&cfg);
        let m = e.manifest().clone();
        let images = vec![0.4f32; m.batch * m.side * m.side];
        let fspec = m.entry("features").unwrap().clone();
        let feats = e.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        assert_eq!(feats.len(), m.batch * m.feature_dim);
        // Feature extraction is software: no tile energy yet.
        let r0 = e.energy_report().unwrap();
        assert_eq!(r0.mvm_count, 0);
        assert!(r0.total_j == 0.0, "bring-up energy must be cleared");

        let hspec = m.entry("head").unwrap().clone();
        let p0 = e.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
        for row in p0.chunks(m.classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax row sums to {sum}");
        }
        // Fresh in-word ε per pass ⇒ stochastic head.
        let p1 = e.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
        assert_ne!(p0, p1, "in-word ε must vary across MC passes");
        // Every MVM deposited joules and drew ε from the in-word banks.
        let r = e.energy_report().unwrap();
        assert!(r.mvm_count > 0 && r.total_j > 0.0);
        assert!(r.grng_samples > 0 && r.grng_j > 0.0);
        assert!(r.total_ops >= r.mvm_count);
        // Headline sanity: fJ/Sample in the hardware ballpark (≈360 fJ).
        let fj_per_sample = r.grng_j / r.grng_samples as f64 * 1e15;
        assert!(
            (100.0..1000.0).contains(&fj_per_sample),
            "fJ/Sample {fj_per_sample:.0} out of range"
        );
        assert_eq!(e.executions(), 3);
    }

    #[test]
    fn same_shard_is_bit_identical_across_instances() {
        let cfg = tiny_cfg();
        let mut a = CimEngine::for_shard(&cfg, 0);
        let mut b = CimEngine::for_shard(&cfg, 0);
        let m = a.manifest().clone();
        let images = vec![0.7f32; m.batch * m.side * m.side];
        let fspec = m.entry("features").unwrap().clone();
        let fa = a.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        let fb = b.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        assert_eq!(fa, fb);
        let hspec = m.entry("head").unwrap().clone();
        for _ in 0..3 {
            let pa = a.run("head", &[(&fa, &hspec.inputs[0].1)]).unwrap();
            let pb = b.run("head", &[(&fb, &hspec.inputs[0].1)]).unwrap();
            assert_eq!(pa, pb, "same (weights, die) must replay bitwise");
        }
    }

    #[test]
    fn different_shards_draw_different_dies() {
        let cfg = tiny_cfg();
        let mut a = CimEngine::for_shard(&cfg, 0);
        let mut b = CimEngine::for_shard(&cfg, 1);
        let m = a.manifest().clone();
        let images = vec![0.7f32; m.batch * m.side * m.side];
        let fspec = m.entry("features").unwrap().clone();
        let fa = a.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        // Weights are shared across shards: identical feature paths.
        let fb = b.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        assert_eq!(fa, fb);
        // Dies are not: ε streams (and analog chains) differ.
        let hspec = m.entry("head").unwrap().clone();
        let pa = a.run("head", &[(&fa, &hspec.inputs[0].1)]).unwrap();
        let pb = b.run("head", &[(&fb, &hspec.inputs[0].1)]).unwrap();
        assert_ne!(pa, pb, "independent dies must sample independently");
    }

    #[test]
    fn rejects_wrong_shapes_and_epsilon_inputs() {
        let cfg = tiny_cfg();
        let mut e = CimEngine::from_config(&cfg);
        let m = e.manifest().clone();
        let fspec = m.entry("features").unwrap().clone();
        let short = vec![0.0f32; 3];
        assert!(e.run("features", &[(&short, &fspec.inputs[0].1)]).is_err());
        // Passing external ε to an in-word engine is a contract error.
        let feats = vec![0.0f32; m.batch * m.feature_dim];
        let hspec = m.entry("head").unwrap().clone();
        let eps = vec![0.0f32; 8];
        let shape = &hspec.inputs[0].1;
        let with_eps = [(&feats[..], shape), (&eps[..], shape)];
        assert!(e.run("head", &with_eps).is_err());
        assert!(e.run("nope", &[]).is_err());
    }
}
