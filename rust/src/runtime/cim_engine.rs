//! The behavioral chip model as a serving backend.
//!
//! `CimEngine` implements the coordinator's `InferenceEngine` contract by
//! mapping the Bayesian head onto simulated `cim::TileArray`s
//! (`Model::map_head_to_hardware`): μ/σ weights are quantized into the
//! differential/magnitude word encodings, every tile is bring-up
//! calibrated (Eq. 8–10), and each head MVM runs through the full analog
//! chain — IDAC drives, σε subarray, SAR ADCs, reduction logic — with ε
//! refreshed by the *in-word GRNG bank inside the engine*. This is the
//! chip's dataflow: the memory array that stores σ produces the
//! randomness the MVM consumes, so the engine declares
//! [`EpsilonMode::InWord`] and the coordinator supplies no external ε.
//!
//! The deterministic feature extractor runs in Rust
//! (`Model::forward_features`), mirroring the paper's partial-Bayesian
//! split (§III-A): only the FC head lives on CIM tiles.
//!
//! Determinism: weights derive from [`CIM_WEIGHT_SEED`] alone (shared by
//! every shard, like replicated PJRT engines), while the die — mismatch,
//! ADC/IDAC non-idealities, GRNG streams — derives from the shard's
//! `die_seed` split. Two engines built for the same `(cfg, shard)` replay
//! bit-identically.
//!
//! Energy: every MVM deposits joules into the tiles' `EnergyLedger`s;
//! [`InferenceEngine::energy_report`] exposes the cumulative totals (fJ/Sample,
//! J/Op numerators) without ever resetting them. Bring-up costs
//! (programming + calibration) are cleared at construction so the report
//! meters serving traffic only.

use super::artifact::{ArtifactSpec, Manifest};
use super::{EngineEnergyReport, EpsilonMode, InferenceEngine};
use crate::config::Config;
use crate::energy::Component;
use crate::error::{Error, Result};
use crate::grng::shard_chip;
use crate::nn::model::{head_sample_layers, head_sample_layers_mc};
use crate::nn::{BayesDense, Model};
use crate::util::rng::SplitMix64;
use crate::util::threadpool::par_map_mut;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Weight seed shared by every shard of a simulated CIM deployment (the
/// "model weights" replicated across lanes; dies still differ per shard).
pub const CIM_WEIGHT_SEED: u64 = 0xC1BE_27F0_5EED_CA11;

/// Chip-model inference backend (no artifacts, no PJRT toolchain).
///
/// # MC-parallel sampling (`server.mc_workers`)
///
/// Every slot of a fused `head` call is an independent Monte-Carlo pass,
/// so the engine keeps `mc_workers` *replicas* of the calibrated head —
/// clones of the same mapped-and-calibrated tile arrays whose stochastic
/// streams (in-word GRNG cells, ADC noise) are reseeded from SplitMix64
/// splits of the shard's `die_seed`. Same die, independent sample
/// sequences: the software mirror of spatially unrolling MC samples
/// across compute lanes (VIBNN's parallel RNG banks; Fan et al.'s
/// unrolled FPGA sampler).
///
/// Determinism contract: slot `b` always runs on replica `b % mc_workers`,
/// each replica processes its slots in ascending order on its own thread
/// (`util::threadpool::par_map_mut` hands each replica to exactly one
/// worker), and outputs are gathered by slot index. Replica streams are
/// private, so the result is a pure function of
/// `(die_seed, workers, mc_workers)` — thread scheduling never leaks in —
/// and replay is bit-identical (pinned by `tests/cim_fidelity.rs`).
pub struct CimEngine {
    manifest: Manifest,
    model: Model,
    /// MC-parallel head replicas (same die as `model`, split streams).
    /// Serving traffic runs here; `model` stays the reference instance
    /// for fidelity tests and hardware diagnostics.
    replicas: Vec<Vec<BayesDense>>,
    /// MAC ops represented by one per-tile MVM (J/Op denominator).
    ops_per_tile_mvm: u64,
    executions: u64,
}

impl CimEngine {
    /// Engine for shard `shard` of a serving pool: shared weights, an
    /// independent die (`shard_die_seed` split of `chip.die_seed`), and
    /// the head mapped + calibrated onto tile arrays.
    pub fn for_shard(cfg: &Config, shard: usize) -> Self {
        let chip = shard_chip(&cfg.chip, shard);
        let batch = cfg.server.max_batch.max(1);
        let side = cfg.model.image_side;
        let classes = cfg.model.classes;
        let mut model = Model::random(side, classes, CIM_WEIGHT_SEED);
        model.map_head_to_hardware(&chip);
        // Bring-up (programming + calibration) energy is a one-time cost;
        // zero the ledgers so energy_report meters serving traffic only.
        model.reset_head_ledgers();

        // MC-parallel replicas: clone the calibrated head (cheap — no
        // recalibration) and reseed each clone's stochastic streams from
        // a split of this shard's die seed. Replica ledgers start at zero
        // (cloned after the bring-up reset).
        let mc_workers = cfg.server.mc_workers.max(1);
        let mut replica_seeder = SplitMix64::new(chip.die_seed ^ 0x4D43_5052_11CA_5EED);
        let replicas: Vec<Vec<BayesDense>> = (0..mc_workers)
            .map(|_| {
                let mut layer_seeder = SplitMix64::new(replica_seeder.split());
                model
                    .head
                    .iter()
                    .map(|layer| {
                        let mut rep = layer.clone();
                        rep.reseed_streams(layer_seeder.split());
                        rep
                    })
                    .collect()
            })
            .collect();

        let feature_dim = model.feature_dim;
        let pixels = side * side;
        let spec = |name: &str,
                    inputs: Vec<(String, Vec<usize>)>,
                    outputs: Vec<(String, Vec<usize>)>| ArtifactSpec {
            file: PathBuf::from(format!("cim://{name}")),
            inputs,
            outputs,
        };
        let mut entry_points = BTreeMap::new();
        entry_points.insert(
            "features".to_string(),
            spec(
                "features",
                vec![("pixels".to_string(), vec![batch, pixels])],
                vec![("features".to_string(), vec![batch, feature_dim])],
            ),
        );
        // In-word ε: the head takes features only — no ε inputs exist in
        // this engine's contract (EpsilonMode::InWord).
        entry_points.insert(
            "head".to_string(),
            spec(
                "head",
                vec![("features".to_string(), vec![batch, feature_dim])],
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        entry_points.insert(
            "full".to_string(),
            spec(
                "full",
                vec![("pixels".to_string(), vec![batch, pixels])],
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        let manifest = Manifest {
            batch,
            side,
            feature_dim,
            classes,
            entry_points,
            dir: PathBuf::from("cim://"),
        };
        Self {
            manifest,
            model,
            replicas,
            ops_per_tile_mvm: chip.tile.ops_per_mvm() as u64,
            executions: 0,
        }
    }

    /// Engine matching a serving [`Config`] on the chip's own die
    /// (shard 0 keeps `die_seed` unsplit).
    pub fn from_config(cfg: &Config) -> Self {
        Self::for_shard(cfg, 0)
    }

    /// The mapped model (fidelity tests / hardware diagnostics).
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Mutable access to the mapped model (fidelity tests drive the tile
    /// arrays directly to compare MVMs against `mvm_reference`).
    pub fn model_mut(&mut self) -> &mut Model {
        &mut self.model
    }

    fn run_features(&self, images: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let p = self.manifest.side * self.manifest.side;
        let fdim = self.manifest.feature_dim;
        let mut out = Vec::with_capacity(b * fdim);
        for bi in 0..b {
            out.extend(self.model.forward_features(&images[bi * p..(bi + 1) * p]));
        }
        out
    }

    fn run_head(&mut self, feats: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let fdim = self.manifest.feature_dim;
        let c = self.manifest.classes;
        let replica_count = self.replicas.len();
        // One hardware MC pass per slot: each tile MVM refreshes ε from
        // its in-word bank, so every slot draws fresh randomness. Padding
        // slots execute too (the static-batch contract shared with the
        // AOT artifacts), so a fused call's energy covers the whole array
        // activation — fJ/Sample and J/Op stay normalized because their
        // denominators scale with the same passes.
        //
        // Deterministic fan-out (see the type-level docs): slot bi runs on
        // replica bi % mc_workers; each replica walks its slots in
        // ascending order; results are gathered by slot index. Scoped
        // threads (spawned per call) are a deliberate tradeoff: the
        // replicas' &mut borrows stay lifetime-checked with no channel
        // plumbing, and the spawn cost is small against a fused call's
        // tile work at the default chip size.
        //
        // Batched MC runs: the slot packer replicates one request's
        // features across its MC-pass slots, so a replica's consecutive
        // slots often carry the *same* feature row. Those runs collapse
        // into one `head_sample_layers_mc` call — the first head layer
        // then rides `mvm_batch`'s amortized drives/planes and (for runs
        // ≥ 4 on full-size banks) the double-buffered ε pipeline, where
        // the in-word banks
        // generate sample k+1's ε while sample k's MVM converts. Batched
        // == sequential bit-for-bit (pinned at every level), so the
        // replay contract below is unchanged.
        let per_replica = par_map_mut(&mut self.replicas, replica_count, |r, layers| {
            let row = |i: usize| &feats[i * fdim..(i + 1) * fdim];
            let mut samples = Vec::new();
            let mut bi = r;
            while bi < b {
                let feat = row(bi);
                let mut run = 1;
                while bi + run * replica_count < b && row(bi + run * replica_count) == feat {
                    run += 1;
                }
                if run == 1 {
                    samples.push((bi, head_sample_layers(layers, feat)));
                } else {
                    let probs = head_sample_layers_mc(layers, feat, run);
                    for (k, p) in probs.into_iter().enumerate() {
                        samples.push((bi + k * replica_count, p));
                    }
                }
                bi += run * replica_count;
            }
            samples
        });
        let mut out = vec![0.0f32; b * c];
        for samples in per_replica {
            for (bi, probs) in samples {
                for (j, &v) in probs.iter().enumerate() {
                    out[bi * c + j] = v as f32;
                }
            }
        }
        out
    }
}

impl InferenceEngine for CimEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>> {
        let spec = self.manifest.entry(entry)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "entry '{entry}' expects {} inputs, got {} (in-word ε: the \
                 head takes features only)",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (data, _shape)) in inputs.iter().enumerate() {
            let want: usize = spec.inputs[i].1.iter().product();
            if data.len() != want {
                return Err(Error::Runtime(format!(
                    "entry '{entry}' input {i} ('{}') expects {} elements, got {}",
                    spec.inputs[i].0,
                    want,
                    data.len()
                )));
            }
        }
        let out = match entry {
            "features" => self.run_features(inputs[0].0),
            "head" => self.run_head(inputs[0].0),
            "full" => {
                let feats = self.run_features(inputs[0].0);
                self.run_head(&feats)
            }
            other => return Err(Error::Runtime(format!("unknown entry '{other}'"))),
        };
        self.executions += 1;
        Ok(out)
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn name(&self) -> &'static str {
        "cim"
    }

    fn epsilon_mode(&self) -> EpsilonMode {
        EpsilonMode::InWord
    }

    fn energy_report(&self) -> Option<EngineEnergyReport> {
        // Serving traffic deposits into the MC replicas; the reference
        // model's tiles only move when fidelity tests drive them
        // directly. Aggregate both so nothing is lost.
        let mut ledger = self.model.head_ledger();
        for replica in &self.replicas {
            for layer in replica {
                ledger.absorb(&layer.ledger());
            }
        }
        Some(EngineEnergyReport {
            total_j: ledger.total_j(),
            grng_j: ledger.component_j(Component::Grng),
            grng_samples: ledger.grng_samples,
            mvm_count: ledger.mvm_count,
            total_ops: ledger.mvm_count * self.ops_per_tile_mvm,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small tiles keep bring-up calibration cheap in debug builds.
    fn tiny_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.chip.tile.rows = 16;
        cfg.chip.tile.words_per_row = 4;
        cfg.server.max_batch = 2;
        cfg
    }

    #[test]
    fn manifest_contract_declares_in_word_epsilon() {
        let cfg = tiny_cfg();
        let e = CimEngine::from_config(&cfg);
        assert_eq!(e.epsilon_mode(), EpsilonMode::InWord);
        let m = e.manifest();
        assert_eq!(m.batch, 2);
        assert_eq!(m.classes, cfg.model.classes);
        for ep in ["features", "head", "full"] {
            assert!(m.entry_points.contains_key(ep), "missing {ep}");
        }
        // The head consumes features only — ε never crosses the boundary.
        assert_eq!(m.entry("head").unwrap().inputs.len(), 1);
        assert_eq!(m.entry("full").unwrap().inputs.len(), 1);
    }

    #[test]
    fn head_produces_normalized_stochastic_probs_and_meters_energy() {
        let cfg = tiny_cfg();
        let mut e = CimEngine::from_config(&cfg);
        let m = e.manifest().clone();
        let images = vec![0.4f32; m.batch * m.side * m.side];
        let fspec = m.entry("features").unwrap().clone();
        let feats = e.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        assert_eq!(feats.len(), m.batch * m.feature_dim);
        // Feature extraction is software: no tile energy yet.
        let r0 = e.energy_report().unwrap();
        assert_eq!(r0.mvm_count, 0);
        assert!(r0.total_j == 0.0, "bring-up energy must be cleared");

        let hspec = m.entry("head").unwrap().clone();
        let p0 = e.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
        for row in p0.chunks(m.classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax row sums to {sum}");
        }
        // Fresh in-word ε per pass ⇒ stochastic head.
        let p1 = e.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
        assert_ne!(p0, p1, "in-word ε must vary across MC passes");
        // Every MVM deposited joules and drew ε from the in-word banks.
        let r = e.energy_report().unwrap();
        assert!(r.mvm_count > 0 && r.total_j > 0.0);
        assert!(r.grng_samples > 0 && r.grng_j > 0.0);
        assert!(r.total_ops >= r.mvm_count);
        // Headline sanity: fJ/Sample in the hardware ballpark (≈360 fJ).
        let fj_per_sample = r.grng_j / r.grng_samples as f64 * 1e15;
        assert!(
            (100.0..1000.0).contains(&fj_per_sample),
            "fJ/Sample {fj_per_sample:.0} out of range"
        );
        assert_eq!(e.executions(), 3);
    }

    #[test]
    fn same_shard_is_bit_identical_across_instances() {
        let cfg = tiny_cfg();
        let mut a = CimEngine::for_shard(&cfg, 0);
        let mut b = CimEngine::for_shard(&cfg, 0);
        let m = a.manifest().clone();
        let images = vec![0.7f32; m.batch * m.side * m.side];
        let fspec = m.entry("features").unwrap().clone();
        let fa = a.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        let fb = b.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        assert_eq!(fa, fb);
        let hspec = m.entry("head").unwrap().clone();
        for _ in 0..3 {
            let pa = a.run("head", &[(&fa, &hspec.inputs[0].1)]).unwrap();
            let pb = b.run("head", &[(&fb, &hspec.inputs[0].1)]).unwrap();
            assert_eq!(pa, pb, "same (weights, die) must replay bitwise");
        }
    }

    #[test]
    fn different_shards_draw_different_dies() {
        let cfg = tiny_cfg();
        let mut a = CimEngine::for_shard(&cfg, 0);
        let mut b = CimEngine::for_shard(&cfg, 1);
        let m = a.manifest().clone();
        let images = vec![0.7f32; m.batch * m.side * m.side];
        let fspec = m.entry("features").unwrap().clone();
        let fa = a.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        // Weights are shared across shards: identical feature paths.
        let fb = b.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        assert_eq!(fa, fb);
        // Dies are not: ε streams (and analog chains) differ.
        let hspec = m.entry("head").unwrap().clone();
        let pa = a.run("head", &[(&fa, &hspec.inputs[0].1)]).unwrap();
        let pb = b.run("head", &[(&fb, &hspec.inputs[0].1)]).unwrap();
        assert_ne!(pa, pb, "independent dies must sample independently");
    }

    #[test]
    fn mc_fanout_covers_all_slots_and_replays_bitwise() {
        // More slots than replicas (5 % 3): some replicas own two slots,
        // one owns one — every slot must still be filled, and replay must
        // be bit-identical for the fixed (die_seed, mc_workers).
        let mut cfg = tiny_cfg();
        cfg.server.max_batch = 5;
        cfg.server.mc_workers = 3;
        let mut a = CimEngine::from_config(&cfg);
        let mut b = CimEngine::from_config(&cfg);
        let m = a.manifest().clone();
        let images = vec![0.6f32; m.batch * m.side * m.side];
        let fspec = m.entry("features").unwrap().clone();
        let feats = a.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        let _ = b.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        let hspec = m.entry("head").unwrap().clone();
        for _ in 0..3 {
            let pa = a.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
            let pb = b.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
            assert_eq!(pa, pb, "MC fan-out must be schedule-independent");
            // Every slot filled: all rows are valid softmax outputs.
            for row in pa.chunks(m.classes) {
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-4, "slot left empty: {row:?}");
            }
        }
        // Different mc_workers ⇒ a different (still deterministic)
        // slot→replica assignment: the contract pins the triple
        // (die_seed, workers, mc_workers), not the samples themselves.
        let mut cfg1 = tiny_cfg();
        cfg1.server.max_batch = 5;
        cfg1.server.mc_workers = 1;
        let mut c = CimEngine::from_config(&cfg1);
        let _ = c.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        let pc = c.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
        let mut d = CimEngine::from_config(&cfg);
        let _ = d.run("features", &[(&images, &fspec.inputs[0].1)]).unwrap();
        let pd = d.run("head", &[(&feats, &hspec.inputs[0].1)]).unwrap();
        assert_ne!(pd, pc, "slot→replica assignment must depend on mc_workers");
    }

    #[test]
    fn rejects_wrong_shapes_and_epsilon_inputs() {
        let cfg = tiny_cfg();
        let mut e = CimEngine::from_config(&cfg);
        let m = e.manifest().clone();
        let fspec = m.entry("features").unwrap().clone();
        let short = vec![0.0f32; 3];
        assert!(e.run("features", &[(&short, &fspec.inputs[0].1)]).is_err());
        // Passing external ε to an in-word engine is a contract error.
        let feats = vec![0.0f32; m.batch * m.feature_dim];
        let hspec = m.entry("head").unwrap().clone();
        let eps = vec![0.0f32; 8];
        let shape = &hspec.inputs[0].1;
        let with_eps = [(&feats[..], shape), (&eps[..], shape)];
        assert!(e.run("head", &with_eps).is_err());
        assert!(e.run("nope", &[]).is_err());
    }
}
