//! The behavioral chip model as a serving backend.
//!
//! `CimEngine` implements the coordinator's `InferenceEngine` contract by
//! mapping the Bayesian head onto simulated `cim::TileArray`s
//! (`Model::map_head_to_hardware`): μ/σ weights are quantized into the
//! differential/magnitude word encodings, every tile is bring-up
//! calibrated (Eq. 8–10), and each head MVM runs through the full analog
//! chain — IDAC drives, σε subarray, SAR ADCs, reduction logic — with ε
//! refreshed by the *in-word GRNG bank inside the engine*. This is the
//! chip's dataflow: the memory array that stores σ produces the
//! randomness the MVM consumes, so the engine declares
//! [`EpsilonMode::InWord`] and the coordinator supplies no external ε.
//!
//! The deterministic feature extractor runs in Rust
//! (`Model::forward_features`), mirroring the paper's partial-Bayesian
//! split (§III-A): only the FC head lives on CIM tiles.
//!
//! Determinism: weights derive from [`CIM_WEIGHT_SEED`] alone (shared by
//! every shard, like replicated PJRT engines), while the die — mismatch,
//! ADC/IDAC non-idealities, GRNG streams — derives from the shard's
//! `die_seed` split. Two engines built for the same `(cfg, shard)` replay
//! bit-identically.
//!
//! Energy: every MVM deposits joules into the tiles' `EnergyLedger`s;
//! [`InferenceEngine::energy_report`] exposes the cumulative totals (fJ/Sample,
//! J/Op numerators) without ever resetting them. Bring-up costs
//! (programming + calibration) are cleared at construction so the report
//! meters serving traffic only.

use super::artifact::{ArtifactSpec, Manifest};
use super::{EngineEnergyReport, EpsilonMode, InferenceEngine};
use crate::config::Config;
use crate::energy::{Component, EnergyLedger};
use crate::error::{Error, Result};
use crate::grng::shard_chip;
use crate::nn::model::{head_sample_layers, head_sample_layers_mc};
use crate::nn::{BayesDense, Model};
use crate::util::rng::SplitMix64;
use crate::util::threadpool::par_map_mut;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Weight seed shared by every shard of a simulated CIM deployment (the
/// "model weights" replicated across lanes; dies still differ per shard).
pub const CIM_WEIGHT_SEED: u64 = 0xC1BE_27F0_5EED_CA11;

/// Chip-model inference backend (no artifacts, no PJRT toolchain).
///
/// # MC-parallel sampling (`server.mc_workers`)
///
/// Every slot of a fused `head` call is an independent Monte-Carlo pass,
/// so the engine keeps `mc_workers` *replicas* of the calibrated head —
/// clones of the same mapped-and-calibrated tile arrays whose stochastic
/// streams (in-word GRNG cells, ADC noise) are reseeded from SplitMix64
/// splits of the shard's `die_seed`. Same die, independent sample
/// sequences: the software mirror of spatially unrolling MC samples
/// across compute lanes (VIBNN's parallel RNG banks; Fan et al.'s
/// unrolled FPGA sampler).
///
/// A replica clone is *cheap* by construction: μ/σ digit planes, IDAC and
/// ADC calibration tables, and the GRNG bank's SoA parameter lanes live
/// in a shared immutable layer behind `Arc`s (copy-on-calibrate — see
/// `cim::tile`), so cloning copies only ε buffers, RNG stream state and
/// scratch. `warm_head_planes` runs before the fan-out so the bit-plane
/// cache is built once and shared, not rebuilt per replica.
///
/// # Elastic capacity (`InferenceEngine::set_replicas`)
///
/// The replica pool can grow and shrink at batch boundaries. Replica `i`
/// always derives its stream seed from the i-th split of the shard's
/// replica seed, whether it was born at boot or re-grown after a shrink —
/// so a pool resized to `n` is bit-identical to a pool *booted* at `n`
/// (pinned by tests below). Shrink retires replicas into
/// `retired_ledger`, so cumulative energy accounting never loses joules.
///
/// Determinism contract: slot `b` always runs on replica `b % mc_workers`,
/// each replica processes its slots in ascending order on its own thread
/// (`util::threadpool::par_map_mut` hands each replica to exactly one
/// worker), and outputs are gathered by slot index. Replica streams are
/// private, so the result is a pure function of
/// `(die_seed, workers, mc_workers)` — thread scheduling never leaks in —
/// and replay is bit-identical (pinned by `tests/cim_fidelity.rs`).
pub struct CimEngine {
    manifest: Manifest,
    model: Model,
    /// MC-parallel head replicas (same die as `model`, split streams).
    /// Serving traffic runs here; `model` stays the reference instance
    /// for fidelity tests and hardware diagnostics.
    replicas: Vec<Vec<BayesDense>>,
    /// Base seed of the replica stream sequence (`die_seed` split); keeps
    /// elastic growth on the same per-index streams as boot-time fan-out.
    replica_seed_base: u64,
    /// Energy deposited by replicas that were since scaled away — folded
    /// into `energy_report` so shrink never loses joules.
    retired_ledger: EnergyLedger,
    /// MAC ops represented by one per-tile MVM (J/Op denominator).
    ops_per_tile_mvm: u64,
    executions: u64,
}

impl CimEngine {
    /// Engine for shard `shard` of a serving pool: shared weights, an
    /// independent die (`shard_die_seed` split of `chip.die_seed`), and
    /// the head mapped + calibrated onto tile arrays.
    pub fn for_shard(cfg: &Config, shard: usize) -> Self {
        Self::from_calibrated(cfg, shard, Self::build_model(cfg, shard))
    }

    /// Like [`Self::for_shard`], but the expensive bring-up (weight
    /// generation, hardware mapping, calibration, plane warming) is
    /// served from `cache`: the first build per shard populates it, and
    /// every later build — supervisor respawns in particular — clones the
    /// cached pristine model, Arc-sharing its weight/calibration layer.
    /// Bit-identical to a fresh [`Self::for_shard`] because bring-up is
    /// deterministic in `(cfg, shard)` and the cached model is stored
    /// untouched (the clone carries boot-time stream state).
    pub fn for_shard_cached(cfg: &Config, shard: usize, cache: &SharedModelCache) -> Self {
        Self::from_calibrated(cfg, shard, cache.model_for(cfg, shard))
    }

    /// The full bring-up for one shard die: shared weights, hardware
    /// mapping + calibration (Eq. 8–10), ledgers cleared, planes warmed.
    fn build_model(cfg: &Config, shard: usize) -> Model {
        let chip = shard_chip(&cfg.chip, shard);
        let mut model = Model::random(cfg.model.image_side, cfg.model.classes, CIM_WEIGHT_SEED);
        model.map_head_to_hardware(&chip);
        // Bring-up (programming + calibration) energy is a one-time cost;
        // zero the ledgers so energy_report meters serving traffic only.
        model.reset_head_ledgers();
        // Build the bit-plane cache ONCE on the prototype before the
        // replica fan-out: clones then share it behind an Arc instead of
        // each replica lazily rebuilding its own copy on first MVM.
        model.warm_head_planes();
        model
    }

    /// Assemble an engine around an already-calibrated model (from
    /// [`Self::build_model`] or a [`SharedModelCache`] hit).
    fn from_calibrated(cfg: &Config, shard: usize, model: Model) -> Self {
        let chip = shard_chip(&cfg.chip, shard);
        let batch = cfg.server.max_batch.max(1);
        let side = cfg.model.image_side;
        let classes = cfg.model.classes;

        // MC-parallel replicas: clone the calibrated head (an Arc share
        // of the immutable weight/calibration layer — no recalibration,
        // no weight copy) and reseed each clone's stochastic streams from
        // a split of this shard's die seed. Replica ledgers start at zero
        // (cloned after the bring-up reset).
        let mc_workers = cfg.server.mc_workers.max(1);
        let replica_seed_base = chip.die_seed ^ 0x4D43_5052_11CA_5EED;
        let replicas: Vec<Vec<BayesDense>> = (0..mc_workers)
            .map(|i| Self::make_replica(&model.head, replica_seed_base, i))
            .collect();

        let feature_dim = model.feature_dim;
        let pixels = side * side;
        let spec = |name: &str,
                    inputs: Vec<(String, Vec<usize>)>,
                    outputs: Vec<(String, Vec<usize>)>| ArtifactSpec {
            file: PathBuf::from(format!("cim://{name}")),
            inputs,
            outputs,
        };
        let mut entry_points = BTreeMap::new();
        entry_points.insert(
            "features".to_string(),
            spec(
                "features",
                vec![("pixels".to_string(), vec![batch, pixels])],
                vec![("features".to_string(), vec![batch, feature_dim])],
            ),
        );
        // In-word ε: the head takes features only — no ε inputs exist in
        // this engine's contract (EpsilonMode::InWord).
        entry_points.insert(
            "head".to_string(),
            spec(
                "head",
                vec![("features".to_string(), vec![batch, feature_dim])],
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        entry_points.insert(
            "full".to_string(),
            spec(
                "full",
                vec![("pixels".to_string(), vec![batch, pixels])],
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        let manifest = Manifest {
            batch,
            side,
            feature_dim,
            classes,
            entry_points,
            dir: PathBuf::from("cim://"),
        };
        Self {
            manifest,
            model,
            replicas,
            replica_seed_base,
            retired_ledger: EnergyLedger::new(),
            ops_per_tile_mvm: chip.tile.ops_per_mvm() as u64,
            executions: 0,
        }
    }

    /// Build MC replica `index` from the calibrated prototype head.
    ///
    /// The clone shares the immutable layer (μ/σ words, planes, IDAC/ADC
    /// calibration, GRNG parameter lanes) behind `Arc`s; only ε buffers
    /// and stream state are private. Replica `index`'s stream seed is the
    /// (index+1)-th split of `seed_base` — replayed from the base each
    /// time — so a replica re-grown after a shrink carries the *same*
    /// stream it would have had at boot, and the boot-time fan-out is
    /// byte-for-byte the historical sequence.
    fn make_replica(prototype: &[BayesDense], seed_base: u64, index: usize) -> Vec<BayesDense> {
        let mut replica_seeder = SplitMix64::new(seed_base);
        let mut seed = 0;
        for _ in 0..=index {
            seed = replica_seeder.split();
        }
        let mut layer_seeder = SplitMix64::new(seed);
        prototype
            .iter()
            .map(|layer| {
                let mut rep = layer.clone();
                rep.reseed_streams(layer_seeder.split());
                rep
            })
            .collect()
    }

    /// Engine matching a serving [`Config`] on the chip's own die
    /// (shard 0 keeps `die_seed` unsplit).
    pub fn from_config(cfg: &Config) -> Self {
        Self::for_shard(cfg, 0)
    }

    /// The mapped model (fidelity tests / hardware diagnostics).
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Mutable access to the mapped model (fidelity tests drive the tile
    /// arrays directly to compare MVMs against `mvm_reference`).
    pub fn model_mut(&mut self) -> &mut Model {
        &mut self.model
    }

    fn run_features(&self, images: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let p = self.manifest.side * self.manifest.side;
        let fdim = self.manifest.feature_dim;
        let mut out = Vec::with_capacity(b * fdim);
        for bi in 0..b {
            out.extend(self.model.forward_features(&images[bi * p..(bi + 1) * p]));
        }
        out
    }

    fn run_head(&mut self, feats: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let fdim = self.manifest.feature_dim;
        let c = self.manifest.classes;
        let replica_count = self.replicas.len();
        // One hardware MC pass per slot: each tile MVM refreshes ε from
        // its in-word bank, so every slot draws fresh randomness. Padding
        // slots execute too (the static-batch contract shared with the
        // AOT artifacts), so a fused call's energy covers the whole array
        // activation — fJ/Sample and J/Op stay normalized because their
        // denominators scale with the same passes.
        //
        // Deterministic fan-out (see the type-level docs): slot bi runs on
        // replica bi % mc_workers; each replica walks its slots in
        // ascending order; results are gathered by slot index. Scoped
        // threads (spawned per call) are a deliberate tradeoff: the
        // replicas' &mut borrows stay lifetime-checked with no channel
        // plumbing, and the spawn cost is small against a fused call's
        // tile work at the default chip size.
        //
        // Batched MC runs: the slot packer replicates one request's
        // features across its MC-pass slots, so a replica's consecutive
        // slots often carry the *same* feature row. Those runs collapse
        // into one `head_sample_layers_mc` call — the first head layer
        // then rides `mvm_batch`'s amortized drives/planes and (for runs
        // ≥ 4 on full-size banks) the double-buffered ε pipeline, where
        // the in-word banks
        // generate sample k+1's ε while sample k's MVM converts. Batched
        // == sequential bit-for-bit (pinned at every level), so the
        // replay contract below is unchanged.
        let per_replica = par_map_mut(&mut self.replicas, replica_count, |r, layers| {
            let row = |i: usize| &feats[i * fdim..(i + 1) * fdim];
            let mut samples = Vec::new();
            let mut bi = r;
            while bi < b {
                let feat = row(bi);
                let mut run = 1;
                while bi + run * replica_count < b && row(bi + run * replica_count) == feat {
                    run += 1;
                }
                if run == 1 {
                    samples.push((bi, head_sample_layers(layers, feat)));
                } else {
                    let probs = head_sample_layers_mc(layers, feat, run);
                    for (k, p) in probs.into_iter().enumerate() {
                        samples.push((bi + k * replica_count, p));
                    }
                }
                bi += run * replica_count;
            }
            samples
        });
        let mut out = vec![0.0f32; b * c];
        for samples in per_replica {
            for (bi, probs) in samples {
                for (j, &v) in probs.iter().enumerate() {
                    out[bi * c + j] = v as f32;
                }
            }
        }
        out
    }
}

/// Per-shard cache of calibrated cim models, shared by an engine
/// factory's clones so that supervisor respawns (and model re-boots in
/// general) skip the bring-up entirely: the respawned engine clones the
/// cached pristine model, Arc-sharing its μ/σ words, digit planes,
/// IDAC/ADC calibration tables, and GRNG parameter lanes with every
/// other engine built for that shard. Only stream state and ε scratch
/// are copied, so a respawn costs O(ε buffers) — and stays bit-identical
/// to a cold boot because the cached model is never mutated after
/// insertion (serving engines own their clones).
#[derive(Clone, Default)]
pub struct SharedModelCache {
    models: Arc<Mutex<HashMap<usize, Model>>>,
}

impl SharedModelCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A pristine calibrated model for `shard`: built once, cloned ever
    /// after. The lock is held across a miss's bring-up so concurrent
    /// boots of the same shard do the expensive work exactly once.
    fn model_for(&self, cfg: &Config, shard: usize) -> Model {
        let mut models = self.models.lock().unwrap_or_else(|p| p.into_inner());
        models
            .entry(shard)
            .or_insert_with(|| CimEngine::build_model(cfg, shard))
            .clone()
    }

    /// Shards with a cached model (diagnostics/tests).
    pub fn cached_shards(&self) -> usize {
        self.models.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

impl InferenceEngine for CimEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>> {
        let spec = self.manifest.entry(entry)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "entry '{entry}' expects {} inputs, got {} (in-word ε: the \
                 head takes features only)",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (data, _shape)) in inputs.iter().enumerate() {
            let want: usize = spec.inputs[i].1.iter().product();
            if data.len() != want {
                return Err(Error::Runtime(format!(
                    "entry '{entry}' input {i} ('{}') expects {} elements, got {}",
                    spec.inputs[i].0,
                    want,
                    data.len()
                )));
            }
        }
        let out = match entry {
            "features" => self.run_features(inputs[0].0),
            "head" => self.run_head(inputs[0].0),
            "full" => {
                let feats = self.run_features(inputs[0].0);
                self.run_head(&feats)
            }
            other => return Err(Error::Runtime(format!("unknown entry '{other}'"))),
        };
        self.executions += 1;
        Ok(out)
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn name(&self) -> &'static str {
        "cim"
    }

    fn epsilon_mode(&self) -> EpsilonMode {
        EpsilonMode::InWord
    }

    fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    fn set_replicas(&mut self, n: usize) {
        let n = n.max(1);
        while self.replicas.len() > n {
            // Retire from the tail so surviving replicas keep their index
            // (and therefore their stream identity).
            if let Some(replica) = self.replicas.pop() {
                for layer in &replica {
                    self.retired_ledger.absorb(&layer.ledger());
                }
            }
        }
        while self.replicas.len() < n {
            let index = self.replicas.len();
            self.replicas
                .push(Self::make_replica(&self.model.head, self.replica_seed_base, index));
        }
    }

    fn bytes_shared(&self) -> usize {
        self.model.head_bytes_shared()
    }

    fn bytes_private(&self) -> usize {
        self.replicas
            .iter()
            .flat_map(|replica| replica.iter())
            .map(|layer| layer.bytes_private())
            .sum()
    }

    fn energy_report(&self) -> Option<EngineEnergyReport> {
        // Serving traffic deposits into the MC replicas; the reference
        // model's tiles only move when fidelity tests drive them
        // directly. Aggregate both — plus replicas retired by elastic
        // shrink — so nothing is lost.
        let mut ledger = self.model.head_ledger();
        ledger.absorb(&self.retired_ledger);
        for replica in &self.replicas {
            for layer in replica {
                ledger.absorb(&layer.ledger());
            }
        }
        Some(EngineEnergyReport {
            total_j: ledger.total_j(),
            grng_j: ledger.component_j(Component::Grng),
            grng_samples: ledger.grng_samples,
            mvm_count: ledger.mvm_count,
            total_ops: ledger.mvm_count * self.ops_per_tile_mvm,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small tiles keep bring-up calibration cheap in debug builds.
    fn tiny_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.chip.tile.rows = 16;
        cfg.chip.tile.words_per_row = 4;
        cfg.server.max_batch = 2;
        cfg
    }

    /// Copy out scalar dims + per-entry input shapes so tests never clone
    /// the whole `Manifest`: (batch, side, feature_dim, classes,
    /// features-input shape, head-input shape).
    fn dims_and_shapes(e: &CimEngine) -> (usize, usize, usize, usize, Vec<usize>, Vec<usize>) {
        let m = e.manifest();
        (
            m.batch,
            m.side,
            m.feature_dim,
            m.classes,
            m.entry("features").unwrap().inputs[0].1.clone(),
            m.entry("head").unwrap().inputs[0].1.clone(),
        )
    }

    #[test]
    fn manifest_contract_declares_in_word_epsilon() {
        let cfg = tiny_cfg();
        let e = CimEngine::from_config(&cfg);
        assert_eq!(e.epsilon_mode(), EpsilonMode::InWord);
        let m = e.manifest();
        assert_eq!(m.batch, 2);
        assert_eq!(m.classes, cfg.model.classes);
        for ep in ["features", "head", "full"] {
            assert!(m.entry_points.contains_key(ep), "missing {ep}");
        }
        // The head consumes features only — ε never crosses the boundary.
        assert_eq!(m.entry("head").unwrap().inputs.len(), 1);
        assert_eq!(m.entry("full").unwrap().inputs.len(), 1);
    }

    #[test]
    fn head_produces_normalized_stochastic_probs_and_meters_energy() {
        let cfg = tiny_cfg();
        let mut e = CimEngine::from_config(&cfg);
        let (batch, side, fdim, classes, fshape, hshape) = dims_and_shapes(&e);
        let images = vec![0.4f32; batch * side * side];
        let feats = e.run("features", &[(&images, &fshape)]).unwrap();
        assert_eq!(feats.len(), batch * fdim);
        // Feature extraction is software: no tile energy yet.
        let r0 = e.energy_report().unwrap();
        assert_eq!(r0.mvm_count, 0);
        assert!(r0.total_j == 0.0, "bring-up energy must be cleared");

        let p0 = e.run("head", &[(&feats, &hshape)]).unwrap();
        for row in p0.chunks(classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax row sums to {sum}");
        }
        // Fresh in-word ε per pass ⇒ stochastic head.
        let p1 = e.run("head", &[(&feats, &hshape)]).unwrap();
        assert_ne!(p0, p1, "in-word ε must vary across MC passes");
        // Every MVM deposited joules and drew ε from the in-word banks.
        let r = e.energy_report().unwrap();
        assert!(r.mvm_count > 0 && r.total_j > 0.0);
        assert!(r.grng_samples > 0 && r.grng_j > 0.0);
        assert!(r.total_ops >= r.mvm_count);
        // Headline sanity: fJ/Sample in the hardware ballpark (≈360 fJ).
        let fj_per_sample = r.grng_j / r.grng_samples as f64 * 1e15;
        assert!(
            (100.0..1000.0).contains(&fj_per_sample),
            "fJ/Sample {fj_per_sample:.0} out of range"
        );
        assert_eq!(e.executions(), 3);
    }

    #[test]
    fn same_shard_is_bit_identical_across_instances() {
        let cfg = tiny_cfg();
        let mut a = CimEngine::for_shard(&cfg, 0);
        let mut b = CimEngine::for_shard(&cfg, 0);
        let (batch, side, _fdim, _classes, fshape, hshape) = dims_and_shapes(&a);
        let images = vec![0.7f32; batch * side * side];
        let fa = a.run("features", &[(&images, &fshape)]).unwrap();
        let fb = b.run("features", &[(&images, &fshape)]).unwrap();
        assert_eq!(fa, fb);
        for _ in 0..3 {
            let pa = a.run("head", &[(&fa, &hshape)]).unwrap();
            let pb = b.run("head", &[(&fb, &hshape)]).unwrap();
            assert_eq!(pa, pb, "same (weights, die) must replay bitwise");
        }
    }

    #[test]
    fn different_shards_draw_different_dies() {
        let cfg = tiny_cfg();
        let mut a = CimEngine::for_shard(&cfg, 0);
        let mut b = CimEngine::for_shard(&cfg, 1);
        let (batch, side, _fdim, _classes, fshape, hshape) = dims_and_shapes(&a);
        let images = vec![0.7f32; batch * side * side];
        let fa = a.run("features", &[(&images, &fshape)]).unwrap();
        // Weights are shared across shards: identical feature paths.
        let fb = b.run("features", &[(&images, &fshape)]).unwrap();
        assert_eq!(fa, fb);
        // Dies are not: ε streams (and analog chains) differ.
        let pa = a.run("head", &[(&fa, &hshape)]).unwrap();
        let pb = b.run("head", &[(&fb, &hshape)]).unwrap();
        assert_ne!(pa, pb, "independent dies must sample independently");
    }

    #[test]
    fn mc_fanout_covers_all_slots_and_replays_bitwise() {
        // More slots than replicas (5 % 3): some replicas own two slots,
        // one owns one — every slot must still be filled, and replay must
        // be bit-identical for the fixed (die_seed, mc_workers).
        let mut cfg = tiny_cfg();
        cfg.server.max_batch = 5;
        cfg.server.mc_workers = 3;
        let mut a = CimEngine::from_config(&cfg);
        let mut b = CimEngine::from_config(&cfg);
        let (batch, side, _fdim, classes, fshape, hshape) = dims_and_shapes(&a);
        let images = vec![0.6f32; batch * side * side];
        let feats = a.run("features", &[(&images, &fshape)]).unwrap();
        let _ = b.run("features", &[(&images, &fshape)]).unwrap();
        for _ in 0..3 {
            let pa = a.run("head", &[(&feats, &hshape)]).unwrap();
            let pb = b.run("head", &[(&feats, &hshape)]).unwrap();
            assert_eq!(pa, pb, "MC fan-out must be schedule-independent");
            // Every slot filled: all rows are valid softmax outputs.
            for row in pa.chunks(classes) {
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-4, "slot left empty: {row:?}");
            }
        }
        // Different mc_workers ⇒ a different (still deterministic)
        // slot→replica assignment: the contract pins the triple
        // (die_seed, workers, mc_workers), not the samples themselves.
        let mut cfg1 = tiny_cfg();
        cfg1.server.max_batch = 5;
        cfg1.server.mc_workers = 1;
        let mut c = CimEngine::from_config(&cfg1);
        let _ = c.run("features", &[(&images, &fshape)]).unwrap();
        let pc = c.run("head", &[(&feats, &hshape)]).unwrap();
        let mut d = CimEngine::from_config(&cfg);
        let _ = d.run("features", &[(&images, &fshape)]).unwrap();
        let pd = d.run("head", &[(&feats, &hshape)]).unwrap();
        assert_ne!(pd, pc, "slot→replica assignment must depend on mc_workers");
    }

    #[test]
    fn rejects_wrong_shapes_and_epsilon_inputs() {
        let cfg = tiny_cfg();
        let mut e = CimEngine::from_config(&cfg);
        let (batch, _side, fdim, _classes, fshape, hshape) = dims_and_shapes(&e);
        let short = vec![0.0f32; 3];
        assert!(e.run("features", &[(&short, &fshape)]).is_err());
        // Passing external ε to an in-word engine is a contract error.
        let feats = vec![0.0f32; batch * fdim];
        let eps = vec![0.0f32; 8];
        let with_eps = [(&feats[..], &hshape), (&eps[..], &hshape)];
        assert!(e.run("head", &with_eps).is_err());
        assert!(e.run("nope", &[]).is_err());
    }

    #[test]
    fn replicas_share_immutable_layer_with_prototype() {
        let mut cfg = tiny_cfg();
        cfg.server.mc_workers = 3;
        let e = CimEngine::from_config(&cfg);
        // Every replica's layers point at the SAME weight/calibration
        // storage as the reference model — clone copied no weights.
        for replica in &e.replicas {
            for (rep, proto) in replica.iter().zip(e.model.head.iter()) {
                assert!(
                    rep.shares_statics_with(proto),
                    "replica must Arc-share the immutable layer"
                );
            }
        }
        // Footprint split: the private (per-replica) state is small next
        // to the shared layer even with 3 replicas on a tiny tile.
        let shared = e.bytes_shared();
        let private = e.bytes_private();
        assert!(shared > 0 && private > 0);
        assert!(
            private < shared,
            "private {private} B should be dwarfed by shared {shared} B"
        );
    }

    #[test]
    fn elastic_regrowth_is_bit_identical_to_boot_and_keeps_energy() {
        // A pool shrunk to 1 and re-grown to 3 must serve the same
        // samples a freshly booted pool would, and shrink must not drop
        // the retired replicas' joules.
        let mut cfg = tiny_cfg();
        cfg.server.max_batch = 3;
        cfg.server.mc_workers = 3;
        let mut a = CimEngine::from_config(&cfg);
        let mut b = CimEngine::from_config(&cfg);
        let (batch, side, _fdim, classes, fshape, hshape) = dims_and_shapes(&a);
        let images = vec![0.5f32; batch * side * side];
        let feats = a.run("features", &[(&images, &fshape)]).unwrap();
        let _ = b.run("features", &[(&images, &fshape)]).unwrap();

        // Deposit energy in all three replicas, then shrink: the total
        // must survive the retirement (modulo f64 summation order).
        assert_eq!(a.replica_count(), 3);
        let _ = a.run("head", &[(&feats, &hshape)]).unwrap();
        let j_before = a.energy_report().unwrap().total_j;
        assert!(j_before > 0.0);
        a.set_replicas(1);
        assert_eq!(a.replica_count(), 1);
        let j_after = a.energy_report().unwrap().total_j;
        assert!(
            (j_after - j_before).abs() <= j_before * 1e-9,
            "shrink must retire ledgers, not drop them: {j_before} -> {j_after}"
        );

        // Re-grow: replicas 1 and 2 restart their boot streams and share
        // statics with the prototype again.
        a.set_replicas(3);
        assert_eq!(a.replica_count(), 3);
        for replica in &a.replicas {
            for (rep, proto) in replica.iter().zip(a.model.head.iter()) {
                assert!(rep.shares_statics_with(proto));
            }
        }
        // Slot i runs on replica i (batch == mc_workers). b's FIRST head
        // pass uses boot streams on every replica, so a's re-grown
        // replicas (1, 2) must reproduce b's slots 1, 2 exactly. Slot 0
        // runs on a's surviving replica 0, whose stream has advanced.
        let pa = a.run("head", &[(&feats, &hshape)]).unwrap();
        let pb = b.run("head", &[(&feats, &hshape)]).unwrap();
        for slot in 1..batch {
            assert_eq!(
                &pa[slot * classes..(slot + 1) * classes],
                &pb[slot * classes..(slot + 1) * classes],
                "re-grown replica {slot} must replay its boot stream"
            );
        }

        // The pool never collapses below one replica.
        a.set_replicas(0);
        assert_eq!(a.replica_count(), 1);
    }

    #[test]
    fn cached_build_is_bit_identical_to_cold_boot_and_shares_statics() {
        // The supervisor's respawn path: a cache-served engine must share
        // the cached calibration layer (no re-calibration) yet serve
        // byte-for-byte what a cold boot serves.
        let cfg = tiny_cfg();
        let cache = SharedModelCache::new();
        let mut cold = CimEngine::for_shard(&cfg, 0);
        let mut warm = CimEngine::for_shard_cached(&cfg, 0, &cache); // populates
        let mut respawn = CimEngine::for_shard_cached(&cfg, 0, &cache); // hit
        assert_eq!(cache.cached_shards(), 1);
        // Engines from the same cache Arc-share one immutable layer.
        for (a, b) in warm.model().head.iter().zip(respawn.model().head.iter()) {
            assert!(
                a.shares_statics_with(b),
                "cache-served engines must share calibration storage"
            );
        }
        let (batch, side, _fdim, _classes, fshape, hshape) = dims_and_shapes(&cold);
        let images = vec![0.3f32; batch * side * side];
        let feats = cold.run("features", &[(&images, &fshape)]).unwrap();
        for e in [&mut warm, &mut respawn] {
            assert_eq!(feats, e.run("features", &[(&images, &fshape)]).unwrap());
        }
        for _ in 0..2 {
            let p_cold = cold.run("head", &[(&feats, &hshape)]).unwrap();
            assert_eq!(p_cold, warm.run("head", &[(&feats, &hshape)]).unwrap());
            assert_eq!(p_cold, respawn.run("head", &[(&feats, &hshape)]).unwrap());
        }
    }
}
