//! Deterministic pure-Rust inference backend with the PJRT engine's
//! entry-point contract (`features` / `head` / `full`).
//!
//! The model is a fixed random two-layer network: a tanh feature
//! projection and a Bayesian-style linear head whose weights are
//! perturbed by the ε inputs (`w = μ + σ·ε`), so the coordinator's
//! Monte-Carlo loop exercises exactly the same dataflow as the compiled
//! artifacts — features once per batch, fresh ε per head pass. Weights
//! derive from a seed alone, so two `SimEngine`s built with the same
//! parameters are bit-identical replicas: the shard pool shares "model
//! weights" across workers just like replicated PJRT engines do.

use super::artifact::{ArtifactSpec, Manifest};
use super::InferenceEngine;
use crate::config::Config;
use crate::error::{Error, Result};
use crate::util::rng::{Rng64, SplitMix64};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Weight seed shared by every shard of a simulated deployment.
const SIM_WEIGHT_SEED: u64 = 0x51E0_C1A5_B00C_A571;

/// Pure-Rust stand-in engine (no artifacts, no PJRT toolchain).
pub struct SimEngine {
    manifest: Manifest,
    /// Feature projection, row-major `[feature_dim][pixels]`.
    w1: Vec<f32>,
    /// Head μ weights, row-major `[feature_dim][classes]`.
    wmu: Vec<f32>,
    /// Head μ bias, `[classes]`.
    bmu: Vec<f32>,
    /// Shared σ scale applied to the ε inputs.
    sigma: f32,
    executions: u64,
}

impl SimEngine {
    /// Feature width used by [`SimEngine::from_config`].
    pub const DEFAULT_FEATURE_DIM: usize = 32;

    pub fn new(batch: usize, side: usize, feature_dim: usize, classes: usize, seed: u64) -> Self {
        assert!(batch > 0 && side > 0 && feature_dim > 0 && classes > 0);
        let pixels = side * side;
        let mut rng = SplitMix64::new(seed);
        let s1 = (2.0 / pixels as f64).sqrt();
        let w1: Vec<f32> = (0..feature_dim * pixels)
            .map(|_| ((rng.next_f64() - 0.5) * 2.0 * s1) as f32)
            .collect();
        let s2 = (2.0 / feature_dim as f64).sqrt();
        let wmu: Vec<f32> = (0..feature_dim * classes)
            .map(|_| ((rng.next_f64() - 0.5) * 2.0 * s2) as f32)
            .collect();
        let bmu: Vec<f32> = (0..classes)
            .map(|_| ((rng.next_f64() - 0.5) * 0.2) as f32)
            .collect();

        let spec = |name: &str,
                    inputs: Vec<(String, Vec<usize>)>,
                    outputs: Vec<(String, Vec<usize>)>| ArtifactSpec {
            file: PathBuf::from(format!("sim://{name}")),
            inputs,
            outputs,
        };
        let mut entry_points = BTreeMap::new();
        entry_points.insert(
            "features".to_string(),
            spec(
                "features",
                vec![("pixels".to_string(), vec![batch, pixels])],
                vec![("features".to_string(), vec![batch, feature_dim])],
            ),
        );
        let eps_inputs = vec![
            ("eps_w".to_string(), vec![feature_dim, classes]),
            ("eps_b".to_string(), vec![classes]),
        ];
        entry_points.insert(
            "head".to_string(),
            spec(
                "head",
                {
                    let mut v = vec![("features".to_string(), vec![batch, feature_dim])];
                    v.extend(eps_inputs.clone());
                    v
                },
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        entry_points.insert(
            "full".to_string(),
            spec(
                "full",
                {
                    let mut v = vec![("pixels".to_string(), vec![batch, pixels])];
                    v.extend(eps_inputs);
                    v
                },
                vec![("probs".to_string(), vec![batch, classes])],
            ),
        );
        let manifest = Manifest {
            batch,
            side,
            feature_dim,
            classes,
            entry_points,
            dir: PathBuf::from("sim://"),
        };
        Self {
            manifest,
            w1,
            wmu,
            bmu,
            sigma: 0.3,
            executions: 0,
        }
    }

    /// Engine matching a serving [`Config`]: the artifact batch is the
    /// server's `max_batch` and input/class shapes come from the model
    /// config. All shards share `SIM_WEIGHT_SEED`.
    pub fn from_config(cfg: &Config) -> Self {
        Self::new(
            cfg.server.max_batch.max(1),
            cfg.model.image_side,
            Self::DEFAULT_FEATURE_DIM,
            cfg.model.classes,
            SIM_WEIGHT_SEED,
        )
    }

    fn run_features(&self, images: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let p = self.manifest.side * self.manifest.side;
        let fdim = self.manifest.feature_dim;
        let mut out = vec![0.0f32; b * fdim];
        for bi in 0..b {
            let img = &images[bi * p..(bi + 1) * p];
            for fi in 0..fdim {
                let row = &self.w1[fi * p..(fi + 1) * p];
                let mut acc = 0.0f32;
                for (w, x) in row.iter().zip(img.iter()) {
                    acc += w * x;
                }
                out[bi * fdim + fi] = acc.tanh();
            }
        }
        out
    }

    fn run_head(&self, feats: &[f32], eps_w: &[f32], eps_b: &[f32]) -> Vec<f32> {
        let b = self.manifest.batch;
        let c = self.manifest.classes;
        let fdim = self.manifest.feature_dim;
        let mut out = vec![0.0f32; b * c];
        let mut logits = vec![0.0f32; c];
        for bi in 0..b {
            let fr = &feats[bi * fdim..(bi + 1) * fdim];
            for (ci, l) in logits.iter_mut().enumerate() {
                let mut acc = self.bmu[ci] + self.sigma * eps_b[ci];
                for (fi, &fv) in fr.iter().enumerate() {
                    acc += fv * (self.wmu[fi * c + ci] + self.sigma * eps_w[fi * c + ci]);
                }
                *l = acc;
            }
            let max = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let mut sum = 0.0f32;
            for l in logits.iter_mut() {
                *l = (*l - max).exp();
                sum += *l;
            }
            for (ci, &l) in logits.iter().enumerate() {
                out[bi * c + ci] = l / sum;
            }
        }
        out
    }
}

impl InferenceEngine for SimEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> Result<Vec<f32>> {
        let spec = self.manifest.entry(entry)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "entry '{entry}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (data, _shape)) in inputs.iter().enumerate() {
            let want: usize = spec.inputs[i].1.iter().product();
            if data.len() != want {
                return Err(Error::Runtime(format!(
                    "entry '{entry}' input {i} ('{}') expects {} elements, got {}",
                    spec.inputs[i].0,
                    want,
                    data.len()
                )));
            }
        }
        let out = match entry {
            "features" => self.run_features(inputs[0].0),
            "head" => self.run_head(inputs[0].0, inputs[1].0, inputs[2].0),
            "full" => {
                let feats = self.run_features(inputs[0].0);
                self.run_head(&feats, inputs[1].0, inputs[2].0)
            }
            other => return Err(Error::Runtime(format!("unknown entry '{other}'"))),
        };
        self.executions += 1;
        Ok(out)
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SimEngine {
        SimEngine::new(4, 8, 6, 3, 99)
    }

    /// Copy out scalar dims + the features-entry input shape so tests
    /// never clone the whole `Manifest`.
    fn dims_and_fshape(e: &SimEngine) -> (usize, usize, usize, usize, Vec<usize>) {
        let m = e.manifest();
        (
            m.batch,
            m.side,
            m.feature_dim,
            m.classes,
            m.entry("features").unwrap().inputs[0].1.clone(),
        )
    }

    fn run_head_of(engine: &mut SimEngine, feats: &[f32], e1: f32, e2: f32) -> Vec<f32> {
        let (fshape, wshape, bshape) = {
            let spec = engine.manifest().entry("head").unwrap();
            (
                spec.inputs[0].1.clone(),
                spec.inputs[1].1.clone(),
                spec.inputs[2].1.clone(),
            )
        };
        let eps1 = vec![e1; wshape.iter().product()];
        let eps2 = vec![e2; bshape.iter().product()];
        engine
            .run(
                "head",
                &[(feats, &fshape), (&eps1, &wshape), (&eps2, &bshape)],
            )
            .unwrap()
    }

    #[test]
    fn manifest_contract_matches_artifacts() {
        let e = tiny();
        let m = e.manifest();
        assert_eq!(m.batch, 4);
        assert_eq!(m.classes, 3);
        for ep in ["features", "head", "full"] {
            assert!(m.entry_points.contains_key(ep), "missing {ep}");
        }
        let head = m.entry("head").unwrap();
        assert_eq!(head.inputs.len(), 3);
        assert_eq!(head.outputs[0].1[1], m.classes);
    }

    #[test]
    fn probs_are_normalized_and_eps_sensitive() {
        let mut e = tiny();
        let (batch, side, fdim, classes, fshape) = dims_and_fshape(&e);
        let images = vec![0.25f32; batch * side * side];
        let feats = e.run("features", &[(&images, &fshape)]).unwrap();
        assert_eq!(feats.len(), batch * fdim);
        let p0 = run_head_of(&mut e, &feats, 0.0, 0.0);
        for row in p0.chunks(classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
        }
        // ε perturbs the head (σ > 0): that is the Bayesian dataflow.
        let p1 = run_head_of(&mut e, &feats, 1.0, -1.0);
        assert_ne!(p0, p1);
        assert_eq!(e.executions(), 3);
    }

    #[test]
    fn same_seed_is_bit_identical_across_instances() {
        let mut a = tiny();
        let mut b = tiny();
        let (batch, side, _fdim, _classes, fshape) = dims_and_fshape(&a);
        let images = vec![0.5f32; batch * side * side];
        let fa = a.run("features", &[(&images, &fshape)]).unwrap();
        let fb = b.run("features", &[(&images, &fshape)]).unwrap();
        assert_eq!(fa, fb);
        assert_eq!(run_head_of(&mut a, &fa, 0.5, 0.5), run_head_of(&mut b, &fb, 0.5, 0.5));
    }

    #[test]
    fn rejects_wrong_input_shapes() {
        let mut e = tiny();
        let (_batch, _side, _fdim, _classes, fshape) = dims_and_fshape(&e);
        let short = vec![0.0f32; 3];
        assert!(e.run("features", &[(&short, &fshape)]).is_err());
        assert!(e.run("nope", &[]).is_err());
    }
}
