//! Artifact manifest: discovery and shape metadata for the AOT outputs.

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered entry point (an HLO-text file plus its signature).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    /// (name, shape) per input, in call order.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// (name, shape) per output.
    pub outputs: Vec<(String, Vec<usize>)>,
}

impl ArtifactSpec {
    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].1.iter().product()
    }

    pub fn output_len(&self, i: usize) -> usize {
        self.outputs[i].1.iter().product()
    }
}

/// The artifacts/manifest.json written by python/compile/aot.py.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub side: usize,
    pub feature_dim: usize,
    pub classes: usize,
    pub entry_points: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let doc = Json::read_file(&dir.join("manifest.json"))
            .map_err(|e| Error::Artifact(format!("manifest: {e}")))?;
        let get = |k: &str| -> Result<usize> {
            doc.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| Error::Artifact(format!("manifest missing '{k}'")))
        };
        let mut entry_points = BTreeMap::new();
        let eps = doc
            .get("entry_points")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| Error::Artifact("manifest missing entry_points".into()))?;
        for (name, spec) in eps {
            let file = spec
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| Error::Artifact(format!("{name}: missing file")))?;
            let parse_sig = |key: &str| -> Result<Vec<(String, Vec<usize>)>> {
                spec.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| Error::Artifact(format!("{name}: missing {key}")))?
                    .iter()
                    .map(|pair| {
                        let arr = pair
                            .as_arr()
                            .ok_or_else(|| Error::Artifact(format!("{name}: bad {key}")))?;
                        let nm = arr[0]
                            .as_str()
                            .ok_or_else(|| Error::Artifact(format!("{name}: bad {key} name")))?
                            .to_string();
                        let shape = arr[1]
                            .as_usize_vec()
                            .ok_or_else(|| Error::Artifact(format!("{name}: bad {key} shape")))?;
                        Ok((nm, shape))
                    })
                    .collect()
            };
            entry_points.insert(
                name.clone(),
                ArtifactSpec {
                    file: dir.join(file),
                    inputs: parse_sig("inputs")?,
                    outputs: parse_sig("outputs")?,
                },
            );
        }
        Ok(Manifest {
            batch: get("batch")?,
            side: get("side")?,
            feature_dim: get("feature_dim")?,
            classes: get("classes")?,
            entry_points,
            dir: dir.to_path_buf(),
        })
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactSpec> {
        self.entry_points
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no entry point '{name}'")))
    }

    /// Path of the weights JSON exported alongside the HLO.
    pub fn weights_path(&self) -> PathBuf {
        self.dir.join("weights.json")
    }

    /// Path of the shared eval batch (may not exist).
    pub fn eval_batch_path(&self) -> PathBuf {
        self.dir.join("eval_batch.json")
    }
}
