//! Energy and area accounting (Fig. 12 breakdown, Tab. II metrics).
//!
//! Every simulated hardware event deposits joules into an [`EnergyLedger`]
//! keyed by component; the benches query breakdowns and derived
//! efficiencies. Area comes statically from the config tables.

use crate::config::{AreaTable, ChipConfig, TileConfig};
use std::collections::BTreeMap;

/// Hardware components tracked by the ledger (Fig. 12 categories).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Component {
    /// 8T SRAM cells conducting during the MVM integration window.
    Sram,
    /// In-word GRNG cells (sampling energy).
    Grng,
    /// SAR ADC conversions.
    Adc,
    /// Row IDACs.
    Idac,
    /// Bitline precharge.
    Bitline,
    /// Digital reduction + offset-correction logic.
    Reduction,
    /// σε-word transmission-gate switching.
    Switches,
    /// Tile leakage (integrated over active time).
    Leakage,
    /// SRAM writes (programming / calibration).
    SramWrite,
}

impl Component {
    pub fn name(&self) -> &'static str {
        match self {
            Component::Sram => "SRAM (read)",
            Component::Grng => "GRNG",
            Component::Adc => "SAR ADC",
            Component::Idac => "IDAC",
            Component::Bitline => "Bitline precharge",
            Component::Reduction => "Reduction logic",
            Component::Switches => "TG switches",
            Component::Leakage => "Leakage",
            Component::SramWrite => "SRAM (write)",
        }
    }

    pub fn all() -> &'static [Component] {
        &[
            Component::Sram,
            Component::Grng,
            Component::Adc,
            Component::Idac,
            Component::Bitline,
            Component::Reduction,
            Component::Switches,
            Component::Leakage,
            Component::SramWrite,
        ]
    }
}

/// Accumulates energy by component.
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    joules: BTreeMap<Component, f64>,
    /// Operation counters for efficiency metrics.
    pub mvm_count: u64,
    pub grng_samples: u64,
}

impl EnergyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn deposit(&mut self, c: Component, joules: f64) {
        *self.joules.entry(c).or_insert(0.0) += joules;
    }

    pub fn total_j(&self) -> f64 {
        self.joules.values().sum()
    }

    pub fn component_j(&self, c: Component) -> f64 {
        self.joules.get(&c).copied().unwrap_or(0.0)
    }

    /// Breakdown as (component, joules, share-of-total).
    pub fn breakdown(&self) -> Vec<(Component, f64, f64)> {
        let total = self.total_j().max(1e-300);
        self.joules
            .iter()
            .map(|(&c, &j)| (c, j, j / total))
            .collect()
    }

    pub fn reset(&mut self) {
        self.joules.clear();
        self.mvm_count = 0;
        self.grng_samples = 0;
    }

    /// Merge another ledger into this one.
    pub fn absorb(&mut self, other: &EnergyLedger) {
        for (&c, &j) in &other.joules {
            self.deposit(c, j);
        }
        self.mvm_count += other.mvm_count;
        self.grng_samples += other.grng_samples;
    }

    /// NN efficiency [J/Op] over everything recorded.
    pub fn j_per_op(&self, ops_per_mvm: usize) -> f64 {
        if self.mvm_count == 0 {
            return f64::NAN;
        }
        self.total_j() / (self.mvm_count as f64 * ops_per_mvm as f64)
    }

    /// GRNG efficiency [J/Sample].
    pub fn j_per_sample(&self) -> f64 {
        if self.grng_samples == 0 {
            return f64::NAN;
        }
        self.component_j(Component::Grng) / self.grng_samples as f64
    }

    /// Render an ASCII breakdown table.
    pub fn ascii_breakdown(&self) -> String {
        let mut s = String::new();
        let total = self.total_j();
        s.push_str(&format!("total: {:.3} pJ\n", total * 1e12));
        for (c, j, share) in self.breakdown() {
            let bar = "#".repeat((share * 40.0).round() as usize);
            s.push_str(&format!(
                "  {:<18} {:>10.3} pJ {:>6.1}% {}\n",
                c.name(),
                j * 1e12,
                share * 100.0,
                bar
            ));
        }
        s
    }
}

/// Static area breakdown of one tile + chip overhead (Fig. 12-left).
#[derive(Clone, Debug)]
pub struct AreaBreakdown {
    pub items: Vec<(&'static str, f64)>,
    pub tile_mm2: f64,
    pub chip_mm2: f64,
}

pub fn area_breakdown(tile: &TileConfig, table: &AreaTable) -> AreaBreakdown {
    let sram = tile.sram_cells() as f64 * table.sram_cell_mm2;
    let grng = tile.grng_cells() as f64 * table.grng_cell_mm2;
    let adc = tile.adc_count() as f64 * table.adc_mm2;
    let idac = tile.rows as f64 * table.idac_mm2;
    let reduction = table.reduction_mm2;
    let tile_mm2 = sram + grng + adc + idac + reduction;
    AreaBreakdown {
        items: vec![
            ("SRAM", sram),
            ("GRNG", grng),
            ("SAR ADC", adc),
            ("IDAC", idac),
            ("Reduction", reduction),
        ],
        tile_mm2,
        chip_mm2: tile_mm2 + table.chip_overhead_mm2,
    }
}

/// Derived headline metrics for Tab. II.
#[derive(Clone, Debug)]
pub struct HeadlineMetrics {
    pub rng_tput_gsa_s: f64,
    pub rng_eff_pj_per_sa: f64,
    pub rng_tput_norm_gsa_s_mm2: f64,
    pub nn_tput_gops: f64,
    pub nn_eff_fj_per_op: f64,
    pub nn_tput_norm_gops_mm2: f64,
    pub area_mm2: f64,
}

impl HeadlineMetrics {
    /// Compute from a chip config + measured per-sample energy and per-MVM
    /// energy (from the simulator's ledger).
    pub fn compute(
        chip: &ChipConfig,
        grng_sa_per_s: f64,
        grng_j_per_sa: f64,
        mvm_j: f64,
    ) -> Self {
        let tile = &chip.tile;
        let area = area_breakdown(tile, &chip.area);
        let ops = tile.ops_per_mvm() as f64;
        let nn_tput = ops * tile.clock_hz;
        HeadlineMetrics {
            rng_tput_gsa_s: grng_sa_per_s / 1e9,
            rng_eff_pj_per_sa: grng_j_per_sa * 1e12,
            rng_tput_norm_gsa_s_mm2: grng_sa_per_s / 1e9 / area.chip_mm2,
            nn_tput_gops: nn_tput / 1e9,
            nn_eff_fj_per_op: mvm_j / ops * 1e15,
            nn_tput_norm_gops_mm2: nn_tput / 1e9 / area.chip_mm2,
            area_mm2: area.chip_mm2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    #[test]
    fn ledger_accumulates_and_breaks_down() {
        let mut l = EnergyLedger::new();
        l.deposit(Component::Sram, 3e-12);
        l.deposit(Component::Grng, 1e-12);
        l.deposit(Component::Sram, 1e-12);
        assert!((l.total_j() - 5e-12).abs() < 1e-24);
        assert!((l.component_j(Component::Sram) - 4e-12).abs() < 1e-24);
        let bd = l.breakdown();
        let sram = bd.iter().find(|(c, _, _)| *c == Component::Sram).unwrap();
        assert!((sram.2 - 0.8).abs() < 1e-12);
    }

    #[test]
    fn ledger_absorb() {
        let mut a = EnergyLedger::new();
        a.deposit(Component::Adc, 1e-12);
        a.mvm_count = 2;
        let mut b = EnergyLedger::new();
        b.deposit(Component::Adc, 2e-12);
        b.grng_samples = 10;
        a.absorb(&b);
        assert!((a.component_j(Component::Adc) - 3e-12).abs() < 1e-24);
        assert_eq!(a.mvm_count, 2);
        assert_eq!(a.grng_samples, 10);
    }

    #[test]
    fn chip_area_matches_paper() {
        // Total die should be ≈ 0.45 mm² (Tab. II).
        let chip = ChipConfig::default();
        let bd = area_breakdown(&chip.tile, &chip.area);
        assert!(
            (bd.chip_mm2 - 0.45).abs() < 0.02,
            "chip area {:.3} mm² should be ≈0.45",
            bd.chip_mm2
        );
        // SRAM share of the tile ≈ 48 % (Fig. 12).
        let sram = bd.items.iter().find(|(n, _)| *n == "SRAM").unwrap().1;
        let share = sram / bd.tile_mm2;
        assert!(
            (0.40..=0.56).contains(&share),
            "SRAM tile share {share:.3}"
        );
    }

    #[test]
    fn headline_metrics_sane() {
        let chip = ChipConfig::default();
        let m = HeadlineMetrics::compute(&chip, 5.12e9, 360e-15, 660e-12);
        assert!((m.rng_tput_gsa_s - 5.12).abs() < 0.01);
        assert!((m.rng_eff_pj_per_sa - 0.36).abs() < 0.01);
        assert!((m.nn_tput_gops - 102.4).abs() < 1.0);
        assert!((m.nn_eff_fj_per_op - 644.5).abs() < 2.0);
        assert!(m.rng_tput_norm_gsa_s_mm2 > 10.0);
    }
}
