//! Uncertainty-estimation mathematics (§IV-B, Fig. 10–11).
//!
//! Monte-Carlo aggregation of BNN forward passes, predictive entropy,
//! expected calibration error (ECE), average predictive entropy (APE) per
//! outcome group, and accuracy-recovery-vs-deferral curves.

use crate::util::stats::entropy_nats;

/// Aggregated prediction from T Monte-Carlo forward passes.
#[derive(Clone, Debug)]
pub struct McPrediction {
    /// Mean predictive distribution (softmax averaged over samples).
    pub probs: Vec<f64>,
    /// Predictive entropy H[E\[p\]] in nats.
    pub entropy: f64,
    /// Expected entropy E[H\[p\]] (aleatoric part) in nats.
    pub expected_entropy: f64,
    /// Mutual information (epistemic part): H[E\[p\]] − E[H\[p\]].
    pub mutual_information: f64,
    /// argmax class.
    pub class: usize,
    /// Confidence = max prob.
    pub confidence: f64,
    /// Number of MC samples aggregated.
    pub t: usize,
}

/// Client-facing uncertainty decomposition plus the deferral verdict for
/// one prediction — the paper's Fig. 1 defer-to-human loop made
/// first-class on [`crate::coordinator::InferResponse`].
///
/// Identity: `epistemic == (entropy − aleatoric).max(0)` — predictive
/// entropy splits into expected entropy (aleatoric: irreducible data
/// noise) plus mutual information (epistemic: model disagreement across
/// MC samples), clamped at zero against MC estimation noise.
#[derive(Clone, Debug, PartialEq)]
pub struct UncertaintyReport {
    /// Predictive entropy H\[E\[p\]\] in nats.
    pub entropy: f64,
    /// Aleatoric part: expected entropy E\[H\[p\]\] in nats.
    pub aleatoric: f64,
    /// Epistemic part: mutual information H\[E\[p\]\] − E\[H\[p\]\] (≥ 0).
    pub epistemic: f64,
    /// The threshold \[nats\] this prediction was judged against —
    /// `model.defer_threshold`, or the per-request override.
    pub threshold: f64,
    /// The deferral policy's verdict: `entropy > threshold` (strict, so
    /// a threshold of exactly the observed entropy keeps the sample).
    pub deferred: bool,
}

impl UncertaintyReport {
    /// Judge `pred` against `threshold`. This is *the* deferral policy:
    /// the serving loop calls it per request, so clients see not just
    /// whether a prediction was deferred but which uncertainty component
    /// drove it and what bar it was measured against.
    pub fn from_prediction(pred: &McPrediction, threshold: f64) -> Self {
        Self {
            entropy: pred.entropy,
            aleatoric: pred.expected_entropy,
            epistemic: pred.mutual_information,
            threshold,
            deferred: pred.entropy > threshold,
        }
    }
}

/// Aggregate per-sample softmax outputs (T × classes).
pub fn aggregate_mc(sample_probs: &[Vec<f64>]) -> McPrediction {
    assert!(!sample_probs.is_empty());
    let t = sample_probs.len();
    let k = sample_probs[0].len();
    let mut mean = vec![0.0f64; k];
    let mut exp_h = 0.0;
    for p in sample_probs {
        assert_eq!(p.len(), k, "inconsistent class count");
        for (m, &pi) in mean.iter_mut().zip(p.iter()) {
            *m += pi;
        }
        exp_h += entropy_nats(p);
    }
    for m in mean.iter_mut() {
        *m /= t as f64;
    }
    exp_h /= t as f64;
    let entropy = entropy_nats(&mean);
    let (class, &confidence) = mean
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    McPrediction {
        probs: mean,
        entropy,
        expected_entropy: exp_h,
        mutual_information: (entropy - exp_h).max(0.0),
        class,
        confidence,
        t,
    }
}

/// Numerically-stable softmax.
pub fn softmax(logits: &[f64]) -> Vec<f64> {
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - max).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

/// One evaluated test point: prediction + ground truth + OOD marker.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub pred: McPrediction,
    pub label: usize,
    pub ood: bool,
}

/// Expected calibration error over a set of in-distribution predictions,
/// with `bins` equal-width confidence bins (standard 15-bin ECE of [31]).
/// Returned in *percent* (the paper quotes ECE 4.88 → 3.31).
pub fn ece_percent(points: &[EvalPoint], bins: usize) -> f64 {
    assert!(bins > 0);
    let id_points: Vec<&EvalPoint> = points.iter().filter(|p| !p.ood).collect();
    if id_points.is_empty() {
        return f64::NAN;
    }
    let mut bin_conf = vec![0.0f64; bins];
    let mut bin_acc = vec![0.0f64; bins];
    let mut bin_n = vec![0usize; bins];
    for p in &id_points {
        let b = ((p.pred.confidence * bins as f64) as usize).min(bins - 1);
        bin_conf[b] += p.pred.confidence;
        bin_acc[b] += if p.pred.class == p.label { 1.0 } else { 0.0 };
        bin_n[b] += 1;
    }
    let n = id_points.len() as f64;
    let mut ece = 0.0;
    for b in 0..bins {
        if bin_n[b] > 0 {
            let conf = bin_conf[b] / bin_n[b] as f64;
            let acc = bin_acc[b] / bin_n[b] as f64;
            ece += (bin_n[b] as f64 / n) * (conf - acc).abs();
        }
    }
    ece * 100.0
}

/// Reliability curve: (mean confidence, accuracy, count) per bin — the
/// calibration plot of Fig. 10-right.
pub fn reliability_curve(points: &[EvalPoint], bins: usize) -> Vec<(f64, f64, usize)> {
    let mut out = Vec::with_capacity(bins);
    let id_points: Vec<&EvalPoint> = points.iter().filter(|p| !p.ood).collect();
    for b in 0..bins {
        let lo = b as f64 / bins as f64;
        let hi = (b + 1) as f64 / bins as f64;
        let in_bin: Vec<&&EvalPoint> = id_points
            .iter()
            .filter(|p| p.pred.confidence >= lo && (p.pred.confidence < hi || b == bins - 1))
            .collect();
        if in_bin.is_empty() {
            out.push((f64::NAN, f64::NAN, 0));
        } else {
            let conf = in_bin.iter().map(|p| p.pred.confidence).sum::<f64>() / in_bin.len() as f64;
            let acc = in_bin.iter().filter(|p| p.pred.class == p.label).count() as f64
                / in_bin.len() as f64;
            out.push((conf, acc, in_bin.len()));
        }
    }
    out
}

/// Average predictive entropy by outcome group (Fig. 10-left):
/// (correct, incorrect, OOD).
pub fn ape_by_group(points: &[EvalPoint]) -> (f64, f64, f64) {
    let mean_of = |it: Vec<f64>| {
        if it.is_empty() {
            f64::NAN
        } else {
            it.iter().sum::<f64>() / it.len() as f64
        }
    };
    let correct = mean_of(
        points
            .iter()
            .filter(|p| !p.ood && p.pred.class == p.label)
            .map(|p| p.pred.entropy)
            .collect(),
    );
    let incorrect = mean_of(
        points
            .iter()
            .filter(|p| !p.ood && p.pred.class != p.label)
            .map(|p| p.pred.entropy)
            .collect(),
    );
    let ood = mean_of(
        points
            .iter()
            .filter(|p| p.ood)
            .map(|p| p.pred.entropy)
            .collect(),
    );
    (correct, incorrect, ood)
}

/// Accuracy after deferring predictions with entropy > threshold
/// (Fig. 11-right). Returns (accuracy_on_kept, fraction_kept).
pub fn deferred_accuracy(points: &[EvalPoint], threshold: f64) -> (f64, f64) {
    let id_points: Vec<&EvalPoint> = points.iter().filter(|p| !p.ood).collect();
    if id_points.is_empty() {
        return (f64::NAN, 0.0);
    }
    let kept: Vec<&&EvalPoint> = id_points
        .iter()
        .filter(|p| p.pred.entropy <= threshold)
        .collect();
    if kept.is_empty() {
        return (f64::NAN, 0.0);
    }
    let acc =
        kept.iter().filter(|p| p.pred.class == p.label).count() as f64 / kept.len() as f64;
    (acc, kept.len() as f64 / id_points.len() as f64)
}

/// Sweep deferral thresholds; returns (threshold, accuracy, kept_frac).
pub fn accuracy_recovery_curve(
    points: &[EvalPoint],
    thresholds: &[f64],
) -> Vec<(f64, f64, f64)> {
    thresholds
        .iter()
        .map(|&t| {
            let (acc, kept) = deferred_accuracy(points, t);
            (t, acc, kept)
        })
        .collect()
}

/// Plain accuracy over in-distribution points.
pub fn accuracy(points: &[EvalPoint]) -> f64 {
    let id: Vec<&EvalPoint> = points.iter().filter(|p| !p.ood).collect();
    if id.is_empty() {
        return f64::NAN;
    }
    id.iter().filter(|p| p.pred.class == p.label).count() as f64 / id.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(probs: Vec<f64>, label: usize, ood: bool) -> EvalPoint {
        EvalPoint {
            pred: aggregate_mc(&[probs]),
            label,
            ood,
        }
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // Stability at large logits.
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mc_aggregation_decomposition() {
        // Two confident-but-disagreeing samples: high MI (epistemic).
        let disagree = aggregate_mc(&[vec![0.99, 0.01], vec![0.01, 0.99]]);
        // Two agreeing-but-unsure samples: high aleatoric, low MI.
        let unsure = aggregate_mc(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        assert!(disagree.mutual_information > 0.5);
        assert!(unsure.mutual_information < 1e-9);
        assert!((disagree.entropy - unsure.entropy).abs() < 1e-9); // same mean
        assert_eq!(disagree.t, 2);
    }

    #[test]
    fn uncertainty_report_decomposition_identity() {
        let pred = aggregate_mc(&[vec![0.9, 0.1], vec![0.6, 0.4]]);
        let rep = UncertaintyReport::from_prediction(&pred, 0.2);
        assert_eq!(rep.entropy, pred.entropy);
        assert_eq!(rep.aleatoric, pred.expected_entropy);
        assert_eq!(rep.epistemic, (rep.entropy - rep.aleatoric).max(0.0));
        assert_eq!(rep.threshold, 0.2);
        // Agreeing-but-unsure samples: MI clamps to exactly 0, never
        // negative under MC estimation noise.
        let unsure = aggregate_mc(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        let rep = UncertaintyReport::from_prediction(&unsure, 0.1);
        assert_eq!(rep.epistemic, 0.0);
        assert!(rep.deferred, "ln 2 entropy must exceed a 0.1 bar");
    }

    #[test]
    fn uncertainty_report_threshold_boundary_is_strict() {
        let pred = aggregate_mc(&[vec![0.8, 0.2], vec![0.7, 0.3]]);
        assert!(pred.entropy > 0.0);
        // Exactly at the bar: kept (policy is entropy > threshold).
        let at = UncertaintyReport::from_prediction(&pred, pred.entropy);
        assert!(!at.deferred);
        // Strictly below: deferred.
        let below = UncertaintyReport::from_prediction(&pred, pred.entropy * 0.999_999);
        assert!(below.deferred);
        // Far above: kept.
        let above = UncertaintyReport::from_prediction(&pred, 10.0);
        assert!(!above.deferred);
    }

    #[test]
    fn ece_perfect_and_overconfident() {
        // Perfectly calibrated: confidence 0.8, accuracy 0.8.
        let mut pts = Vec::new();
        for i in 0..100 {
            pts.push(point(vec![0.8, 0.2], if i < 80 { 0 } else { 1 }, false));
        }
        let e = ece_percent(&pts, 10);
        assert!(e < 1.0, "calibrated ECE {e}");
        // Overconfident: confidence 0.99, accuracy 0.5.
        let mut pts = Vec::new();
        for i in 0..100 {
            pts.push(point(vec![0.99, 0.01], i % 2, false));
        }
        let e = ece_percent(&pts, 10);
        assert!(e > 40.0, "overconfident ECE {e}");
    }

    #[test]
    fn ape_groups_ordering() {
        let pts = vec![
            point(vec![0.95, 0.05], 0, false), // correct, low entropy
            point(vec![0.6, 0.4], 1, false),   // incorrect, high entropy
            point(vec![0.5, 0.5], 0, true),    // OOD, max entropy
        ];
        let (c, i, o) = ape_by_group(&pts);
        assert!(c < i && i < o, "entropy ordering c={c} i={i} o={o}");
    }

    #[test]
    fn deferral_improves_accuracy() {
        let mut pts = Vec::new();
        // 80 confident correct, 20 unsure incorrect.
        for _ in 0..80 {
            pts.push(point(vec![0.97, 0.03], 0, false));
        }
        for _ in 0..20 {
            pts.push(point(vec![0.55, 0.45], 1, false));
        }
        let base = accuracy(&pts);
        let (acc, kept) = deferred_accuracy(&pts, 0.3);
        assert!((base - 0.8).abs() < 1e-9);
        assert!(acc > 0.99, "after deferral acc {acc}");
        assert!((kept - 0.8).abs() < 1e-9);
        // Curve is monotone in kept fraction.
        let curve = accuracy_recovery_curve(&pts, &[0.1, 0.3, 0.7]);
        assert!(curve[0].2 <= curve[2].2);
    }

    #[test]
    fn reliability_curve_bins() {
        let pts = vec![
            point(vec![0.95, 0.05], 0, false),
            point(vec![0.55, 0.45], 0, false),
        ];
        let curve = reliability_curve(&pts, 10);
        assert_eq!(curve.len(), 10);
        assert_eq!(curve[9].2, 1); // 0.95 bin
        assert_eq!(curve[5].2, 1); // 0.55 bin
        assert_eq!(curve[0].2, 0);
    }
}
