//! bnn-cim CLI — leader entrypoint.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md §3) plus
//! operational commands (`serve`, `infer`, `calibrate`).

use bnn_cim::cim::{calibrate, CimTile};
use bnn_cim::client::{Backend, Config, Coordinator, Infer};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::experiments::{self, fig10_11::Arm};
use bnn_cim::nn::Model;
use bnn_cim::util::cli::{parse_args, render_cmd_help, render_help, Command, OptSpec};
use std::path::Path;
use std::time::Duration;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        print!("{}", render_help("bnn-cim", ABOUT, &commands()));
        return;
    }
    let cmd = args.remove(0);
    let parsed = parse_args(args);
    if parsed.has_flag("help") {
        if let Some(c) = commands().into_iter().find(|c| c.name == cmd) {
            print!("{}", render_cmd_help("bnn-cim", &c));
            return;
        }
    }
    let result = match cmd.as_str() {
        "grng-char" => cmd_grng_char(&parsed),
        "sweep-bias" => cmd_sweep_bias(&parsed),
        "sweep-temp" => cmd_sweep_temp(&parsed),
        "breakdown" => cmd_breakdown(&parsed),
        "compare" => cmd_compare(&parsed),
        "calibrate" => cmd_calibrate(&parsed),
        "uncertainty" => cmd_uncertainty(&parsed),
        "infer" => cmd_infer(&parsed),
        "serve" => cmd_serve(&parsed),
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{}", render_help("bnn-cim", ABOUT, &commands()));
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

const ABOUT: &str =
    "65 nm BNN accelerator with in-word GRNG — behavioral reproduction (CS.AR 2025)";

fn commands() -> Vec<Command> {
    vec![
        Command {
            name: "grng-char",
            about: "Fig. 8: GRNG pulse/latency distributions + Q-Q r",
            opts: vec![
                opt("samples", "conversions to draw", Some("2500")),
                opt("bias-mv", "gate bias V_R [mV]", Some("180")),
                opt("temp", "temperature [°C]", Some("28")),
                flag("fast", "closed-form sampling instead of circuit ODE"),
            ],
        },
        Command {
            name: "sweep-bias",
            about: "Fig. 9: latency/σ/energy vs bias voltage",
            opts: vec![
                opt("mc", "circuit-ODE samples per point (0 = model only)", Some("300")),
            ],
        },
        Command {
            name: "sweep-temp",
            about: "Tab. I: GRNG temperature stability",
            opts: vec![
                opt("samples", "samples per temperature", Some("2500")),
                opt("temps", "comma-separated °C list", Some("28,40,50,60")),
            ],
        },
        Command {
            name: "breakdown",
            about: "Fig. 12: tile energy & area breakdown",
            opts: vec![],
        },
        Command {
            name: "compare",
            about: "Tab. II: comparison table incl. baseline RNG benches",
            opts: vec![opt("sw-bench", "samples per software microbench", Some("2000000"))],
        },
        Command {
            name: "calibrate",
            about: "run the Eq. 8-10 calibration and report residuals",
            opts: vec![
                opt("adc-n", "conversions per ADC offset estimate", Some("16")),
                opt("grng-n", "conversions per GRNG offset estimate", Some("64")),
            ],
        },
        Command {
            name: "uncertainty",
            about: "Fig. 10/11: entropy, ECE, σ-precision & deferral sweeps",
            opts: vec![
                opt("n", "in-distribution eval samples", Some("200")),
                opt("mc", "MC samples per inference", Some("16")),
                flag("sigma-sweep", "also run the Fig. 11 σ-bit sweep"),
            ],
        },
        Command {
            name: "infer",
            about: "classify one synthetic sample via the serving coordinator",
            opts: vec![
                opt("index", "dataset index to classify", Some("0")),
                opt("mc", "MC samples", Some("32")),
                opt(
                    "defer-threshold",
                    "per-request deferral threshold [nats] (default: model.defer_threshold)",
                    None,
                ),
                opt(
                    "backend",
                    "engine backend: sim | cim | pjrt (default: config server.backend)",
                    None,
                ),
            ],
        },
        Command {
            name: "serve",
            about: "run the coordinator under synthetic load, report metrics",
            opts: vec![
                opt("duration", "seconds of load", Some("10")),
                opt("rate", "offered requests/second", Some("50")),
                opt("mc", "MC samples per request", Some("8")),
                opt("workers", "shard workers (each owns an engine + GRNG bank)", Some("1")),
                opt(
                    "mc-workers",
                    "MC-parallel replicas per cim engine (split ε streams)",
                    Some("4"),
                ),
                opt(
                    "backend",
                    "engine backend: sim | cim | pjrt (cim = chip model, in-word ε + energy)",
                    Some("pjrt"),
                ),
                flag("sim", "deprecated alias for --backend sim"),
                opt(
                    "listen",
                    "serve the /v1 HTTP API on host:port (port 0 = ephemeral) instead of \
                     synthetic load; --duration bounds the run, omit it to run until killed",
                    None,
                ),
                opt(
                    "fault-plan",
                    "deterministic fault-injection spec 'k=v,...' (e.g. \
                     'seed=7,panic_at_run=40,stall_ms=0.5'); overrides [faults] and \
                     BNN_CIM_FAULT_PLAN — chaos drills, DESIGN.md §9",
                    None,
                ),
            ],
        },
    ]
}

fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec {
        name,
        help,
        default,
        is_flag: false,
    }
}

fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: true,
    }
}

type CmdResult = Result<(), Box<dyn std::error::Error>>;

fn load_config(args: &bnn_cim::util::cli::Args) -> Result<Config, Box<dyn std::error::Error>> {
    match args.get("config") {
        Some(path) => Ok(Config::from_toml_file(Path::new(path))?),
        None => Ok(Config::default()),
    }
}

fn cmd_grng_char(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let mut cfg = load_config(args)?;
    cfg.chip.grng.bias_v = args.get_f64("bias-mv", 180.0)? / 1e3;
    cfg.chip.grng.temp_c = args.get_f64("temp", 28.0)?;
    let n = args.get_usize("samples", 2500)?;
    let rep = experiments::run_characterization(&cfg.chip.grng, n, 42, !args.has_flag("fast"));
    println!("{}", rep.render());
    Ok(())
}

fn cmd_sweep_bias(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let cfg = load_config(args)?;
    let mc = args.get_usize("mc", 300)?;
    let pts = experiments::run_bias_sweep(
        &cfg.chip.grng,
        &experiments::fig9::default_biases(),
        mc,
        7,
    );
    println!("{}", experiments::fig9::render(&pts));
    Ok(())
}

fn cmd_sweep_temp(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let cfg = load_config(args)?;
    let temps = args.get_f64_list("temps", &[28.0, 40.0, 50.0, 60.0])?;
    let n = args.get_usize("samples", 2500)?;
    let pts = experiments::run_temp_sweep(&cfg.chip.grng, &temps, n, 11);
    println!("{}", experiments::tab1::render(&pts));
    Ok(())
}

fn cmd_breakdown(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let cfg = load_config(args)?;
    let rep = experiments::run_breakdown(&cfg.chip, 3);
    println!("{}", rep.render());
    Ok(())
}

fn cmd_compare(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let cfg = load_config(args)?;
    let sw_n = args.get_usize("sw-bench", 2_000_000)?;
    let (rows, m) = experiments::comparison_table(&cfg.chip, sw_n);
    println!("{}", experiments::tab2::render(&rows, &m));
    Ok(())
}

fn cmd_calibrate(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let cfg = load_config(args)?;
    let mut tile = CimTile::new(&cfg.chip);
    let raw_rms = {
        let offs = tile.bank.true_offsets();
        (offs.iter().map(|x| x * x).sum::<f64>() / offs.len() as f64).sqrt()
    };
    let rep = calibrate(
        &mut tile,
        args.get_usize("adc-n", 16)?,
        args.get_usize("grng-n", 64)?,
    )?;
    println!(
        "calibration (Eq. 8-10):\n  raw ε₀ RMS          {raw_rms:.3}\n  \
         estimated ε₀ RMS    {:.3}\n  residual RMS        {:.3}\n  \
         ADC offset RMS      {:.3} LSB\n  energy              {:.2} nJ (paper: 3.6 nJ)",
        rep.grng_offset_rms,
        rep.grng_residual_rms,
        rep.adc_offset_rms_lsb,
        rep.energy_j * 1e9
    );
    Ok(())
}

fn cmd_uncertainty(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let cfg = load_config(args)?;
    let weights = Path::new(&cfg.model.artifacts_dir).join("weights.json");
    if !weights.exists() {
        return Err("artifacts/weights.json missing — run `make artifacts`".into());
    }
    let n = args.get_usize("n", 200)?;
    let mc = args.get_usize("mc", 16)?;
    println!("Fig. 10 — uncertainty arms ({n} ID + {} OOD, T={mc}):", n * 2 / 5);
    for arm in [Arm::DetNn, Arm::BnnFloat, Arm::BnnHw] {
        let mut model = Model::load(&weights)?;
        let t = if arm == Arm::DetNn { 1 } else { mc };
        let rep =
            experiments::run_uncertainty(&mut model, &cfg.chip, arm, n, n * 2 / 5, t, 5);
        println!("  {}", rep.render());
    }
    if args.has_flag("sigma-sweep") {
        println!("\nFig. 11-left — σ precision sweep (hardware arm):");
        for (bits, rep) in
            experiments::sigma_bit_sweep(&weights, &cfg.chip, &[2, 3, 4], n / 2, mc / 2, 9)
        {
            println!("  σ = {bits} bits: {}", rep.render());
        }
    }
    Ok(())
}

fn cmd_infer(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let mut cfg = load_config(args)?;
    let index = args.get_u64("index", 0)?;
    let mc = args.get_usize("mc", 32)?;
    if let Some(b) = args.get("backend") {
        cfg.server.backend = Backend::parse(b)?;
    }
    let gen = SyntheticPerson::new(cfg.model.image_side, 123);
    let sample = gen.sample(index);
    let coord = Coordinator::builder(cfg).start()?;
    let mut req = Infer::new(sample.pixels).mc_samples(mc);
    if let Some(h) = args.get("defer-threshold") {
        req = req.defer_threshold(h.parse::<f64>().map_err(|e| format!("defer-threshold: {e}"))?);
    }
    let resp = coord.infer(req)?;
    let u = &resp.uncertainty;
    println!(
        "sample {index}: true={} pred={} probs={:?}\n\
         entropy={:.3} nats = aleatoric {:.3} + epistemic {:.3} | \
         threshold={:.3} → deferred={}\n\
         latency={:.2} ms",
        sample.label,
        resp.pred.class,
        resp.pred
            .probs
            .iter()
            .map(|p| (p * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>(),
        u.entropy,
        u.aleatoric,
        u.epistemic,
        u.threshold,
        u.deferred,
        resp.latency.as_secs_f64() * 1e3
    );
    coord.shutdown();
    Ok(())
}

fn cmd_serve(args: &bnn_cim::util::cli::Args) -> CmdResult {
    let mut cfg = load_config(args)?;
    let duration = Duration::from_secs_f64(args.get_f64("duration", 10.0)?);
    let rate = args.get_f64("rate", 50.0)?;
    cfg.model.mc_samples = args.get_usize("mc", 8)?;
    cfg.server.workers = args.get_usize("workers", cfg.server.workers)?;
    cfg.server.mc_workers = args.get_usize("mc-workers", cfg.server.mc_workers)?;
    if let Some(b) = args.get("backend") {
        cfg.server.backend = Backend::parse(b)?;
    } else if args.has_flag("sim") {
        eprintln!("warning: --sim is deprecated; use --backend sim");
        cfg.server.backend = Backend::Sim;
    }
    // CLI beats env beats config: hand the spec to the builder as an
    // explicit override rather than via cfg.faults.
    let fault_plan = match args.get("fault-plan") {
        Some(spec) => Some(bnn_cim::client::FaultPlan::parse_spec(spec)?),
        None => None,
    };
    // --listen (or [server] listen in the config) switches from the
    // synthetic-load loop to the network edge.
    let listen = args
        .get("listen")
        .map(str::to_string)
        .unwrap_or_else(|| cfg.server.listen.clone());
    if !listen.is_empty() {
        // No explicit --duration means run until killed.
        let bound = args.get("duration").map(|_| duration);
        return serve_listen(cfg, &listen, bound, fault_plan);
    }
    let mut builder = Coordinator::builder(cfg.clone());
    if let Some(plan) = fault_plan {
        builder = builder.fault_plan(plan);
    }
    let coord = builder.start()?;
    println!(
        "serving on {} shard worker(s), backend = {}",
        cfg.server.workers,
        cfg.server.backend.name()
    );
    let gen = SyntheticPerson::new(cfg.model.image_side, 321);
    let period = Duration::from_secs_f64(1.0 / rate.max(0.1));
    let t0 = bnn_cim::util::clock::now();
    let mut tickets = Vec::new();
    let mut sent = 0u64;
    while t0.elapsed() < duration {
        let s = gen.sample(sent);
        match coord.submit(Infer::new(s.pixels)) {
            Ok(ticket) => tickets.push(ticket),
            Err(_) => { /* backpressure: counted in metrics */ }
        }
        sent += 1;
        std::thread::sleep(period);
    }
    let mut ok = 0;
    for ticket in tickets {
        if ticket.wait_timeout(Duration::from_secs(30)).is_ok() {
            ok += 1;
        }
    }
    println!(
        "offered {sent} requests over {:.1} s ({rate}/s), {ok} completed\n{}",
        t0.elapsed().as_secs_f64(),
        coord.metrics().render()
    );
    coord.shutdown();
    Ok(())
}

/// `serve --listen`: boot the coordinator plus the network edge and hold
/// until the duration elapses (`None` = until killed), printing a metrics
/// render every ~10 s.
fn serve_listen(
    cfg: Config,
    listen: &str,
    duration: Option<Duration>,
    fault_plan: Option<bnn_cim::client::FaultPlan>,
) -> CmdResult {
    use bnn_cim::client::EdgeServer;
    use std::sync::Arc;

    let mut builder = Coordinator::builder(cfg.clone());
    if let Some(plan) = fault_plan {
        builder = builder.fault_plan(plan);
    }
    let coord = Arc::new(builder.start()?);
    let edge = EdgeServer::bind(listen, Arc::clone(&coord))?;
    println!(
        "edge listening on http://{} — {} shard worker(s), backend = {}, \
         degrade/shed at {:.0}%/{:.0}% queue load",
        edge.local_addr(),
        cfg.server.workers,
        cfg.server.backend.name(),
        cfg.server.edge_degrade_load * 100.0,
        cfg.server.edge_shed_load * 100.0,
    );
    let t0 = bnn_cim::util::clock::now();
    let mut ticks = 0u64;
    loop {
        std::thread::sleep(Duration::from_secs(1));
        ticks += 1;
        if let Some(d) = duration {
            if t0.elapsed() >= d {
                break;
            }
        }
        if ticks % 10 == 0 {
            println!("{}", coord.metrics().render());
        }
    }
    println!("{}", coord.metrics().render());
    edge.shutdown();
    Ok(())
}
