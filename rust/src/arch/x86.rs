//! AVX2 kernels (x86-64, runtime-detected). See the module docs in
//! `arch/mod.rs` for the determinism contract; every function here is
//! bit-identical to its scalar oracle.
//!
//! # Safety
//!
//! Every function carries `#[target_feature(enable = "avx2")]` and must
//! only be called after `is_x86_feature_detected!("avx2")` succeeded —
//! the safe wrappers in `arch/mod.rs` enforce that via `clamp_supported`.
//!
//! FMA is deliberately **not** used even where the host has it: the
//! scalar spec rounds the multiply and the add separately, and a fused
//! multiply-add rounds once, which would break bit-identity. The
//! `_mm256_mul_pd`/`_mm256_add_pd` pairs below lower to plain vector
//! `fmul`/`fadd` (rustc does not enable floating-point contraction), so
//! the compiler cannot re-fuse them.

use core::arch::x86_64::*;

use super::lane_combine;
use crate::util::rng::xoshiro_lane_step;

/// Vector [`super::lane_dot`]: two 4×f64 accumulators hold the eight
/// interleaved lanes (acc0 = lanes 0–3, acc1 = lanes 4–7); each 8-row
/// chunk contributes one mul+add per accumulator, in the same ascending
/// row order as the scalar walk. The remainder (rows mod 8) is scalar
/// into lanes 0..rem, then the fixed pairwise [`lane_combine`].
///
/// # Safety
/// Caller must have verified AVX2 support (`clamp_supported` in
/// `arch/mod.rs`); `a` and `b` must be equal-length slices.
#[target_feature(enable = "avx2")]
pub unsafe fn lane_dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    for k in 0..chunks {
        let i = k * 8;
        let prod0 = _mm256_mul_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)));
        acc0 = _mm256_add_pd(acc0, prod0);
        let prod1 = _mm256_mul_pd(_mm256_loadu_pd(pa.add(i + 4)), _mm256_loadu_pd(pb.add(i + 4)));
        acc1 = _mm256_add_pd(acc1, prod1);
    }
    let mut s = [0.0f64; 8];
    _mm256_storeu_pd(s.as_mut_ptr(), acc0);
    _mm256_storeu_pd(s.as_mut_ptr().add(4), acc1);
    for (l, i) in (chunks * 8..n).enumerate() {
        s[l] += *pa.add(i) * *pb.add(i);
    }
    lane_combine(&s)
}

/// Vector [`super::mul_into`]: elementwise product, 4 lanes at a time.
///
/// # Safety
/// Caller must have verified AVX2 support; `dst`, `a`, and `b` must be
/// equal-length slices.
#[target_feature(enable = "avx2")]
pub unsafe fn mul_into_avx2(dst: &mut [f64], a: &[f64], b: &[f64]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    let n = dst.len();
    let pd = dst.as_mut_ptr();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let v = _mm256_mul_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)));
        _mm256_storeu_pd(pd.add(i), v);
        i += 4;
    }
    while i < n {
        *pd.add(i) = *pa.add(i) * *pb.add(i);
        i += 1;
    }
}

/// Vector [`super::div_assign`]: elementwise quotient, 4 lanes at a time.
///
/// # Safety
/// Caller must have verified AVX2 support; `dst` and `by` must be
/// equal-length slices.
#[target_feature(enable = "avx2")]
pub unsafe fn div_assign_avx2(dst: &mut [f64], by: &[f64]) {
    debug_assert_eq!(dst.len(), by.len());
    let n = dst.len();
    let pd = dst.as_mut_ptr();
    let pb = by.as_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let v = _mm256_div_pd(_mm256_loadu_pd(pd.add(i)), _mm256_loadu_pd(pb.add(i)));
        _mm256_storeu_pd(pd.add(i), v);
        i += 4;
    }
    while i < n {
        *pd.add(i) /= *pb.add(i);
        i += 1;
    }
}

/// Vector [`super::xoshiro_block`]: one xoshiro256++ step on four lanes
/// at a time, integer-exact; remainder lanes step scalar. AVX2 has no
/// 64-bit lane rotate (vprolq is AVX-512), so rotl(v, k) is composed as
/// `(v << k) | (v >> (64 - k))`.
///
/// # Safety
/// Caller must have verified AVX2 support; all five slices must share
/// one length (unaligned loads/stores are used, so no alignment duty).
#[target_feature(enable = "avx2")]
pub unsafe fn xoshiro_block_avx2(
    s0: &mut [u64],
    s1: &mut [u64],
    s2: &mut [u64],
    s3: &mut [u64],
    out: &mut [u64],
) {
    let n = out.len();
    debug_assert!(s0.len() == n && s1.len() == n && s2.len() == n && s3.len() == n);
    let chunks = n / 4;
    for k in 0..chunks {
        let i = k * 4;
        let p0 = s0.as_mut_ptr().add(i) as *mut __m256i;
        let p1 = s1.as_mut_ptr().add(i) as *mut __m256i;
        let p2 = s2.as_mut_ptr().add(i) as *mut __m256i;
        let p3 = s3.as_mut_ptr().add(i) as *mut __m256i;
        let v0 = _mm256_loadu_si256(p0 as *const __m256i);
        let v1 = _mm256_loadu_si256(p1 as *const __m256i);
        let v2 = _mm256_loadu_si256(p2 as *const __m256i);
        let v3 = _mm256_loadu_si256(p3 as *const __m256i);
        // result = rotl(s0 + s3, 23) + s0   (wrapping adds)
        let sum = _mm256_add_epi64(v0, v3);
        let rot = _mm256_or_si256(_mm256_slli_epi64::<23>(sum), _mm256_srli_epi64::<41>(sum));
        let result = _mm256_add_epi64(rot, v0);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, result);
        // t = s1 << 17; s2 ^= s0; s3 ^= s1; s1 ^= s2; s0 ^= s3;
        // s2 ^= t; s3 = rotl(s3, 45)
        let t = _mm256_slli_epi64::<17>(v1);
        let v2 = _mm256_xor_si256(v2, v0);
        let v3 = _mm256_xor_si256(v3, v1);
        let v1 = _mm256_xor_si256(v1, v2);
        let v0 = _mm256_xor_si256(v0, v3);
        let v2 = _mm256_xor_si256(v2, t);
        let v3 = _mm256_or_si256(_mm256_slli_epi64::<45>(v3), _mm256_srli_epi64::<19>(v3));
        _mm256_storeu_si256(p0, v0);
        _mm256_storeu_si256(p1, v1);
        _mm256_storeu_si256(p2, v2);
        _mm256_storeu_si256(p3, v3);
    }
    for i in chunks * 4..n {
        out[i] = xoshiro_lane_step(&mut s0[i], &mut s1[i], &mut s2[i], &mut s3[i]);
    }
}
