//! Runtime-dispatched SIMD kernels for the two hot paths (ISSUE 6).
//!
//! PRs 3–4 shaped the MVM and GRNG inner loops to be SIMD-mappable — the
//! fixed 8-lane interleaved reduction spec of [`lane_combine`], the SoA
//! bit-plane layout, the branch-free three-pass block fill — but every
//! loop was still scalar. This module is where the lanes finally land in
//! registers: stable `std::arch` intrinsics behind runtime feature
//! detection, with the scalar kernels always compiled and kept as the
//! oracle (no new crates; crates.io is unreachable in this build
//! environment).
//!
//! # Dispatch
//!
//! [`active_level`] picks the widest supported [`SimdLevel`] once per
//! process (AVX2 on x86-64 via `is_x86_feature_detected!`, NEON on
//! aarch64 where it is baseline, scalar everywhere else). Two overrides
//! exist, both capped at what the host actually supports (an unsupported
//! request degrades to [`SimdLevel::Scalar`], never to undefined
//! behavior):
//!
//! - `BNN_CIM_FORCE_SCALAR=1` in the environment pins the whole process
//!   to the scalar oracle — CI runs one leg this way so both dispatch
//!   arms execute in every pipeline.
//! - [`force_level`] switches the dispatch at runtime — this is how the
//!   property tests and benches exercise scalar and vector arms in one
//!   process and A/B them on the same host.
//!
//! # Determinism contract
//!
//! Every f64 kernel here is **bit-identical** to its scalar reference on
//! every input, not merely close:
//!
//! - [`lane_dot`] maps the 8-lane spec directly onto two 4×f64 AVX2
//!   accumulators (four 2×f64 on NEON): vector lane *l* performs exactly
//!   the scalar `s[l] += a[8k+l] * b[8k+l]` chain, as separate
//!   multiply-then-add (never FMA — the scalar path rounds twice), and
//!   the final [`lane_combine`] is the same pairwise tree.
//! - [`mul_into`] and [`div_assign`] are elementwise; IEEE 754 `*` and
//!   `/` are correctly rounded, so vectorizing them cannot change bits.
//! - [`xoshiro_block`] advances independent xoshiro256++ lanes with
//!   integer ops only — trivially exact.
//!
//! Because the kernels are bit-exact, the MVM fast path and the GRNG
//! block fill stay pinned to their legacy oracles by the existing
//! property tests *regardless of which arm dispatch picks* (see DESIGN.md
//! §5d for the cross-ISA contract).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

/// A dispatchable kernel implementation level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels — always available, the oracle.
    Scalar,
    /// AVX2 4×f64 / 4×u64 kernels (x86-64, runtime-detected).
    Avx2,
    /// NEON 2×f64 / 2×u64 kernels (aarch64 baseline).
    Neon,
}

impl SimdLevel {
    /// Stable lowercase name, used in bench JSON and logs.
    pub fn as_str(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The widest level this host supports (cached after first probe).
#[allow(unreachable_code)]
pub fn detected_level() -> SimdLevel {
    static DETECTED: OnceLock<SimdLevel> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return SimdLevel::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON is part of the aarch64 baseline: no detection needed.
            return SimdLevel::Neon;
        }
        SimdLevel::Scalar
    })
}

/// `BNN_CIM_FORCE_SCALAR` (non-empty, not "0") pins the process scalar.
fn env_forced_scalar() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| {
        matches!(std::env::var("BNN_CIM_FORCE_SCALAR"), Ok(s) if !s.is_empty() && s != "0")
    })
}

/// Programmatic dispatch override: 0 = none, else 1 + discriminant.
static FORCED: AtomicU8 = AtomicU8::new(0);

fn encode(level: Option<SimdLevel>) -> u8 {
    match level {
        None => 0,
        Some(SimdLevel::Scalar) => 1,
        Some(SimdLevel::Avx2) => 2,
        Some(SimdLevel::Neon) => 3,
    }
}

fn decode(v: u8) -> Option<SimdLevel> {
    match v {
        1 => Some(SimdLevel::Scalar),
        2 => Some(SimdLevel::Avx2),
        3 => Some(SimdLevel::Neon),
        _ => None,
    }
}

/// Cap a requested level at what the host supports. Scalar is always
/// supported; an unsupported vector request degrades to scalar (running
/// e.g. AVX2 code on a non-AVX2 host would be undefined behavior, so the
/// safe wrappers route every level request through this).
fn clamp_supported(level: SimdLevel) -> SimdLevel {
    if level == SimdLevel::Scalar || level == detected_level() {
        level
    } else {
        SimdLevel::Scalar
    }
}

/// Override the dispatch level for the whole process (tests/benches: A/B
/// scalar vs vector in one run). `None` restores automatic dispatch.
/// Returns the previous override so callers can scope-restore it. The
/// override is capped at the detected level when applied, not here.
pub fn force_level(level: Option<SimdLevel>) -> Option<SimdLevel> {
    // RELAXED: the override is a standalone u8 cell — no other memory is
    // published through it, and forced scopes are serialized by the
    // FORCE_SCOPE mutex in ForcedLevelGuard, so swap order is total.
    decode(FORCED.swap(encode(level), Ordering::Relaxed))
}

/// The level the dispatched kernels will run at *right now*: the
/// programmatic override, else the `BNN_CIM_FORCE_SCALAR` environment
/// pin, else the detected hardware level.
pub fn active_level() -> SimdLevel {
    // RELAXED: reads the same standalone override cell; a stale read can
    // only pick a *supported* level (clamp below), never corrupt data.
    if let Some(l) = decode(FORCED.load(Ordering::Relaxed)) {
        return clamp_supported(l);
    }
    if env_forced_scalar() {
        return SimdLevel::Scalar;
    }
    detected_level()
}

// ---------------------------------------------------------------------------
// The 8-lane reduction spec (shared scalar pieces)
// ---------------------------------------------------------------------------

/// The tile's fixed column-charge reduction spec: eight interleaved
/// partial sums (lane = row mod 8) combined pairwise,
/// `q = ((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))`. Physically the column
/// charge is an order-independent analog sum; the spec just fixes one
/// reproducible order that every MVM implementation — scalar, AVX2,
/// NEON, and the legacy AoS walk — follows, so all stay bit-identical.
#[inline]
pub fn lane_combine(s: &[f64; 8]) -> f64 {
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
}

/// Scalar oracle for [`lane_dot`]: walk `a[r]*b[r]` into lane `r & 7` in
/// ascending row order, then [`lane_combine`].
#[inline]
pub fn lane_dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    let mut s = [0.0f64; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for l in 0..8 {
            s[l] += xa[l] * xb[l];
        }
    }
    for (l, (x, y)) in ca
        .remainder()
        .iter()
        .zip(cb.remainder().iter())
        .enumerate()
    {
        s[l] += x * y;
    }
    lane_combine(&s)
}

/// Scalar oracle for [`mul_into`].
#[inline]
pub fn mul_into_scalar(dst: &mut [f64], a: &[f64], b: &[f64]) {
    for ((d, x), y) in dst.iter_mut().zip(a.iter()).zip(b.iter()) {
        *d = x * y;
    }
}

/// Scalar oracle for [`div_assign`].
#[inline]
pub fn div_assign_scalar(dst: &mut [f64], by: &[f64]) {
    for (d, s) in dst.iter_mut().zip(by.iter()) {
        *d /= *s;
    }
}

/// Scalar oracle for [`xoshiro_block`]: one xoshiro256++ step per lane.
#[inline]
pub fn xoshiro_block_scalar(
    s0: &mut [u64],
    s1: &mut [u64],
    s2: &mut [u64],
    s3: &mut [u64],
    out: &mut [u64],
    from: usize,
) {
    for i in from..out.len() {
        out[i] = crate::util::rng::xoshiro_lane_step(
            &mut s0[i],
            &mut s1[i],
            &mut s2[i],
            &mut s3[i],
        );
    }
}

// ---------------------------------------------------------------------------
// Dispatched kernels
// ---------------------------------------------------------------------------

/// Lane-interleaved dot product over contiguous slices (the MVM fast
/// path's inner loop) at the ambient [`active_level`]. Bit-identical to
/// [`lane_dot_scalar`] on every arm.
#[inline]
pub fn lane_dot(a: &[f64], b: &[f64]) -> f64 {
    lane_dot_at(active_level(), a, b)
}

/// [`lane_dot`] at an explicit level (capped at host support — safe on
/// any machine). Lets tests and benches A/B the arms directly.
pub fn lane_dot_at(level: SimdLevel, a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "lane_dot operand lengths differ");
    match clamp_supported(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: clamp_supported only returns Avx2 when detection passed.
        SimdLevel::Avx2 => unsafe { x86::lane_dot_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::lane_dot_neon(a, b) },
        _ => lane_dot_scalar(a, b),
    }
}

/// Elementwise `dst[i] = a[i] * b[i]` (the `row_terms = drives·ε` fill in
/// `ConvertUnit::convert_words`) at the ambient level. Bit-identical on
/// every arm (IEEE multiply is correctly rounded).
#[inline]
pub fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
    mul_into_at(active_level(), dst, a, b)
}

/// [`mul_into`] at an explicit level (capped at host support).
pub fn mul_into_at(level: SimdLevel, dst: &mut [f64], a: &[f64], b: &[f64]) {
    assert_eq!(dst.len(), a.len(), "mul_into operand lengths differ");
    assert_eq!(dst.len(), b.len(), "mul_into operand lengths differ");
    match clamp_supported(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: clamp_supported only returns Avx2 when detection passed.
        SimdLevel::Avx2 => unsafe { x86::mul_into_avx2(dst, a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::mul_into_neon(dst, a, b) },
        _ => mul_into_scalar(dst, a, b),
    }
}

/// Elementwise `dst[i] /= by[i]` (the GRNG block fill's normalization
/// pass) at the ambient level. Bit-identical on every arm (IEEE divide is
/// correctly rounded).
#[inline]
pub fn div_assign(dst: &mut [f64], by: &[f64]) {
    div_assign_at(active_level(), dst, by)
}

/// [`div_assign`] at an explicit level (capped at host support).
pub fn div_assign_at(level: SimdLevel, dst: &mut [f64], by: &[f64]) {
    assert_eq!(dst.len(), by.len(), "div_assign operand lengths differ");
    match clamp_supported(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: clamp_supported only returns Avx2 when detection passed.
        SimdLevel::Avx2 => unsafe { x86::div_assign_avx2(dst, by) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::div_assign_neon(dst, by) },
        _ => div_assign_scalar(dst, by),
    }
}

/// Advance every xoshiro256++ lane by one step, writing one output word
/// per lane (the GRNG block fill's uniform draw across all cells), at the
/// ambient level. The four state slices and `out` must share one length.
/// Integer-only: bit-identical on every arm.
#[inline]
pub fn xoshiro_block(
    s0: &mut [u64],
    s1: &mut [u64],
    s2: &mut [u64],
    s3: &mut [u64],
    out: &mut [u64],
) {
    xoshiro_block_at(active_level(), s0, s1, s2, s3, out)
}

/// [`xoshiro_block`] at an explicit level (capped at host support).
pub fn xoshiro_block_at(
    level: SimdLevel,
    s0: &mut [u64],
    s1: &mut [u64],
    s2: &mut [u64],
    s3: &mut [u64],
    out: &mut [u64],
) {
    let n = out.len();
    assert!(
        s0.len() == n && s1.len() == n && s2.len() == n && s3.len() == n,
        "xoshiro_block lane lengths differ"
    );
    match clamp_supported(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: clamp_supported only returns Avx2 when detection passed.
        SimdLevel::Avx2 => unsafe { x86::xoshiro_block_avx2(s0, s1, s2, s3, out) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::xoshiro_block_neon(s0, s1, s2, s3, out) },
        _ => xoshiro_block_scalar(s0, s1, s2, s3, out, 0),
    }
}

/// Serializes forced-dispatch scopes process-wide. `FORCED` is global
/// state: two concurrent [`ForcedLevelGuard`]s (e.g. parallel test
/// threads) could interleave their save/restore pairs and leak an
/// override past both guards. Holding this lock for the guard's lifetime
/// makes forced regions strictly nested.
static FORCE_SCOPE: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Scope guard: force a dispatch level for the guard's lifetime, then
/// restore the previous override. Holds [`FORCE_SCOPE`] so concurrent
/// guards serialize instead of clobbering each other's saved state, and
/// restores on drop so a panicking property case cannot leak a forced
/// level into later tests.
pub struct ForcedLevelGuard {
    prev: Option<SimdLevel>,
    _scope: std::sync::MutexGuard<'static, ()>,
}

impl ForcedLevelGuard {
    pub fn new(level: SimdLevel) -> Self {
        // A panic while a guard is held poisons the mutex; the () payload
        // carries no invariants, so later guards just take the lock.
        let scope = FORCE_SCOPE.lock().unwrap_or_else(|e| e.into_inner());
        Self {
            prev: force_level(Some(level)),
            _scope: scope,
        }
    }
}

impl Drop for ForcedLevelGuard {
    fn drop(&mut self) {
        force_level(self.prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng64, Xoshiro256};

    fn random_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| {
                // Mix magnitudes, signs and exact zeros: bit-identity must
                // hold on awkward inputs, not just friendly ones.
                match rng.next_below(8) {
                    0 => 0.0,
                    1 => (rng.next_f64() - 0.5) * 1e-12,
                    2 => (rng.next_f64() - 0.5) * 1e12,
                    _ => (rng.next_f64() - 0.5) * 200.0,
                }
            })
            .collect()
    }

    /// Every level the host can actually run (scalar + detected vector).
    fn runnable_levels() -> Vec<SimdLevel> {
        let mut levels = vec![SimdLevel::Scalar];
        if detected_level() != SimdLevel::Scalar {
            levels.push(detected_level());
        }
        levels
    }

    #[test]
    fn lane_dot_levels_are_bit_identical_across_remainders() {
        let mut rng = Pcg64::new(0xA11CE);
        // Lengths straddling every remainder class mod 8, incl. empty.
        for n in 0..=67 {
            let a = random_vec(&mut rng, n);
            let b = random_vec(&mut rng, n);
            let want = lane_dot_scalar(&a, &b);
            for &level in &runnable_levels() {
                let got = lane_dot_at(level, &a, &b);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "lane_dot level {level} diverged at n={n}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn mul_into_and_div_assign_levels_are_bit_identical() {
        let mut rng = Pcg64::new(0xB0B);
        for n in [0, 1, 3, 4, 5, 8, 17, 64, 100] {
            let a = random_vec(&mut rng, n);
            let b: Vec<f64> = random_vec(&mut rng, n)
                .into_iter()
                .map(|x| if x == 0.0 { 1.0 } else { x })
                .collect();
            let mut want = vec![0.0; n];
            mul_into_scalar(&mut want, &a, &b);
            for &level in &runnable_levels() {
                let mut got = vec![0.0; n];
                mul_into_at(level, &mut got, &a, &b);
                let eq = got
                    .iter()
                    .zip(want.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(eq, "mul_into level {level} diverged at n={n}");
            }
            let mut want_div = a.clone();
            div_assign_scalar(&mut want_div, &b);
            for &level in &runnable_levels() {
                let mut got = a.clone();
                div_assign_at(level, &mut got, &b);
                let eq = got
                    .iter()
                    .zip(want_div.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(eq, "div_assign level {level} diverged at n={n}");
            }
        }
    }

    #[test]
    fn xoshiro_block_levels_match_sequential_generators() {
        // Reference: n independent Xoshiro256 generators stepped one at a
        // time. The block kernel must advance states and emit outputs
        // exactly the same way, at every level, for every remainder.
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 16, 33] {
            let refs: Vec<Xoshiro256> = (0..n).map(|i| Xoshiro256::new(0x5EED + i as u64)).collect();
            for &level in &runnable_levels() {
                let mut gens = refs.clone();
                let mut s0: Vec<u64> = gens.iter().map(|g| g.state()[0]).collect();
                let mut s1: Vec<u64> = gens.iter().map(|g| g.state()[1]).collect();
                let mut s2: Vec<u64> = gens.iter().map(|g| g.state()[2]).collect();
                let mut s3: Vec<u64> = gens.iter().map(|g| g.state()[3]).collect();
                let mut out = vec![0u64; n];
                for round in 0..3 {
                    xoshiro_block_at(level, &mut s0, &mut s1, &mut s2, &mut s3, &mut out);
                    for (i, g) in gens.iter_mut().enumerate() {
                        assert_eq!(
                            out[i],
                            g.next_u64(),
                            "lane {i} round {round} level {level}"
                        );
                        assert_eq!(g.state()[0], s0[i], "state0 lane {i} level {level}");
                        assert_eq!(g.state()[3], s3[i], "state3 lane {i} level {level}");
                    }
                }
            }
        }
    }

    #[test]
    fn force_level_overrides_and_restores() {
        let before = active_level();
        {
            let _guard = ForcedLevelGuard::new(SimdLevel::Scalar);
            assert_eq!(active_level(), SimdLevel::Scalar);
        }
        assert_eq!(active_level(), before, "guard must restore dispatch");
        // Forcing an unsupported vector level degrades to scalar instead
        // of dispatching into unreachable intrinsics.
        let unsupported = match detected_level() {
            SimdLevel::Avx2 => SimdLevel::Neon,
            _ => SimdLevel::Avx2,
        };
        let _guard = ForcedLevelGuard::new(unsupported);
        let a = [1.0, 2.0, 3.0];
        assert_eq!(
            lane_dot(&a, &a).to_bits(),
            lane_dot_scalar(&a, &a).to_bits()
        );
    }

    #[test]
    fn mismatched_lengths_panic() {
        let r = std::panic::catch_unwind(|| lane_dot(&[1.0], &[1.0, 2.0]));
        assert!(r.is_err(), "length mismatch must panic, not UB");
    }
}
