//! NEON kernels (aarch64, where NEON is baseline — no runtime probe).
//! See the module docs in `arch/mod.rs` for the determinism contract;
//! every function here is bit-identical to its scalar oracle. As on
//! x86, fused multiply-add is deliberately avoided: the scalar spec
//! rounds multiply and add separately.

use core::arch::aarch64::*;

use super::lane_combine;
use crate::util::rng::xoshiro_lane_step;

/// Vector [`super::lane_dot`]: four 2×f64 accumulators hold the eight
/// interleaved lanes; each 8-row chunk contributes one mul+add per
/// accumulator in the same ascending row order as the scalar walk.
///
/// # Safety
/// NEON is baseline on aarch64, but callers still route through
/// `clamp_supported` in `arch/mod.rs`; `a` and `b` must be equal-length
/// slices.
#[target_feature(enable = "neon")]
pub unsafe fn lane_dot_neon(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let zero = vdupq_n_f64(0.0);
    let mut acc0 = zero;
    let mut acc1 = zero;
    let mut acc2 = zero;
    let mut acc3 = zero;
    for k in 0..chunks {
        let i = k * 8;
        acc0 = vaddq_f64(acc0, vmulq_f64(vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i))));
        acc1 = vaddq_f64(
            acc1,
            vmulq_f64(vld1q_f64(pa.add(i + 2)), vld1q_f64(pb.add(i + 2))),
        );
        acc2 = vaddq_f64(
            acc2,
            vmulq_f64(vld1q_f64(pa.add(i + 4)), vld1q_f64(pb.add(i + 4))),
        );
        acc3 = vaddq_f64(
            acc3,
            vmulq_f64(vld1q_f64(pa.add(i + 6)), vld1q_f64(pb.add(i + 6))),
        );
    }
    let mut s = [0.0f64; 8];
    vst1q_f64(s.as_mut_ptr(), acc0);
    vst1q_f64(s.as_mut_ptr().add(2), acc1);
    vst1q_f64(s.as_mut_ptr().add(4), acc2);
    vst1q_f64(s.as_mut_ptr().add(6), acc3);
    for (l, i) in (chunks * 8..n).enumerate() {
        s[l] += *pa.add(i) * *pb.add(i);
    }
    lane_combine(&s)
}

/// Vector [`super::mul_into`]: elementwise product, 2 lanes at a time.
///
/// # Safety
/// `dst`, `a`, and `b` must be equal-length slices; NEON must be
/// available (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn mul_into_neon(dst: &mut [f64], a: &[f64], b: &[f64]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    let n = dst.len();
    let pd = dst.as_mut_ptr();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0;
    while i + 2 <= n {
        vst1q_f64(pd.add(i), vmulq_f64(vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i))));
        i += 2;
    }
    if i < n {
        *pd.add(i) = *pa.add(i) * *pb.add(i);
    }
}

/// Vector [`super::div_assign`]: elementwise quotient, 2 lanes at a time.
///
/// # Safety
/// `dst` and `by` must be equal-length slices; NEON must be available
/// (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn div_assign_neon(dst: &mut [f64], by: &[f64]) {
    debug_assert_eq!(dst.len(), by.len());
    let n = dst.len();
    let pd = dst.as_mut_ptr();
    let pb = by.as_ptr();
    let mut i = 0;
    while i + 2 <= n {
        vst1q_f64(pd.add(i), vdivq_f64(vld1q_f64(pd.add(i)), vld1q_f64(pb.add(i))));
        i += 2;
    }
    if i < n {
        *pd.add(i) /= *pb.add(i);
    }
}

/// Vector [`super::xoshiro_block`]: one xoshiro256++ step on two lanes at
/// a time, integer-exact; a trailing odd lane steps scalar. rotl(v, k)
/// is `(v << k) | (v >> (64 - k))`.
///
/// # Safety
/// All five slices must share one length; NEON must be available
/// (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn xoshiro_block_neon(
    s0: &mut [u64],
    s1: &mut [u64],
    s2: &mut [u64],
    s3: &mut [u64],
    out: &mut [u64],
) {
    let n = out.len();
    debug_assert!(s0.len() == n && s1.len() == n && s2.len() == n && s3.len() == n);
    let chunks = n / 2;
    for k in 0..chunks {
        let i = k * 2;
        let v0 = vld1q_u64(s0.as_ptr().add(i));
        let v1 = vld1q_u64(s1.as_ptr().add(i));
        let v2 = vld1q_u64(s2.as_ptr().add(i));
        let v3 = vld1q_u64(s3.as_ptr().add(i));
        // result = rotl(s0 + s3, 23) + s0   (wrapping adds)
        let sum = vaddq_u64(v0, v3);
        let rot = vorrq_u64(vshlq_n_u64::<23>(sum), vshrq_n_u64::<41>(sum));
        vst1q_u64(out.as_mut_ptr().add(i), vaddq_u64(rot, v0));
        // t = s1 << 17; s2 ^= s0; s3 ^= s1; s1 ^= s2; s0 ^= s3;
        // s2 ^= t; s3 = rotl(s3, 45)
        let t = vshlq_n_u64::<17>(v1);
        let v2 = veorq_u64(v2, v0);
        let v3 = veorq_u64(v3, v1);
        let v1 = veorq_u64(v1, v2);
        let v0 = veorq_u64(v0, v3);
        let v2 = veorq_u64(v2, t);
        let v3 = vorrq_u64(vshlq_n_u64::<45>(v3), vshrq_n_u64::<19>(v3));
        vst1q_u64(s0.as_mut_ptr().add(i), v0);
        vst1q_u64(s1.as_mut_ptr().add(i), v1);
        vst1q_u64(s2.as_mut_ptr().add(i), v2);
        vst1q_u64(s3.as_mut_ptr().add(i), v3);
    }
    if n % 2 == 1 {
        let i = n - 1;
        out[i] = xoshiro_lane_step(&mut s0[i], &mut s1[i], &mut s2[i], &mut s3[i]);
    }
}
