//! The serving engine: dispatcher + shard-worker pool around the runtime.
//!
//! Topology (see DESIGN.md §4): callers submit [`InferRequest`]s into a
//! bounded queue (backpressure); a dispatcher thread assembles fused
//! batches (size/deadline policy, pure cores in `coordinator::batch`) and
//! round-robins them over `cfg.server.workers` shard workers. Each shard
//! worker constructs its *own* engine (PJRT handles are not `Send`-safe by
//! contract, so engines are built inside the worker threads); its ε demand
//! is met per the pool's [`EpsilonSupply`] — an independent
//! [`EpsilonSource`] per shard (a GRNG bank seeded from a SplitMix64 split
//! of `die_seed`) for external-ε backends, or nothing at all for the cim
//! backend, whose memory arrays generate ε in-word during the MVM.
//!
//! This mirrors the chip scaled out: each lane's memory array produces the
//! randomness its MVMs consume, with no shared RNG unit on a bus, so ε
//! throughput scales linearly with the number of lanes. Shard 0 keeps the
//! unsplit `die_seed`, so a `workers = 1` pool reproduces the original
//! single-worker coordinator bit for bit, and a fixed `(die_seed,
//! workers)` pair replays identically for serial workloads (routing is
//! round-robin on the batch id, not racy work-stealing).
//!
//! The pool is supervised (DESIGN.md §9): a supervisor thread respawns
//! dead shard workers with their original shard index (so the
//! deterministic seed splits are re-derived), recovers the in-flight
//! batch, and redelivers it under `server.retry_budget` and each
//! request's admission-time deadline — see [`crate::coordinator::supervisor`]'s
//! module docs for the state machine.
//!
//! Client-facing construction and submission live in [`crate::client`]
//! (API v1): `Coordinator::builder(cfg)…start()`, `submit(Infer) →
//! Ticket`. The historical `start*` constructors remain below as
//! `#[deprecated]` one-line shims over the builder for one release.

use crate::client::{Infer, ServeError};
use crate::config::{Backend, Config};
use crate::coordinator::batch::Batch;
use crate::coordinator::dispatch::run_dispatcher;
use crate::coordinator::elastic::ElasticCtx;
use crate::coordinator::epsilon::{EpsilonSource, EpsilonSupply};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::{InferRequest, InferResponse, RejectReason, Reply};
use crate::coordinator::supervisor::{
    run_supervisor, spawn_shard_worker, InFlight, ShardHealth, ShardTable, SupervisorMsg,
    WorkerCtx,
};
use crate::error::{Error, Result};
use crate::runtime::EpsilonMode;
use crate::util::threadpool::Bounded;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Factory building one engine per shard, called inside the shard's own
/// worker thread (engines need not be `Send`). The argument is the shard
/// index.
pub type EngineFactory =
    Arc<dyn Fn(usize) -> Result<Box<dyn crate::runtime::InferenceEngine>> + Send + Sync>;

/// Factory building one ε source per shard, called inside the shard's own
/// worker thread. The argument is the shard index.
pub type SourceFactory = Arc<dyn Fn(usize) -> Box<dyn EpsilonSource> + Send + Sync>;

/// Handle to a running coordinator pool.
pub struct Coordinator {
    requests: Bounded<InferRequest>,
    table: Arc<ShardTable>,
    metrics: Metrics,
    cfg: Config,
    elastic: ElasticCtx,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    supervisor: Option<std::thread::JoinHandle<()>>,
    supervisor_tx: Sender<SupervisorMsg>,
    shutting_down: Arc<AtomicBool>,
    shards: usize,
    next_id: Arc<AtomicU64>,
}

impl Coordinator {
    /// Boot the full pool: `cfg.server.workers` shard workers, each with
    /// its own engine from the factory and its ε demand met per `supply`
    /// (external per-shard sources, or engine-owned in-word ε). The
    /// engine/supply resolution in front of this lives in
    /// [`crate::client::CoordinatorBuilder`].
    pub(crate) fn boot(
        cfg: Config,
        make_engine: EngineFactory,
        supply: EpsilonSupply,
    ) -> Result<Coordinator> {
        cfg.validate()?;
        let shards = cfg.server.workers.max(1);
        let requests: Bounded<InferRequest> = Bounded::new(cfg.server.queue_capacity);
        let shard_queues: Vec<Bounded<Batch>> = (0..shards).map(|_| Bounded::new(2)).collect();
        let slots: Vec<InFlight> = (0..shards).map(|_| InFlight::default()).collect();
        let metrics = Metrics::new(shards);
        // The table is built *before* the workers so it can ride inside
        // WorkerCtx: elastic workers steal queued batches from peers
        // through it.
        let table = Arc::new(ShardTable::new(shard_queues));

        // Elastic control plane: the hot-swap slot (owns the engine
        // factory — workers and supervisor respawns both build from the
        // published factory) plus per-shard replica targets, seeded at
        // the static pool size.
        let elastic = ElasticCtx::new(
            cfg.server.elastic,
            shards,
            cfg.server.mc_workers.max(1),
            make_engine,
        );

        // Everything a (re)spawn needs, kept by the supervisor for the
        // pool's lifetime so a restarted shard is built from the same
        // factory/supply/config as at boot (or the swapped-in factory,
        // if a model swap was published since).
        let ctx = WorkerCtx {
            supply,
            metrics: metrics.clone(),
            cfg: cfg.clone(),
            requests: requests.clone(),
            elastic: elastic.clone(),
            table: Arc::clone(&table),
        };
        let (exit_tx, exit_rx) = channel::<SupervisorMsg>();

        // Spawn the workers; each reports Ok(artifact batch) or Err(msg)
        // once its engine is constructed.
        let (ready_tx, ready_rx) = channel::<std::result::Result<usize, String>>();
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let handle = spawn_shard_worker(
                shard,
                &ctx,
                table.queue(shard),
                slots[shard].clone(),
                exit_tx.clone(),
                ready_tx.clone(),
            )?;
            workers.push(handle);
        }
        drop(ready_tx);

        let mut failure: Option<Error> = None;
        let mut min_art_batch = usize::MAX;
        for _ in 0..shards {
            match ready_rx.recv() {
                Ok(Ok(art_batch)) => min_art_batch = min_art_batch.min(art_batch.max(1)),
                Ok(Err(msg)) => {
                    failure = Some(Error::Coordinator(format!("engine load: {msg}")))
                }
                Err(_) => {
                    failure =
                        Some(Error::Coordinator("shard worker died during startup".into()))
                }
            }
        }
        if let Some(err) = failure {
            requests.close();
            table.close_all();
            for w in workers {
                let _ = w.join();
            }
            return Err(err);
        }

        let handles: Arc<Mutex<Vec<Option<std::thread::JoinHandle<()>>>>> =
            Arc::new(Mutex::new(workers.into_iter().map(Some).collect()));
        let shutting_down = Arc::new(AtomicBool::new(false));

        // Batches can never exceed what the smallest engine can pack.
        let max_batch = cfg.server.max_batch.min(min_art_batch);
        let deadline = Duration::from_secs_f64(cfg.server.batch_deadline_ms / 1e3);
        let dispatcher = {
            let requests = requests.clone();
            let table = Arc::clone(&table);
            let metrics = metrics.clone();
            let elastic = elastic.clone();
            let max_mc = cfg.server.max_mc_workers.max(1);
            std::thread::Builder::new()
                .name("bnn-cim-dispatcher".into())
                .spawn(move || {
                    run_dispatcher(requests, table, metrics, max_batch, deadline, elastic, max_mc)
                })
                .map_err(|e| Error::Coordinator(format!("spawn dispatcher: {e}")))?
        };
        // The supervisor owns the worker handles from here on: it joins
        // dead workers as it respawns them and joins the whole (possibly
        // respawned) pool at shutdown.
        let supervisor = {
            let exit_tx = exit_tx.clone();
            let table = Arc::clone(&table);
            let shutting_down = Arc::clone(&shutting_down);
            std::thread::Builder::new()
                .name("bnn-cim-supervisor".into())
                .spawn(move || {
                    run_supervisor(exit_rx, exit_tx, table, slots, handles, ctx, shutting_down)
                })
                .map_err(|e| Error::Coordinator(format!("spawn supervisor: {e}")))?
        };

        Ok(Coordinator {
            requests,
            table,
            metrics,
            cfg,
            elastic,
            dispatcher: Some(dispatcher),
            supervisor: Some(supervisor),
            supervisor_tx: exit_tx,
            shutting_down,
            shards,
            next_id: Arc::new(AtomicU64::new(1)),
        })
    }

    /// Admission core behind `client::Coordinator::{submit, infer}`:
    /// validate, allocate an id, enqueue. Kept here so the queue and
    /// config stay private to this module.
    pub(crate) fn submit_request(
        &self,
        req: Infer,
    ) -> std::result::Result<(u64, Receiver<Reply>), ServeError> {
        let Infer {
            pixels,
            mc_samples,
            defer_threshold,
            deadline,
        } = req;
        let expected = self.cfg.model.image_side * self.cfg.model.image_side;
        if pixels.len() != expected {
            self.metrics.record_reject();
            return Err(ServeError::WrongShape {
                expected,
                got: pixels.len(),
            });
        }
        // Bound t up front: one greedy request must not inflate the MC
        // pass count for every batch-mate it gets fused with.
        if mc_samples > self.cfg.server.max_mc_samples {
            self.metrics.record_reject();
            return Err(ServeError::McSamplesTooLarge {
                max: self.cfg.server.max_mc_samples,
                got: mc_samples,
            });
        }
        // Same bound Config::validate applies to the server default.
        if let Some(h) = defer_threshold {
            if !h.is_finite() || !(0.0..=10.0).contains(&h) {
                self.metrics.record_reject();
                return Err(ServeError::InvalidDeferThreshold { got: h });
            }
        }
        let (tx, rx) = channel();
        let enqueued = crate::util::clock::now();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            pixels,
            mc_samples,
            defer_threshold,
            enqueued,
            // Fixed at admission: a retried request keeps this instant,
            // so failure recovery never stretches the caller's budget.
            deadline: enqueued + deadline.unwrap_or_else(|| self.request_timeout()),
            retries: 0,
            reply: tx,
        };
        let id = req.id;
        match self.requests.try_send(req) {
            Ok(()) => Ok((id, rx)),
            Err(_) => {
                self.metrics.record_reject();
                // A closed queue (pool tearing down) is not "try again
                // later" — distinguish it from backpressure.
                Err(if self.requests.is_closed() {
                    ServeError::ShuttingDown
                } else {
                    ServeError::QueueFull
                })
            }
        }
    }

    /// The blocking-call deadline (`server.request_timeout_ms`).
    pub(crate) fn request_timeout(&self) -> Duration {
        Duration::from_secs_f64(self.cfg.server.request_timeout_ms / 1e3)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Number of shard workers in the pool (healthy or not).
    pub fn workers(&self) -> usize {
        self.shards
    }

    /// Per-shard liveness as tracked by the supervisor:
    /// `healthy` / `restarting/n` / `dead` (DESIGN.md §9). Surfaced by
    /// the edge's `/v1/health`.
    pub fn shard_health(&self) -> Vec<ShardHealth> {
        self.table.health()
    }

    /// Shards currently serving (health == `Healthy`).
    pub fn healthy_workers(&self) -> usize {
        self.table.healthy_count()
    }

    /// True once every shard is terminally dead (`shard_restart_limit`
    /// exceeded or respawns failing): the pool cannot serve again, and
    /// new submissions fail fast with [`ServeError::ShardFailed`].
    pub fn all_shards_dead(&self) -> bool {
        self.table.all_dead()
    }

    /// Requests currently waiting in the admission queue. The network
    /// edge's load signal: `queue_depth() / queue_capacity()` is the
    /// instantaneous load fraction its shed/degrade thresholds act on.
    pub fn queue_depth(&self) -> usize {
        self.requests.len()
    }

    /// Capacity of the admission queue (`server.queue_capacity`).
    pub fn queue_capacity(&self) -> usize {
        self.cfg.server.queue_capacity
    }

    /// Live handle to the shared metrics registry, so out-of-band
    /// observers (the network edge's admission counters) can record into
    /// the same ledger the shard workers use. Snapshots stay
    /// non-destructive; this is a `Clone` of the `Arc`ed registry.
    pub fn metrics_registry(&self) -> Metrics {
        self.metrics.clone()
    }

    /// The resolved configuration this pool was booted with (read-only).
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Publish a new engine factory for online model hot-swap
    /// (publish-drain-flip; DESIGN.md §10). Returns the new swap
    /// generation. Each shard worker finishes the batch it is serving,
    /// notices the generation bump at its next batch boundary, builds
    /// the new engine *in its own thread*, and flips — no request is
    /// ever served by a torn model and no downtime is taken. Supervisor
    /// respawns also build from the published factory.
    ///
    /// Compatibility rules (violations keep the old model serving and
    /// are logged): the new engine's artifact batch must not be smaller
    /// than the pool's boot-time batch, and its ε mode must be
    /// satisfiable by the pool's ε supply. Engine-owned energy/ε
    /// counters restart from zero on the new engine; the metrics
    /// registry keeps absolute totals, so cumulative counters simply
    /// continue from the swap point.
    pub fn swap_model(&self, factory: EngineFactory) -> u64 {
        self.elastic.swap.publish(factory)
    }

    /// Force one shard's MC-replica target (operator override and the
    /// deterministic escape hatch for tests). Clamped to
    /// `[min_mc_workers, max_mc_workers]`; the owning worker applies it
    /// at its next batch boundary or idle tick. With `server.elastic`
    /// off the target is applied on the next served batch but never
    /// drifts afterwards (no autoscaler is running).
    pub fn set_replica_target(&self, shard: usize, n: usize) {
        let lo = self.cfg.server.min_mc_workers.max(1);
        let hi = self.cfg.server.max_mc_workers.max(lo);
        self.elastic.set_target(shard, n.clamp(lo, hi));
    }

    /// The current MC-replica target for `shard` (what the autoscaler
    /// or an operator override has asked for; the live count is the
    /// `replicas_active` gauge in [`Coordinator::metrics`]).
    pub fn replica_target(&self, shard: usize) -> usize {
        self.elastic.target(shard)
    }

    /// Graceful shutdown: close the request queue, let the dispatcher
    /// flush and close the shard queues, join everything.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        // Flag first: worker exits during the drain are normal, and the
        // supervisor must not respawn into a closing pool.
        self.shutting_down.store(true, Ordering::SeqCst);
        self.requests.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // The dispatcher closes the shard queues on exit; repeat here so a
        // dispatcher that never started still lets the workers drain.
        self.table.close_all();
        // The supervisor owns the worker handles (it joins respawned
        // threads the constructor never saw); tell it to finish and wait.
        if let Some(s) = self.supervisor.take() {
            let _ = self.supervisor_tx.send(SupervisorMsg::Shutdown);
            let _ = s.join();
        }
    }
}

/// Deprecated constructors (pre-v1 surface): one-line shims over
/// [`crate::client::CoordinatorBuilder`], kept for one release so
/// downstream code migrates on its own schedule. Referenced only by the
/// shim-equivalence test in `tests/api_surface.rs`.
impl Coordinator {
    /// Start with the default engine (PJRT artifacts).
    #[deprecated(note = "use Coordinator::builder(cfg).backend(Backend::Pjrt).start()")]
    pub fn start(cfg: Config) -> Result<Coordinator> {
        Self::builder(cfg)
            .backend(Backend::Pjrt)
            .start()
            .map_err(Error::from)
    }

    /// Start on the backend named by `cfg.server.backend`.
    #[deprecated(note = "use Coordinator::builder(cfg).start()")]
    pub fn start_backend(cfg: Config) -> Result<Coordinator> {
        Self::builder(cfg).start().map_err(Error::from)
    }

    /// Start on the pure-Rust [`crate::runtime::SimEngine`] backend.
    #[deprecated(note = "use Coordinator::builder(cfg).backend(Backend::Sim).start()")]
    pub fn start_sim(cfg: Config) -> Result<Coordinator> {
        Self::builder(cfg)
            .backend(Backend::Sim)
            .start()
            .map_err(Error::from)
    }

    /// Start on the behavioral chip model ([`crate::runtime::CimEngine`]).
    #[deprecated(note = "use Coordinator::builder(cfg).backend(Backend::Cim).start()")]
    pub fn start_cim(cfg: Config) -> Result<Coordinator> {
        Self::builder(cfg)
            .backend(Backend::Cim)
            .start()
            .map_err(Error::from)
    }

    /// Start with custom ε sources on the default (PJRT) engine.
    #[deprecated(note = "use Coordinator::builder(cfg).source_factory(f).start()")]
    pub fn start_with_source(cfg: Config, make_source: SourceFactory) -> Result<Coordinator> {
        Self::builder(cfg)
            .backend(Backend::Pjrt)
            .source_factory(make_source)
            .start()
            .map_err(Error::from)
    }

    /// Start with explicit engine factory and ε supply.
    #[deprecated(
        note = "use builder(cfg).engine_factory(f) with .source_factory(s) or .epsilon(mode)"
    )]
    pub fn start_with(
        cfg: Config,
        make_engine: EngineFactory,
        supply: EpsilonSupply,
    ) -> Result<Coordinator> {
        let builder = Self::builder(cfg).engine_factory(make_engine);
        match supply {
            EpsilonSupply::External(f) => builder.source_factory(f),
            EpsilonSupply::InWord => builder.epsilon(EpsilonMode::InWord),
        }
        .start()
        .map_err(Error::from)
    }

    /// Blocking convenience wrapper, with its historical signature: the
    /// pre-v1 error vocabulary ([`RejectReason`]) and the pre-v1
    /// behavior of folding every wait failure into `Timeout`.
    #[deprecated(note = "use Coordinator::infer(Infer::new(pixels).mc_samples(t))")]
    pub fn infer_blocking(
        &self,
        pixels: Vec<f32>,
        mc_samples: usize,
    ) -> std::result::Result<InferResponse, RejectReason> {
        self.infer(Infer::new(pixels).mc_samples(mc_samples))
            .map_err(|e| match e {
                ServeError::QueueFull => RejectReason::QueueFull,
                ServeError::WrongShape { expected, got } => {
                    RejectReason::WrongShape { expected, got }
                }
                ServeError::McSamplesTooLarge { max, got } => {
                    RejectReason::McSamplesTooLarge { max, got }
                }
                ServeError::ShuttingDown => RejectReason::ShuttingDown,
                _ => RejectReason::Timeout,
            })
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(feature = "pjrt")]
pub(crate) fn pjrt_engine_factory(cfg: &Config) -> EngineFactory {
    let artifacts = std::path::PathBuf::from(&cfg.model.artifacts_dir);
    Arc::new(move |_shard| {
        let engine = crate::runtime::Engine::load(&artifacts)?;
        Ok(Box::new(engine) as Box<dyn crate::runtime::InferenceEngine>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticPerson;

    fn sim_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.model.mc_samples = 4;
        cfg.server.batch_deadline_ms = 5.0;
        cfg
    }

    #[test]
    fn builder_dispatches_on_config_backend() {
        let mut cfg = sim_cfg();
        cfg.server.backend = crate::config::Backend::Sim;
        let coord = Coordinator::builder(cfg).start().unwrap();
        let gen = SyntheticPerson::new(32, 3);
        let resp = coord.infer(Infer::new(gen.sample(0).pixels)).unwrap();
        assert_eq!(resp.pred.probs.len(), 2);
        // External-ε backend: no tile energy model, zero request energy.
        assert_eq!(resp.energy_j, 0.0);
        coord.shutdown();
    }

    #[test]
    fn coordinator_serves_on_sim_engine() {
        let cfg = sim_cfg();
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Sim)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(32, 77);
        for i in 0..6 {
            let s = gen.sample(i);
            let resp = coord.infer(Infer::new(s.pixels)).unwrap();
            assert_eq!(resp.pred.probs.len(), 2);
            assert!((resp.pred.probs.iter().sum::<f64>() - 1.0).abs() < 1e-5);
        }
        let m = coord.metrics();
        assert_eq!(m.requests_total, 6);
        assert!(m.epsilon_samples > 0);
        assert!(m.pjrt_executions > 0);
        assert_eq!(m.per_shard.len(), 1);
        assert_eq!(m.per_shard[0].requests, 6);
        coord.shutdown();
    }

    #[test]
    fn coordinator_rejects_bad_shapes_oversized_mc_and_bad_thresholds() {
        let mut cfg = sim_cfg();
        cfg.server.max_mc_samples = 16;
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Sim)
            .start()
            .unwrap();
        let err = coord.submit(Infer::new(vec![0.0; 7])).unwrap_err();
        assert!(matches!(err, ServeError::WrongShape { .. }));
        let err = coord
            .submit(Infer::new(vec![0.0; 32 * 32]).mc_samples(17))
            .unwrap_err();
        assert!(matches!(
            err,
            ServeError::McSamplesTooLarge { max: 16, got: 17 }
        ));
        let err = coord
            .submit(Infer::new(vec![0.0; 32 * 32]).defer_threshold(f64::NAN))
            .unwrap_err();
        assert!(matches!(err, ServeError::InvalidDeferThreshold { .. }));
        let err = coord
            .submit(Infer::new(vec![0.0; 32 * 32]).defer_threshold(-0.5))
            .unwrap_err();
        assert!(matches!(err, ServeError::InvalidDeferThreshold { .. }));
        // At the bounds is still accepted.
        let ticket = coord
            .submit(Infer::new(vec![0.0; 32 * 32]).mc_samples(16).defer_threshold(10.0))
            .unwrap();
        ticket.wait_timeout(Duration::from_secs(30)).unwrap();
        let m = coord.metrics();
        assert_eq!(m.requests_rejected, 4);
        assert_eq!(m.requests_total, 1);
        coord.shutdown();
    }

    #[test]
    fn builder_rejects_external_epsilon_on_stock_cim_backend() {
        use crate::coordinator::epsilon::GrngBankSource;
        // The stock cim engine owns its ε; a supplied source would be
        // silently unused by the worker handshake — the builder must
        // refuse instead (an ablation believing it measured its source).
        let cfg = sim_cfg();
        let err = Coordinator::builder(cfg.clone())
            .backend(Backend::Cim)
            .source_factory(GrngBankSource::shard_factory(&cfg.chip))
            .start()
            .unwrap_err();
        assert!(matches!(err, ServeError::Config(_)), "got {err:?}");
        let err = Coordinator::builder(cfg)
            .backend(Backend::Cim)
            .epsilon(crate::runtime::EpsilonMode::External)
            .start()
            .unwrap_err();
        assert!(matches!(err, ServeError::Config(_)), "got {err:?}");
    }

    #[test]
    fn coordinator_batches_concurrent_requests() {
        let mut cfg = sim_cfg();
        cfg.server.batch_deadline_ms = 30.0;
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Sim)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(32, 5);
        let tickets = coord
            .submit_many((0..8).map(|i| Infer::new(gen.sample(i).pixels)))
            .unwrap();
        let responses: Vec<_> = tickets
            .into_iter()
            .map(|t| t.wait_timeout(Duration::from_secs(30)).unwrap())
            .collect();
        let m = coord.metrics();
        // 8 requests in ≤ a few batches (deadline batching).
        assert!(
            m.batches < 8,
            "batching should fuse requests: {} batches",
            m.batches
        );
        let ids: std::collections::HashSet<u64> =
            responses.iter().map(|r| r.batch_id).collect();
        assert!(ids.len() < 8);
        coord.shutdown();
    }

    #[test]
    fn multi_worker_pool_serves_everything() {
        let mut cfg = sim_cfg();
        cfg.server.batch_deadline_ms = 1.0;
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Sim)
            .workers(4)
            .start()
            .unwrap();
        assert_eq!(coord.workers(), 4);
        let gen = SyntheticPerson::new(32, 11);
        let tickets = coord
            .submit_many((0..32).map(|i| Infer::new(gen.sample(i).pixels)))
            .unwrap();
        for t in tickets {
            t.wait_timeout(Duration::from_secs(30)).unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.requests_total, 32);
        assert_eq!(m.per_shard.len(), 4);
        let shard_requests: u64 = m.per_shard.iter().map(|s| s.requests).sum();
        assert_eq!(shard_requests, 32);
        let shard_exec: u64 = m.per_shard.iter().map(|s| s.engine_executions).sum();
        assert_eq!(shard_exec, m.pjrt_executions);
        let shard_eps: u64 = m.per_shard.iter().map(|s| s.epsilon_samples).sum();
        assert_eq!(shard_eps, m.epsilon_samples);
        coord.shutdown();
    }

    #[test]
    fn ticket_try_wait_polls_without_blocking() {
        let cfg = sim_cfg();
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Sim)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(32, 13);
        let ticket = coord.submit(Infer::new(gen.sample(0).pixels)).unwrap();
        let t0 = crate::util::clock::now();
        let resp = loop {
            match ticket.try_wait().unwrap() {
                Some(resp) => break resp,
                None => {
                    assert!(t0.elapsed() < Duration::from_secs(60), "response never came");
                    std::thread::yield_now();
                }
            }
        };
        assert_eq!(resp.id, ticket.id);
        // Drained: the channel reports Disconnected after shutdown, not
        // a second response.
        coord.shutdown();
        assert!(matches!(ticket.try_wait(), Err(ServeError::Disconnected)));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn coordinator_end_to_end_on_artifacts() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut cfg = Config::default();
        cfg.model.mc_samples = 8;
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Pjrt)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(32, 77);
        let mut correct = 0;
        let n = 12;
        for i in 0..n {
            let s = gen.sample(i);
            let resp = coord.infer(Infer::new(s.pixels)).unwrap();
            assert_eq!(resp.pred.probs.len(), 2);
            assert!((resp.pred.probs.iter().sum::<f64>() - 1.0).abs() < 1e-6);
            if resp.pred.class == s.label {
                correct += 1;
            }
        }
        // The trained model should beat chance comfortably.
        assert!(
            correct >= (n * 6 / 10) as i32,
            "accuracy too low: {correct}/{n}"
        );
        let m = coord.metrics();
        assert_eq!(m.requests_total, n as u64);
        assert!(m.epsilon_samples > 0);
        coord.shutdown();
    }
}
