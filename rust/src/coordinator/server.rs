//! The serving engine: dynamic batcher + Monte-Carlo sample scheduler +
//! deferral policy around the PJRT runtime.
//!
//! Topology: callers submit [`InferRequest`]s into a bounded queue
//! (backpressure); a worker thread owns the PJRT [`Engine`] (its handles
//! are not `Send`-safe by contract, so the engine is *constructed inside*
//! the worker) and runs the loop:
//!
//!   collect batch (size/deadline) → `features` once → T × (fill ε from
//!   the in-word GRNG bank → `head`) → aggregate → defer/reply.
//!
//! This mirrors the chip: features stream through deterministic layers,
//! while every MC pass re-samples all Bayesian weights in parallel from
//! the in-memory GRNG.

use crate::bayes::aggregate_mc;
use crate::config::Config;
use crate::coordinator::epsilon::{EpsilonSource, GrngBankSource};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::{InferRequest, InferResponse, RejectReason};
use crate::error::{Error, Result};
use crate::runtime::Engine;
use crate::util::threadpool::Bounded;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Factory building the ε source inside the worker thread.
pub type SourceFactory = Box<dyn FnOnce() -> Box<dyn EpsilonSource> + Send>;

/// Handle to a running coordinator.
pub struct Coordinator {
    queue: Bounded<InferRequest>,
    metrics: Metrics,
    cfg: Config,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: Arc<AtomicU64>,
}

impl Coordinator {
    /// Start with the default ε source (the simulated in-word GRNG bank).
    pub fn start(cfg: Config) -> Result<Coordinator> {
        let chip = cfg.chip.clone();
        Self::start_with_source(cfg, Box::new(move || Box::new(GrngBankSource::new(&chip))))
    }

    /// Start with a custom ε source (ablations: Philox mirror, Wallace…).
    pub fn start_with_source(cfg: Config, make_source: SourceFactory) -> Result<Coordinator> {
        let queue: Bounded<InferRequest> = Bounded::new(cfg.server.queue_capacity);
        let metrics = Metrics::new();
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let worker = {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("bnn-cim-coordinator".into())
                .spawn(move || {
                    let artifacts = PathBuf::from(&cfg.model.artifacts_dir);
                    let engine = match Engine::load(&artifacts) {
                        Ok(e) => e,
                        Err(e) => {
                            let _ = ready_tx.send(Err(e.to_string()));
                            return;
                        }
                    };
                    let source = make_source();
                    let _ = ready_tx.send(Ok(()));
                    worker_loop(engine, source, queue, metrics, cfg);
                })
                .map_err(|e| Error::Coordinator(format!("spawn: {e}")))?
        };
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => return Err(Error::Coordinator(format!("engine load: {msg}"))),
            Err(_) => return Err(Error::Coordinator("worker died during startup".into())),
        }
        Ok(Coordinator {
            queue,
            metrics,
            cfg,
            worker: Some(worker),
            next_id: Arc::new(AtomicU64::new(1)),
        })
    }

    /// Submit asynchronously; the returned receiver yields the response.
    pub fn submit(
        &self,
        pixels: Vec<f32>,
        mc_samples: usize,
    ) -> std::result::Result<std::sync::mpsc::Receiver<InferResponse>, RejectReason> {
        let expected = self.cfg.model.image_side * self.cfg.model.image_side;
        if pixels.len() != expected {
            self.metrics.record_reject();
            return Err(RejectReason::WrongShape {
                expected,
                got: pixels.len(),
            });
        }
        let (tx, rx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            pixels,
            mc_samples,
            enqueued: Instant::now(),
            reply: tx,
        };
        match self.queue.try_send(req) {
            Ok(()) => Ok(rx),
            Err(_) => {
                self.metrics.record_reject();
                Err(RejectReason::QueueFull)
            }
        }
    }

    /// Blocking convenience wrapper.
    pub fn infer_blocking(
        &self,
        pixels: Vec<f32>,
        mc_samples: usize,
    ) -> std::result::Result<InferResponse, RejectReason> {
        let rx = self.submit(pixels, mc_samples)?;
        let timeout = Duration::from_secs_f64(self.cfg.server.request_timeout_ms / 1e3);
        rx.recv_timeout(timeout).map_err(|_| RejectReason::Timeout)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The batching/inference loop (runs on the worker thread).
fn worker_loop(
    mut engine: Engine,
    mut source: Box<dyn EpsilonSource>,
    queue: Bounded<InferRequest>,
    metrics: Metrics,
    cfg: Config,
) {
    let manifest = engine.manifest().clone();
    let art_batch = manifest.batch;
    let feat_spec = manifest.entry("features").expect("features entry").clone();
    let head_spec = manifest.entry("head").expect("head entry").clone();
    let pixels_per_img: usize = manifest.side * manifest.side;
    let classes = manifest.classes;
    let deadline = Duration::from_secs_f64(cfg.server.batch_deadline_ms / 1e3);
    let mut batch_id: u64 = 0;

    'outer: loop {
        // Block for the first request (or shutdown).
        let first = match queue.recv() {
            Some(r) => r,
            None => break 'outer,
        };
        let mut batch = vec![first];
        // Fill up to max_batch until the deadline.
        let batch_deadline = Instant::now() + deadline;
        while batch.len() < cfg.server.max_batch.min(art_batch) {
            let now = Instant::now();
            if now >= batch_deadline {
                break;
            }
            match queue.recv_timeout(batch_deadline - now) {
                Ok(Some(r)) => batch.push(r),
                Ok(None) => break, // timeout
                Err(()) => {
                    // closed: serve what we have, then exit.
                    serve_batch(
                        &mut engine, &mut source, &batch, &metrics, &cfg, &feat_spec,
                        &head_spec, art_batch, pixels_per_img, classes, batch_id,
                    );
                    break 'outer;
                }
            }
        }
        batch_id += 1;
        serve_batch(
            &mut engine, &mut source, &batch, &metrics, &cfg, &feat_spec, &head_spec,
            art_batch, pixels_per_img, classes, batch_id,
        );
        metrics.record_epsilon(source.samples_drawn(), source.energy_j());
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_batch(
    engine: &mut Engine,
    source: &mut Box<dyn EpsilonSource>,
    batch: &[InferRequest],
    metrics: &Metrics,
    cfg: &Config,
    feat_spec: &crate::runtime::ArtifactSpec,
    head_spec: &crate::runtime::ArtifactSpec,
    art_batch: usize,
    pixels_per_img: usize,
    classes: usize,
    batch_id: u64,
) {
    let t = batch
        .iter()
        .map(|r| {
            if r.mc_samples == 0 {
                cfg.model.mc_samples
            } else {
                r.mc_samples
            }
        })
        .max()
        .unwrap_or(cfg.model.mc_samples);

    // Pad images to the artifact's static batch.
    let mut images = vec![0.0f32; art_batch * pixels_per_img];
    for (i, req) in batch.iter().enumerate() {
        images[i * pixels_per_img..(i + 1) * pixels_per_img].copy_from_slice(&req.pixels);
    }

    let exec_before = engine.executions;
    let feats = match engine.run("features", &[(&images, &feat_spec.inputs[0].1)]) {
        Ok(f) => f,
        Err(e) => {
            log::error!("features execution failed: {e}");
            return;
        }
    };

    // T MC passes with fresh ε each — PACKED: every artifact call has
    // `art_batch` slots, and each slot can carry any (request, MC-pass)
    // pair, so the number of PJRT executions is ceil(k·T / B) instead of
    // T. (§Perf in EXPERIMENTS.md: ~5× fewer head executions at k=1,
    // T=32, B=8.) Features are replicated into the slots of each call.
    let e1_len = head_spec.input_len(1);
    let e2_len = head_spec.input_len(2);
    let feat_dim = feats.len() / art_batch;
    let mut eps1 = vec![0.0f32; e1_len];
    let mut eps2 = vec![0.0f32; e2_len];
    let mut per_request: Vec<Vec<Vec<f64>>> = vec![Vec::with_capacity(t); batch.len()];
    let total_slots = batch.len() * t;
    let calls = total_slots.div_ceil(art_batch);
    let mut packed_feats = vec![0.0f32; feats.len()];
    for call in 0..calls {
        // Assign (request, pass) pairs to this call's slots.
        let mut owners = Vec::with_capacity(art_batch);
        for slot in 0..art_batch {
            let g = call * art_batch + slot;
            if g < total_slots {
                let req = g / t;
                owners.push(req);
                packed_feats[slot * feat_dim..(slot + 1) * feat_dim]
                    .copy_from_slice(&feats[req * feat_dim..(req + 1) * feat_dim]);
            }
        }
        // Fresh ε for every slot (each slot is an independent MC pass).
        source.fill(&mut eps1);
        source.fill(&mut eps2);
        let probs = match engine.run(
            "head",
            &[
                (&packed_feats, &head_spec.inputs[0].1),
                (&eps1, &head_spec.inputs[1].1),
                (&eps2, &head_spec.inputs[2].1),
            ],
        ) {
            Ok(p) => p,
            Err(e) => {
                log::error!("head execution failed: {e}");
                return;
            }
        };
        for (slot, &req) in owners.iter().enumerate() {
            per_request[req].push(
                probs[slot * classes..(slot + 1) * classes]
                    .iter()
                    .map(|&v| v as f64)
                    .collect(),
            );
        }
    }
    metrics.record_batch(
        batch.len(),
        art_batch,
        t as u64,
        engine.executions - exec_before,
    );

    for (req, samples) in batch.iter().zip(per_request.iter()) {
        let pred = aggregate_mc(samples);
        let deferred = pred.entropy > cfg.model.defer_threshold;
        let latency = req.enqueued.elapsed();
        metrics.record_response(latency, deferred);
        let _ = req.reply.send(InferResponse {
            id: req.id,
            pred,
            deferred,
            latency,
            batch_id,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticPerson;
    use std::path::Path;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn coordinator_end_to_end() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut cfg = Config::default();
        cfg.model.mc_samples = 8;
        let coord = Coordinator::start(cfg).unwrap();
        let gen = SyntheticPerson::new(32, 77);
        let mut correct = 0;
        let n = 12;
        for i in 0..n {
            let s = gen.sample(i);
            let resp = coord.infer_blocking(s.pixels, 0).unwrap();
            assert_eq!(resp.pred.probs.len(), 2);
            assert!((resp.pred.probs.iter().sum::<f64>() - 1.0).abs() < 1e-6);
            if resp.pred.class == s.label {
                correct += 1;
            }
        }
        // The trained model should beat chance comfortably.
        assert!(
            correct >= (n * 6 / 10) as i32,
            "accuracy too low: {correct}/{n}"
        );
        let m = coord.metrics();
        assert_eq!(m.requests_total, n as u64);
        assert!(m.epsilon_samples > 0);
        coord.shutdown();
    }

    #[test]
    fn coordinator_rejects_bad_shapes() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let coord = Coordinator::start(Config::default()).unwrap();
        let err = coord.submit(vec![0.0; 7], 0).unwrap_err();
        assert!(matches!(err, RejectReason::WrongShape { .. }));
        coord.shutdown();
    }

    #[test]
    fn coordinator_batches_concurrent_requests() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut cfg = Config::default();
        cfg.model.mc_samples = 4;
        cfg.server.batch_deadline_ms = 30.0;
        let coord = Coordinator::start(cfg).unwrap();
        let gen = SyntheticPerson::new(32, 5);
        let receivers: Vec<_> = (0..8)
            .map(|i| coord.submit(gen.sample(i).pixels, 0).unwrap())
            .collect();
        let responses: Vec<_> = receivers
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap())
            .collect();
        let m = coord.metrics();
        // 8 requests in ≤ a few batches (deadline batching).
        assert!(
            m.batches < 8,
            "batching should fuse requests: {} batches",
            m.batches
        );
        let ids: std::collections::HashSet<u64> =
            responses.iter().map(|r| r.batch_id).collect();
        assert!(ids.len() < 8);
        coord.shutdown();
    }
}
