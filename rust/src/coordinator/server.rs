//! The serving engine: dispatcher + shard-worker pool around the runtime.
//!
//! Topology (see DESIGN.md §4): callers submit [`InferRequest`]s into a
//! bounded queue (backpressure); a dispatcher thread assembles fused
//! batches (size/deadline policy, pure cores in `coordinator::batch`) and
//! round-robins them over `cfg.server.workers` shard workers. Each shard
//! worker constructs its *own* engine (PJRT handles are not `Send`-safe by
//! contract, so engines are built inside the worker threads); its ε demand
//! is met per the pool's [`EpsilonSupply`] — an independent
//! [`EpsilonSource`] per shard (a GRNG bank seeded from a SplitMix64 split
//! of `die_seed`) for external-ε backends, or nothing at all for the cim
//! backend, whose memory arrays generate ε in-word during the MVM.
//!
//! This mirrors the chip scaled out: each lane's memory array produces the
//! randomness its MVMs consume, with no shared RNG unit on a bus, so ε
//! throughput scales linearly with the number of lanes. Shard 0 keeps the
//! unsplit `die_seed`, so a `workers = 1` pool reproduces the original
//! single-worker coordinator bit for bit, and a fixed `(die_seed,
//! workers)` pair replays identically for serial workloads (routing is
//! round-robin on the batch id, not racy work-stealing).

use crate::config::{Backend, Config};
use crate::coordinator::batch::Batch;
use crate::coordinator::dispatch::{run_dispatcher, run_shard_worker};
use crate::coordinator::epsilon::{EpsilonSource, EpsilonSupply};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::{InferRequest, InferResponse, RejectReason};
use crate::error::{Error, Result};
use crate::runtime::{CimEngine, EpsilonMode, InferenceEngine, SimEngine};
use crate::util::threadpool::Bounded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Factory building one engine per shard, called inside the shard's own
/// worker thread (engines need not be `Send`). The argument is the shard
/// index.
pub type EngineFactory = Arc<dyn Fn(usize) -> Result<Box<dyn InferenceEngine>> + Send + Sync>;

/// Factory building one ε source per shard, called inside the shard's own
/// worker thread. The argument is the shard index.
pub type SourceFactory = Arc<dyn Fn(usize) -> Box<dyn EpsilonSource> + Send + Sync>;

/// Handle to a running coordinator pool.
pub struct Coordinator {
    requests: Bounded<InferRequest>,
    shard_queues: Vec<Bounded<Batch>>,
    metrics: Metrics,
    cfg: Config,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: Arc<AtomicU64>,
}

impl Coordinator {
    /// Start with the default engine (the PJRT runtime; requires the
    /// `pjrt` feature and built artifacts) and the default ε supply
    /// (per-shard simulated in-word GRNG banks, coordinator-owned).
    pub fn start(cfg: Config) -> Result<Coordinator> {
        #[cfg(feature = "pjrt")]
        return Self::start_with(
            cfg.clone(),
            pjrt_engine_factory(&cfg),
            EpsilonSupply::grng_banks(&cfg.chip),
        );
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = cfg;
            Err(Error::Runtime(
                "built without the `pjrt` feature — use Coordinator::start_sim \
                 (pure-Rust engine), start_cim (chip model), or start_with"
                    .into(),
            ))
        }
    }

    /// Start on the backend named by `cfg.server.backend` (the
    /// `serve --backend {sim,cim,pjrt}` entry point).
    pub fn start_backend(cfg: Config) -> Result<Coordinator> {
        match cfg.server.backend {
            Backend::Sim => Self::start_sim(cfg),
            Backend::Cim => Self::start_cim(cfg),
            Backend::Pjrt => Self::start(cfg),
        }
    }

    /// Start on the pure-Rust [`SimEngine`] backend: no artifacts, no
    /// PJRT toolchain. Every shard replicates the same deterministic
    /// weights; ε still comes from per-shard GRNG banks.
    pub fn start_sim(cfg: Config) -> Result<Coordinator> {
        let engine_cfg = cfg.clone();
        let make_engine: EngineFactory = Arc::new(move |_shard| {
            Ok(Box::new(SimEngine::from_config(&engine_cfg)) as Box<dyn InferenceEngine>)
        });
        let supply = EpsilonSupply::grng_banks(&cfg.chip);
        Self::start_with(cfg, make_engine, supply)
    }

    /// Start on the behavioral chip model ([`CimEngine`]): the Bayesian
    /// head runs on simulated CIM tile arrays whose in-word GRNG banks
    /// generate ε *inside* the engine — the coordinator supplies none —
    /// and whose energy ledgers surface fJ/Sample + J/Op into metrics.
    /// Weights are replicated across shards; each shard gets its own
    /// simulated die (a `shard_die_seed` split of `chip.die_seed`).
    pub fn start_cim(cfg: Config) -> Result<Coordinator> {
        let engine_cfg = cfg.clone();
        let make_engine: EngineFactory = Arc::new(move |shard| {
            Ok(Box::new(CimEngine::for_shard(&engine_cfg, shard)) as Box<dyn InferenceEngine>)
        });
        Self::start_with(cfg, make_engine, EpsilonSupply::InWord)
    }

    /// Start with custom ε sources on the default engine (ablations:
    /// Philox mirror, Wallace…).
    pub fn start_with_source(cfg: Config, make_source: SourceFactory) -> Result<Coordinator> {
        #[cfg(feature = "pjrt")]
        return Self::start_with(
            cfg.clone(),
            pjrt_engine_factory(&cfg),
            EpsilonSupply::External(make_source),
        );
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = (cfg, make_source);
            Err(Error::Runtime(
                "built without the `pjrt` feature — use Coordinator::start_with \
                 with an explicit engine factory"
                    .into(),
            ))
        }
    }

    /// Start the full pool: `cfg.server.workers` shard workers, each with
    /// its own engine from the factory and its ε demand met per `supply`
    /// (external per-shard sources, or engine-owned in-word ε).
    pub fn start_with(
        cfg: Config,
        make_engine: EngineFactory,
        supply: EpsilonSupply,
    ) -> Result<Coordinator> {
        cfg.validate()?;
        let shards = cfg.server.workers.max(1);
        let requests: Bounded<InferRequest> = Bounded::new(cfg.server.queue_capacity);
        let shard_queues: Vec<Bounded<Batch>> = (0..shards).map(|_| Bounded::new(2)).collect();
        let metrics = Metrics::new(shards);

        // Spawn the workers; each reports Ok(artifact batch) or Err(msg)
        // once its engine is constructed.
        let (ready_tx, ready_rx) = channel::<std::result::Result<usize, String>>();
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let make_engine = Arc::clone(&make_engine);
            let supply = supply.clone();
            let queue = shard_queues[shard].clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            let ready_tx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("bnn-cim-shard-{shard}"))
                .spawn(move || {
                    // If this worker dies — startup failure or a panic
                    // anywhere in the serving loop — closing its queue
                    // unblocks the dispatcher's round-robin send so
                    // shutdown can never deadlock on a dead shard.
                    struct CloseOnDrop(Bounded<Batch>);
                    impl Drop for CloseOnDrop {
                        fn drop(&mut self) {
                            self.0.close();
                        }
                    }
                    let _close_guard = CloseOnDrop(queue.clone());
                    let engine = match make_engine(shard) {
                        Ok(e) => e,
                        Err(e) => {
                            let _ = ready_tx.send(Err(e.to_string()));
                            return;
                        }
                    };
                    // ε-ownership handshake: in-word engines draw their
                    // own ε (any external supply is simply unused);
                    // external-ε engines must be given a source.
                    let source = match (engine.epsilon_mode(), supply.source_for(shard)) {
                        (EpsilonMode::InWord, _) => None,
                        (EpsilonMode::External, Some(s)) => Some(s),
                        (EpsilonMode::External, None) => {
                            let _ = ready_tx.send(Err(format!(
                                "shard {shard}: engine '{}' consumes external ε \
                                 but the supply is in-word",
                                engine.name()
                            )));
                            return;
                        }
                    };
                    let _ = ready_tx.send(Ok(engine.manifest().batch));
                    run_shard_worker(shard, engine, source, queue, metrics, cfg);
                })
                .map_err(|e| Error::Coordinator(format!("spawn shard {shard}: {e}")))?;
            workers.push(handle);
        }
        drop(ready_tx);

        let mut failure: Option<Error> = None;
        let mut min_art_batch = usize::MAX;
        for _ in 0..shards {
            match ready_rx.recv() {
                Ok(Ok(art_batch)) => min_art_batch = min_art_batch.min(art_batch.max(1)),
                Ok(Err(msg)) => {
                    failure = Some(Error::Coordinator(format!("engine load: {msg}")))
                }
                Err(_) => {
                    failure =
                        Some(Error::Coordinator("shard worker died during startup".into()))
                }
            }
        }
        if let Some(err) = failure {
            requests.close();
            for q in &shard_queues {
                q.close();
            }
            for w in workers {
                let _ = w.join();
            }
            return Err(err);
        }

        // Batches can never exceed what the smallest engine can pack.
        let max_batch = cfg.server.max_batch.min(min_art_batch);
        let deadline = Duration::from_secs_f64(cfg.server.batch_deadline_ms / 1e3);
        let dispatcher = {
            let requests = requests.clone();
            let shard_queues = shard_queues.clone();
            std::thread::Builder::new()
                .name("bnn-cim-dispatcher".into())
                .spawn(move || run_dispatcher(requests, shard_queues, max_batch, deadline))
                .map_err(|e| Error::Coordinator(format!("spawn dispatcher: {e}")))?
        };

        Ok(Coordinator {
            requests,
            shard_queues,
            metrics,
            cfg,
            dispatcher: Some(dispatcher),
            workers,
            next_id: Arc::new(AtomicU64::new(1)),
        })
    }

    /// Submit asynchronously; the returned receiver yields the response.
    pub fn submit(
        &self,
        pixels: Vec<f32>,
        mc_samples: usize,
    ) -> std::result::Result<std::sync::mpsc::Receiver<InferResponse>, RejectReason> {
        let expected = self.cfg.model.image_side * self.cfg.model.image_side;
        if pixels.len() != expected {
            self.metrics.record_reject();
            return Err(RejectReason::WrongShape {
                expected,
                got: pixels.len(),
            });
        }
        // Bound t up front: one greedy request must not inflate the MC
        // pass count for every batch-mate it gets fused with.
        if mc_samples > self.cfg.server.max_mc_samples {
            self.metrics.record_reject();
            return Err(RejectReason::McSamplesTooLarge {
                max: self.cfg.server.max_mc_samples,
                got: mc_samples,
            });
        }
        let (tx, rx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            pixels,
            mc_samples,
            enqueued: Instant::now(),
            reply: tx,
        };
        match self.requests.try_send(req) {
            Ok(()) => Ok(rx),
            Err(_) => {
                self.metrics.record_reject();
                Err(RejectReason::QueueFull)
            }
        }
    }

    /// Blocking convenience wrapper.
    pub fn infer_blocking(
        &self,
        pixels: Vec<f32>,
        mc_samples: usize,
    ) -> std::result::Result<InferResponse, RejectReason> {
        let rx = self.submit(pixels, mc_samples)?;
        let timeout = Duration::from_secs_f64(self.cfg.server.request_timeout_ms / 1e3);
        rx.recv_timeout(timeout).map_err(|_| RejectReason::Timeout)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Number of shard workers in the pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Graceful shutdown: close the request queue, let the dispatcher
    /// flush and close the shard queues, join everything.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.requests.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // The dispatcher closes the shard queues on exit; repeat here so a
        // dispatcher that never started still lets the workers drain.
        for q in &self.shard_queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_engine_factory(cfg: &Config) -> EngineFactory {
    let artifacts = std::path::PathBuf::from(&cfg.model.artifacts_dir);
    Arc::new(move |_shard| {
        let engine = crate::runtime::Engine::load(&artifacts)?;
        Ok(Box::new(engine) as Box<dyn InferenceEngine>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticPerson;

    fn sim_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.model.mc_samples = 4;
        cfg.server.batch_deadline_ms = 5.0;
        cfg
    }

    #[test]
    fn start_backend_dispatches_on_config() {
        let mut cfg = sim_cfg();
        cfg.server.backend = crate::config::Backend::Sim;
        let coord = Coordinator::start_backend(cfg).unwrap();
        let gen = SyntheticPerson::new(32, 3);
        let resp = coord.infer_blocking(gen.sample(0).pixels, 0).unwrap();
        assert_eq!(resp.pred.probs.len(), 2);
        // External-ε backend: no tile energy model, zero request energy.
        assert_eq!(resp.energy_j, 0.0);
        coord.shutdown();
    }

    #[test]
    fn coordinator_serves_on_sim_engine() {
        let cfg = sim_cfg();
        let coord = Coordinator::start_sim(cfg).unwrap();
        let gen = SyntheticPerson::new(32, 77);
        for i in 0..6 {
            let s = gen.sample(i);
            let resp = coord.infer_blocking(s.pixels, 0).unwrap();
            assert_eq!(resp.pred.probs.len(), 2);
            assert!((resp.pred.probs.iter().sum::<f64>() - 1.0).abs() < 1e-5);
        }
        let m = coord.metrics();
        assert_eq!(m.requests_total, 6);
        assert!(m.epsilon_samples > 0);
        assert!(m.pjrt_executions > 0);
        assert_eq!(m.per_shard.len(), 1);
        assert_eq!(m.per_shard[0].requests, 6);
        coord.shutdown();
    }

    #[test]
    fn coordinator_rejects_bad_shapes_and_oversized_mc() {
        let mut cfg = sim_cfg();
        cfg.server.max_mc_samples = 16;
        let coord = Coordinator::start_sim(cfg).unwrap();
        let err = coord.submit(vec![0.0; 7], 0).unwrap_err();
        assert!(matches!(err, RejectReason::WrongShape { .. }));
        let err = coord.submit(vec![0.0; 32 * 32], 17).unwrap_err();
        assert!(matches!(
            err,
            RejectReason::McSamplesTooLarge { max: 16, got: 17 }
        ));
        // At the bound is still accepted.
        let rx = coord.submit(vec![0.0; 32 * 32], 16).unwrap();
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let m = coord.metrics();
        assert_eq!(m.requests_rejected, 2);
        assert_eq!(m.requests_total, 1);
        coord.shutdown();
    }

    #[test]
    fn coordinator_batches_concurrent_requests() {
        let mut cfg = sim_cfg();
        cfg.server.batch_deadline_ms = 30.0;
        let coord = Coordinator::start_sim(cfg).unwrap();
        let gen = SyntheticPerson::new(32, 5);
        let receivers: Vec<_> = (0..8)
            .map(|i| coord.submit(gen.sample(i).pixels, 0).unwrap())
            .collect();
        let responses: Vec<_> = receivers
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap())
            .collect();
        let m = coord.metrics();
        // 8 requests in ≤ a few batches (deadline batching).
        assert!(
            m.batches < 8,
            "batching should fuse requests: {} batches",
            m.batches
        );
        let ids: std::collections::HashSet<u64> =
            responses.iter().map(|r| r.batch_id).collect();
        assert!(ids.len() < 8);
        coord.shutdown();
    }

    #[test]
    fn multi_worker_pool_serves_everything() {
        let mut cfg = sim_cfg();
        cfg.server.workers = 4;
        cfg.server.batch_deadline_ms = 1.0;
        let coord = Coordinator::start_sim(cfg).unwrap();
        assert_eq!(coord.workers(), 4);
        let gen = SyntheticPerson::new(32, 11);
        let receivers: Vec<_> = (0..32)
            .map(|i| coord.submit(gen.sample(i).pixels, 0).unwrap())
            .collect();
        for rx in receivers {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.requests_total, 32);
        assert_eq!(m.per_shard.len(), 4);
        let shard_requests: u64 = m.per_shard.iter().map(|s| s.requests).sum();
        assert_eq!(shard_requests, 32);
        let shard_exec: u64 = m.per_shard.iter().map(|s| s.engine_executions).sum();
        assert_eq!(shard_exec, m.pjrt_executions);
        let shard_eps: u64 = m.per_shard.iter().map(|s| s.epsilon_samples).sum();
        assert_eq!(shard_eps, m.epsilon_samples);
        coord.shutdown();
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn coordinator_end_to_end_on_artifacts() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut cfg = Config::default();
        cfg.model.mc_samples = 8;
        let coord = Coordinator::start(cfg).unwrap();
        let gen = SyntheticPerson::new(32, 77);
        let mut correct = 0;
        let n = 12;
        for i in 0..n {
            let s = gen.sample(i);
            let resp = coord.infer_blocking(s.pixels, 0).unwrap();
            assert_eq!(resp.pred.probs.len(), 2);
            assert!((resp.pred.probs.iter().sum::<f64>() - 1.0).abs() < 1e-6);
            if resp.pred.class == s.label {
                correct += 1;
            }
        }
        // The trained model should beat chance comfortably.
        assert!(
            correct >= (n * 6 / 10) as i32,
            "accuracy too low: {correct}/{n}"
        );
        let m = coord.metrics();
        assert_eq!(m.requests_total, n as u64);
        assert!(m.epsilon_samples > 0);
        coord.shutdown();
    }
}
