//! Shard supervision: health tracking, worker respawn, and in-flight
//! batch recovery (DESIGN.md §9).
//!
//! Every shard worker carries two drop guards. The inner one closes the
//! shard's batch queue (so the dispatcher can never block on a dead
//! shard); the outer one notifies this module's supervisor thread. On a
//! worker death the supervisor: reaps the thread, recovers the in-flight
//! batch (parked in the shard's `InFlight` slot) plus anything still
//! queued behind the closed queue, respawns the worker **with its
//! original shard index** — the engine factory and ε supply re-derive
//! the original deterministic `shard_die_seed` split, so a restarted
//! shard serves bit-identically to a fresh boot — and redelivers the
//! recovered requests through the admission queue under the per-request
//! retry budget. Inference is pure, so redelivery is safe; when the
//! budget (or the request's original deadline) is exhausted the client
//! receives a typed [`ServeError::ShardFailed`] / `Timeout` reply
//! instead of a dropped channel.
//!
//! State machine per shard: `healthy → restarting/n → healthy` on each
//! recovered crash, `→ dead` once `server.shard_restart_limit` is
//! exceeded or a respawn itself fails. `dead` is terminal for the pool's
//! lifetime; the dispatcher routes around non-healthy shards and fails
//! batches typed-and-fast only when *every* shard is dead.

use crate::client::ServeError;
use crate::config::Config;
use crate::coordinator::batch::Batch;
use crate::coordinator::dispatch::run_shard_worker;
use crate::coordinator::elastic::ElasticCtx;
use crate::coordinator::epsilon::EpsilonSupply;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferRequest, Reply};
use crate::error::{Error, Result};
use crate::runtime::EpsilonMode;
use crate::util::threadpool::Bounded;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Liveness of one shard, as reported by `/v1/health` and
/// [`Coordinator::shard_health`](crate::coordinator::Coordinator::shard_health).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving normally.
    Healthy,
    /// The worker died and respawn `n` is in flight.
    Restarting(u64),
    /// Past `server.shard_restart_limit` (or a respawn failed): the
    /// supervisor has given up on this shard for the pool's lifetime.
    Dead,
}

impl ShardHealth {
    /// Wire label: `healthy`, `restarting/n`, `dead`.
    pub fn label(&self) -> String {
        match self {
            ShardHealth::Healthy => "healthy".into(),
            ShardHealth::Restarting(n) => format!("restarting/{n}"),
            ShardHealth::Dead => "dead".into(),
        }
    }
}

struct ShardEntry {
    queue: Bounded<Batch>,
    health: ShardHealth,
    restarts: u64,
}

/// Shared registry of per-shard queues and health, read by the
/// dispatcher (routing), the supervisor (restart bookkeeping), and the
/// coordinator handle (health surface). Queues are swapped on respawn —
/// a closed `Bounded` cannot reopen — so everything routes through this
/// table instead of holding queue clones.
pub(crate) struct ShardTable {
    entries: Mutex<Vec<ShardEntry>>,
}

impl ShardTable {
    pub fn new(queues: Vec<Bounded<Batch>>) -> Self {
        Self {
            entries: Mutex::new(
                queues
                    .into_iter()
                    .map(|queue| ShardEntry {
                        queue,
                        health: ShardHealth::Healthy,
                        restarts: 0,
                    })
                    .collect(),
            ),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Vec<ShardEntry>> {
        self.entries.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn shards(&self) -> usize {
        self.lock().len()
    }

    pub fn queue(&self, shard: usize) -> Bounded<Batch> {
        self.lock()[shard].queue.clone()
    }

    pub fn swap_queue(&self, shard: usize, queue: Bounded<Batch>) {
        self.lock()[shard].queue = queue;
    }

    pub fn mark(&self, shard: usize, health: ShardHealth) {
        self.lock()[shard].health = health;
    }

    /// Bump the restart counter and enter `Restarting(n)`; returns `n`.
    pub fn begin_restart(&self, shard: usize) -> u64 {
        let mut entries = self.lock();
        entries[shard].restarts += 1;
        let n = entries[shard].restarts;
        entries[shard].health = ShardHealth::Restarting(n);
        n
    }

    pub fn restarts(&self, shard: usize) -> u64 {
        self.lock()[shard].restarts
    }

    pub fn health(&self) -> Vec<ShardHealth> {
        self.lock().iter().map(|e| e.health.clone()).collect()
    }

    pub fn healthy_count(&self) -> usize {
        self.lock()
            .iter()
            .filter(|e| e.health == ShardHealth::Healthy)
            .count()
    }

    pub fn all_dead(&self) -> bool {
        self.lock().iter().all(|e| e.health == ShardHealth::Dead)
    }

    pub fn close_all(&self) {
        for entry in self.lock().iter() {
            entry.queue.close();
        }
    }

    /// Work stealing (elastic mode): an idle worker takes one queued
    /// batch from the first backed-up healthy peer, scanning round-robin
    /// from its own index. The drain is atomic under the table lock, so
    /// a batch is served exactly once — by whichever worker got it.
    pub fn try_steal(&self, thief: usize) -> Option<Batch> {
        let entries = self.lock();
        let n = entries.len();
        for k in 1..n {
            let victim = (thief + k) % n;
            if entries[victim].health != ShardHealth::Healthy {
                continue;
            }
            if let Some(batch) = entries[victim].queue.drain_up_to(1).pop() {
                return Some(batch);
            }
        }
        None
    }
}

/// The shard's in-flight slot: the worker parks each batch here while
/// serving it and clears the slot once every reply is sent, so a panic
/// mid-batch leaves the batch recoverable by the supervisor. The lock is
/// uncontended while the worker lives (the supervisor only touches it
/// after the death notification) and poison-tolerant after a panic.
#[derive(Clone, Default)]
pub(crate) struct InFlight(Arc<Mutex<Option<Batch>>>);

impl InFlight {
    pub fn lock(&self) -> MutexGuard<'_, Option<Batch>> {
        self.0.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn take(&self) -> Option<Batch> {
        self.lock().take()
    }
}

/// Everything needed to (re)spawn a shard worker and to recover its
/// work: kept by the supervisor for the pool's lifetime so a respawned
/// shard is built from the *same* factory/supply/config as at boot.
#[derive(Clone)]
pub(crate) struct WorkerCtx {
    pub supply: EpsilonSupply,
    pub metrics: Metrics,
    pub cfg: Config,
    /// The admission queue: recovered requests are redelivered through
    /// the front door so normal routing applies to retries.
    pub requests: Bounded<InferRequest>,
    /// Hot-swap slot + per-shard replica targets. The engine factory
    /// lives in `elastic.swap`, so a worker (re)spawn always builds from
    /// the most recently published model.
    pub elastic: ElasticCtx,
    /// The shard registry, for idle-time work stealing (elastic mode).
    pub table: Arc<ShardTable>,
}

/// Wire format between worker drop guards / `Coordinator::stop` and the
/// supervisor loop.
pub(crate) enum SupervisorMsg {
    /// A worker thread exited (panic or drain) — sent by its drop guard
    /// *after* its queue closed, so the queue's stranded contents are
    /// stable.
    WorkerExit(usize),
    /// The pool is stopping: close every queue, join every worker, exit.
    Shutdown,
}

/// Spawn one shard worker thread. The worker reports
/// `Ok(manifest batch)` or `Err(reason)` on `ready_tx` once its engine
/// is constructed, then serves until its queue closes or it dies.
pub(crate) fn spawn_shard_worker(
    shard: usize,
    ctx: &WorkerCtx,
    queue: Bounded<Batch>,
    slot: InFlight,
    exit_tx: Sender<SupervisorMsg>,
    ready_tx: Sender<std::result::Result<usize, String>>,
) -> Result<JoinHandle<()>> {
    let ctx = ctx.clone();
    std::thread::Builder::new()
        .name(format!("bnn-cim-shard-{shard}"))
        .spawn(move || {
            // Declared before the close guard so it drops *after* it
            // (reverse drop order): by the time the supervisor hears of
            // this death the queue is closed and no new batch can land
            // in it — draining the stranded contents is race-free.
            struct ExitNotify(Sender<SupervisorMsg>, usize);
            impl Drop for ExitNotify {
                fn drop(&mut self) {
                    let _ = self.0.send(SupervisorMsg::WorkerExit(self.1));
                }
            }
            let _exit_guard = ExitNotify(exit_tx, shard);
            // If this worker dies — startup failure or a panic anywhere
            // in the serving loop — closing its queue unblocks the
            // dispatcher's send so routing (and shutdown) can never
            // deadlock on a dead shard.
            struct CloseOnDrop(Bounded<Batch>);
            impl Drop for CloseOnDrop {
                fn drop(&mut self) {
                    self.0.close();
                }
            }
            let _close_guard = CloseOnDrop(queue.clone());
            // Build from the swap slot's current factory: at boot this is
            // the factory the pool started with; after a swap_model, a
            // respawned shard comes back on the published model.
            let (engine_gen, factory) = ctx.elastic.swap.current();
            let engine = match factory(shard) {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            // ε-ownership handshake: in-word engines draw their own ε
            // (any external supply is simply unused); external-ε engines
            // must be given a source.
            let source = match (engine.epsilon_mode(), ctx.supply.source_for(shard)) {
                (EpsilonMode::InWord, _) => None,
                (EpsilonMode::External, Some(s)) => Some(s),
                (EpsilonMode::External, None) => {
                    let _ = ready_tx.send(Err(format!(
                        "shard {shard}: engine '{}' consumes {} ε \
                         but the supply is {}",
                        engine.name(),
                        EpsilonMode::External.name(),
                        EpsilonMode::InWord.name(),
                    )));
                    return;
                }
            };
            // Initial capacity gauges, so metrics report the replica
            // pool and its shared/private footprint before any traffic.
            ctx.metrics.record_replicas(
                shard,
                engine.replica_count(),
                engine.bytes_shared(),
                engine.bytes_private(),
            );
            let _ = ready_tx.send(Ok(engine.manifest().batch));
            run_shard_worker(shard, engine, engine_gen, source, queue, slot, ctx);
        })
        .map_err(|e| Error::Coordinator(format!("spawn shard {shard}: {e}")))
}

/// Redeliver a recovered batch's requests, one by one, under the retry
/// budget and each request's original deadline. Shared by the supervisor
/// (worker death) and the worker itself (transient engine errors).
pub(crate) fn recover_batch(batch: Batch, failed_shard: usize, ctx: &WorkerCtx) {
    let budget = ctx.cfg.server.retry_budget;
    for mut req in batch.requests {
        req.retries += 1;
        if req.retries > budget {
            ctx.metrics.record_failed_shard(failed_shard);
            let _ = req
                .reply
                .send(Reply::Failed(ServeError::ShardFailed { shard: failed_shard }));
            continue;
        }
        if crate::util::clock::now() >= req.deadline {
            // Budget remains but time does not: the deadline fixed at
            // admission caps the retry, so recovery never stretches the
            // caller's end-to-end bound.
            ctx.metrics.record_failed_shard(failed_shard);
            let _ = req.reply.send(Reply::Failed(ServeError::Timeout));
            continue;
        }
        match ctx.requests.try_send(req) {
            Ok(()) => ctx.metrics.record_retried(failed_shard),
            Err(req) => {
                // Admission full or closed — there is nowhere to retry.
                ctx.metrics.record_failed_shard(failed_shard);
                let _ = req
                    .reply
                    .send(Reply::Failed(ServeError::ShardFailed { shard: failed_shard }));
            }
        }
    }
}

/// The supervisor loop (thread `bnn-cim-supervisor`): turns worker-death
/// notifications into recovery + respawn, and owns the worker
/// `JoinHandle`s so shutdown joins respawned threads too.
pub(crate) fn run_supervisor(
    rx: Receiver<SupervisorMsg>,
    exit_tx: Sender<SupervisorMsg>,
    table: Arc<ShardTable>,
    slots: Vec<InFlight>,
    handles: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    ctx: WorkerCtx,
    shutting_down: Arc<AtomicBool>,
) {
    let lock_handles = |h: &Arc<Mutex<Vec<Option<JoinHandle<()>>>>>| {
        h.lock().unwrap_or_else(|p| p.into_inner())
    };
    while let Ok(msg) = rx.recv() {
        let shard = match msg {
            SupervisorMsg::Shutdown => break,
            SupervisorMsg::WorkerExit(shard) => shard,
        };
        if shutting_down.load(Ordering::SeqCst) {
            // Normal drain during stop(); everything is joined below.
            continue;
        }
        // Reap the dead thread (its exit guard already ran, so this
        // join returns promptly).
        if let Some(handle) = lock_handles(&handles)[shard].take() {
            let _ = handle.join();
        }
        // Recover the in-flight batch plus anything stranded behind the
        // now-closed queue. Collected before the queue is swapped.
        let mut stranded: Vec<Batch> = slots[shard].take().into_iter().collect();
        stranded.extend(table.queue(shard).drain_up_to(usize::MAX));

        if table.restarts(shard) >= ctx.cfg.server.shard_restart_limit as u64 {
            eprintln!(
                "[bnn-cim supervisor] shard {shard} exceeded shard_restart_limit ({}) — dead",
                ctx.cfg.server.shard_restart_limit
            );
            table.mark(shard, ShardHealth::Dead);
        } else {
            let attempt = table.begin_restart(shard);
            // Respawn with the original shard index: the factory and ε
            // supply re-derive the original deterministic seeds.
            let queue = Bounded::new(2);
            let (ready_tx, ready_rx) = channel::<std::result::Result<usize, String>>();
            let spawned = spawn_shard_worker(
                shard,
                &ctx,
                queue.clone(),
                slots[shard].clone(),
                exit_tx.clone(),
                ready_tx,
            );
            match spawned {
                Ok(handle) => {
                    let ready = ready_rx.recv();
                    if matches!(&ready, Ok(Ok(_))) {
                        table.swap_queue(shard, queue);
                        table.mark(shard, ShardHealth::Healthy);
                        ctx.metrics.record_shard_restart(shard);
                        eprintln!(
                            "[bnn-cim supervisor] shard {shard} restarted \
                             (attempt {attempt}, original seed split)"
                        );
                        lock_handles(&handles)[shard] = Some(handle);
                    } else {
                        let why = match ready {
                            Ok(Err(msg)) => msg,
                            _ => "worker died before reporting ready".into(),
                        };
                        eprintln!(
                            "[bnn-cim supervisor] shard {shard} respawn failed: {why} — dead"
                        );
                        let _ = handle.join();
                        table.mark(shard, ShardHealth::Dead);
                    }
                }
                Err(e) => {
                    eprintln!("[bnn-cim supervisor] shard {shard} respawn failed: {e} — dead");
                    table.mark(shard, ShardHealth::Dead);
                }
            }
        }
        // Redeliver after the respawn so even a one-shard pool has a
        // healthy destination for the recovered work.
        for batch in stranded {
            recover_batch(batch, shard, &ctx);
        }
    }
    // Shutdown: close every (possibly swapped-in) queue so workers
    // drain, then join the whole pool — including respawned threads the
    // coordinator handle never saw.
    table.close_all();
    for slot in lock_handles(&handles).iter_mut() {
        if let Some(handle) = slot.take() {
            let _ = handle.join();
        }
    }
}
