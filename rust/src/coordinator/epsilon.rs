//! ε sourcing for the Monte-Carlo scheduler.
//!
//! Two ε-ownership modes exist (`runtime::EpsilonMode`), captured here as
//! [`EpsilonSupply`]:
//!
//! - **External** — the engine's head takes ε as an *input* (AOT
//!   artifacts, `SimEngine`): the coordinator owns ε and supplies it from
//!   a per-shard [`EpsilonSource`], normally the simulated in-word GRNG
//!   bank — the chip's dataflow re-created at the coordinator layer.
//! - **InWord** — the engine *is* the chip model (`CimEngine`): ε
//!   materializes inside the engine's own tile arrays, so the coordinator
//!   supplies nothing and reads ε/energy counters back from the engine.
//!
//! Sources (External mode):
//! - [`GrngBankSource`] — the paper's hardware: one simulated GRNG cell
//!   per (row, word); successive fills are successive whole-bank
//!   conversions through the SoA block sampler (`GrngBank::fill_epsilon`).
//!   Includes per-die mismatch (calibrated upstream) and outliers.
//! - [`PhiloxSource`] — bit-exact mirror of the L1 Pallas kernel's
//!   in-kernel sampler (key/counter), for cross-layer reproducibility.
//! - [`BaselineSource`] — wraps any `grng::baselines::GaussianSource`
//!   for ablation serving (e.g. Wallace-fed BNN).

use crate::config::ChipConfig;
use crate::coordinator::server::SourceFactory;
use crate::grng::baselines::GaussianSource;
use crate::grng::GrngBank;
use crate::util::rng::Philox4x32;
use std::sync::Arc;

// Per-shard seed derivation lives next to the bank it shards.
pub use crate::grng::bank::{shard_chip, shard_die_seed};
pub use crate::runtime::EpsilonMode;

/// How a shard worker's ε demand is met (the coordinator-side half of
/// [`EpsilonMode`]). Replaces the hardwired per-shard GRNG-bank supply:
/// external-ε backends get a source per shard, in-word backends get none.
#[derive(Clone)]
pub enum EpsilonSupply {
    /// Coordinator-owned ε: `factory(shard)` builds the shard's source
    /// inside its worker thread.
    External(SourceFactory),
    /// Engine-owned ε: the in-word GRNG lives inside the engine's memory
    /// arrays; no coordinator source exists.
    InWord,
}

impl EpsilonSupply {
    /// The default external supply: one simulated in-word GRNG bank per
    /// shard, seeded from a SplitMix64 split of `die_seed`.
    pub fn grng_banks(chip: &ChipConfig) -> Self {
        EpsilonSupply::External(GrngBankSource::shard_factory(chip))
    }

    /// The source for one shard (`None` for engine-owned ε).
    pub(crate) fn source_for(&self, shard: usize) -> Option<Box<dyn EpsilonSource>> {
        match self {
            EpsilonSupply::External(factory) => Some(factory(shard)),
            EpsilonSupply::InWord => None,
        }
    }
}

/// Anything that can fill ε buffers, one MC pass at a time.
pub trait EpsilonSource: Send {
    /// Fill `out` with fresh N(0,1) samples.
    fn fill(&mut self, out: &mut [f32]);

    /// Total samples drawn so far.
    fn samples_drawn(&self) -> u64;

    /// Energy cost so far \[J\] (per the source's hardware model).
    fn energy_j(&self) -> f64;

    fn name(&self) -> &'static str;
}

/// The in-word GRNG bank as an ε source. The bank has rows×words cells;
/// larger demands are met by repeated conversions (the chip refreshes all
/// 512 cells per conversion cycle).
///
/// The per-cell static offsets ε₀ (Eq. 8) are corrected exactly as the
/// chip does after its one-time calibration (Eq. 9–10): the measured
/// offset of each cell is subtracted downstream. Here the correction
/// registers are initialized from a calibration-style estimate (mean of
/// `cal_n` conversions per cell), not the ground truth.
pub struct GrngBankSource {
    bank: GrngBank,
    offset_cal: Vec<f64>,
    scratch: Vec<f64>,
    cursor: usize,
    drawn: u64,
}

impl GrngBankSource {
    pub fn new(chip: &ChipConfig) -> Self {
        Self::with_calibration(chip, 64)
    }

    /// `cal_n` = conversions averaged per cell for the ε₀ estimate
    /// (0 = uncalibrated: the ablation arm).
    pub fn with_calibration(chip: &ChipConfig, cal_n: usize) -> Self {
        let mut bank = GrngBank::for_chip(chip);
        let n = bank.len();
        let mut offset_cal = vec![0.0f64; n];
        if cal_n > 0 {
            let mut buf = vec![0.0f64; n];
            for _ in 0..cal_n {
                bank.fill_epsilon(&mut buf);
                for (o, v) in offset_cal.iter_mut().zip(buf.iter()) {
                    *o += v;
                }
            }
            for o in offset_cal.iter_mut() {
                *o /= cal_n as f64;
            }
        }
        Self {
            bank,
            offset_cal,
            scratch: vec![0.0; n],
            cursor: n, // force a conversion on first use
            drawn: 0,
        }
    }

    /// RMS of the correction registers (diagnostics).
    pub fn offset_rms(&self) -> f64 {
        (self.offset_cal.iter().map(|x| x * x).sum::<f64>() / self.offset_cal.len() as f64)
            .sqrt()
    }

    /// The bank for shard `shard`: an independent simulated die whose
    /// seed is a [`shard_die_seed`] split of `chip.die_seed`.
    pub fn for_shard(chip: &ChipConfig, shard: usize) -> Self {
        Self::new(&shard_chip(chip, shard))
    }

    /// Factory handing each shard worker its own bank (the coordinator's
    /// default ε sourcing).
    pub fn shard_factory(chip: &ChipConfig) -> SourceFactory {
        let chip = chip.clone();
        Arc::new(move |shard| {
            Box::new(GrngBankSource::for_shard(&chip, shard)) as Box<dyn EpsilonSource>
        })
    }
}

impl EpsilonSource for GrngBankSource {
    fn fill(&mut self, out: &mut [f32]) {
        if out.is_empty() {
            return;
        }
        assert!(!self.scratch.is_empty(), "empty GRNG bank cannot source ε");
        // Whole-conversion block fills, then contiguous chunk copies out
        // of the scratch (same values and order as a per-slot walk).
        let mut filled = 0;
        while filled < out.len() {
            if self.cursor >= self.scratch.len() {
                self.bank.fill_epsilon(&mut self.scratch);
                for (v, o) in self.scratch.iter_mut().zip(self.offset_cal.iter()) {
                    *v -= o;
                }
                self.cursor = 0;
            }
            let take = (out.len() - filled).min(self.scratch.len() - self.cursor);
            for (dst, src) in out[filled..filled + take]
                .iter_mut()
                .zip(self.scratch[self.cursor..self.cursor + take].iter())
            {
                *dst = *src as f32;
            }
            self.cursor += take;
            filled += take;
        }
        self.drawn += out.len() as u64;
    }

    fn samples_drawn(&self) -> u64 {
        self.drawn
    }

    fn energy_j(&self) -> f64 {
        // Energy is per *conversion* of the whole bank.
        self.bank.samples_drawn() as f64 * self.bank.mean_energy_per_sample()
    }

    fn name(&self) -> &'static str {
        "in-word-grng"
    }
}

/// Counter-based source mirroring the L1 kernel (Philox4x32-10 bits →
/// Box–Muller with the same 24-bit mapping).
pub struct PhiloxSource {
    key: u64,
    counter: u128,
    drawn: u64,
}

impl PhiloxSource {
    pub fn new(key: u64) -> Self {
        Self {
            key,
            counter: 0,
            drawn: 0,
        }
    }

    /// Factory giving each shard an independent key split of `key`
    /// (shard 0 keeps `key` itself, mirroring [`shard_die_seed`]).
    pub fn shard_factory(key: u64) -> SourceFactory {
        Arc::new(move |shard| {
            Box::new(PhiloxSource::new(shard_die_seed(key, shard))) as Box<dyn EpsilonSource>
        })
    }
}

impl EpsilonSource for PhiloxSource {
    fn fill(&mut self, out: &mut [f32]) {
        for slot in out.iter_mut() {
            let gen = Philox4x32::at(self.key, self.counter);
            let block = gen.block();
            self.counter += 1;
            // Same mapping as python/compile/kernels/grng.py
            let u1 = ((block[0] >> 8) as f32 + 1.0) / 16_777_216.0;
            let u2 = (block[1] >> 8) as f32 / 16_777_216.0;
            let r = (-2.0 * u1.ln()).sqrt();
            *slot = r * (2.0 * std::f32::consts::PI * u2).cos();
        }
        self.drawn += out.len() as u64;
    }

    fn samples_drawn(&self) -> u64 {
        self.drawn
    }

    fn energy_j(&self) -> f64 {
        0.0 // software source: no hardware energy model
    }

    fn name(&self) -> &'static str {
        "philox-kernel-mirror"
    }
}

/// Any comparison GRNG as an ε source (Tab. II ablations).
pub struct BaselineSource {
    inner: Box<dyn GaussianSource + Send>,
    drawn: u64,
    name: &'static str,
}

impl BaselineSource {
    pub fn new(inner: Box<dyn GaussianSource + Send>) -> Self {
        // `name()` returns &'static str on the trait already.
        let name = {
            // Safety-free: just copy the static name out before boxing.
            let n = inner.name();
            n
        };
        Self {
            inner,
            drawn: 0,
            name,
        }
    }
}

impl EpsilonSource for BaselineSource {
    fn fill(&mut self, out: &mut [f32]) {
        for slot in out.iter_mut() {
            *slot = self.inner.sample() as f32;
        }
        self.drawn += out.len() as u64;
    }

    fn samples_drawn(&self) -> u64 {
        self.drawn
    }

    fn energy_j(&self) -> f64 {
        let pj = self
            .inner
            .cost()
            .published_pj_per_sa
            .unwrap_or(0.0);
        self.drawn as f64 * pj * 1e-12
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn bank_source_statistics() {
        let chip = ChipConfig::default();
        let mut src = GrngBankSource::new(&chip);
        let mut buf = vec![0.0f32; 4096];
        src.fill(&mut buf);
        let xs: Vec<f64> = buf.iter().map(|&v| v as f64).collect();
        let s = Summary::from_slice(&xs);
        assert!(s.mean().abs() < 0.2, "mean {}", s.mean());
        assert!((s.std() - 1.0).abs() < 0.25, "std {}", s.std());
        assert_eq!(src.samples_drawn(), 4096);
        assert!(src.energy_j() > 0.0);
    }

    #[test]
    fn philox_source_matches_kernel_mapping() {
        // First sample from key=(7 | 9<<32), counter=0 must match the
        // python kernel's eps[0,0] (pinned in python tests): 0.52273285.
        let mut src = PhiloxSource::new((9u64 << 32) | 7);
        let mut buf = vec![0.0f32; 1];
        src.fill(&mut buf);
        assert!(
            (buf[0] - 0.522_732_85).abs() < 1e-5,
            "cross-language ε mismatch: {}",
            buf[0]
        );
    }

    #[test]
    fn philox_source_deterministic() {
        let mut a = PhiloxSource::new(42);
        let mut b = PhiloxSource::new(42);
        let mut ba = vec![0.0f32; 64];
        let mut bb = vec![0.0f32; 64];
        a.fill(&mut ba);
        b.fill(&mut bb);
        assert_eq!(ba, bb);
    }

    #[test]
    fn shard_seeds_stable_and_distinct() {
        assert_eq!(shard_die_seed(42, 0), 42, "shard 0 must keep the die seed");
        let seeds: Vec<u64> = (0..8).map(|s| shard_die_seed(42, s)).collect();
        let again: Vec<u64> = (0..8).map(|s| shard_die_seed(42, s)).collect();
        assert_eq!(seeds, again, "derivation must be deterministic");
        for i in 0..seeds.len() {
            for j in (i + 1)..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "shards {i}/{j} collided");
            }
        }
    }

    #[test]
    fn shard_banks_draw_distinct_streams() {
        let chip = ChipConfig::default();
        let mut streams = Vec::new();
        for shard in 0..4 {
            let mut src = GrngBankSource::for_shard(&chip, shard);
            let mut buf = vec![0.0f32; 128];
            src.fill(&mut buf);
            streams.push(buf);
        }
        for i in 0..streams.len() {
            for j in (i + 1)..streams.len() {
                assert_ne!(streams[i], streams[j], "shards {i}/{j} correlated");
            }
        }
        // Shard 0 is bit-identical to the unsharded source.
        let mut base = GrngBankSource::new(&chip);
        let mut buf = vec![0.0f32; 128];
        base.fill(&mut buf);
        assert_eq!(buf, streams[0]);
    }

    #[test]
    fn baseline_source_wraps() {
        let mut src = BaselineSource::new(Box::new(
            crate::grng::baselines::wallace::Wallace::new(3),
        ));
        let mut buf = vec![0.0f32; 1000];
        src.fill(&mut buf);
        assert_eq!(src.samples_drawn(), 1000);
        assert!(src.energy_j() > 0.0);
        assert_eq!(src.name(), "wallace [11]");
    }
}
