//! Elastic-capacity shared state (DESIGN.md §10).
//!
//! Two control surfaces live here, both written by control-plane actors
//! and *applied* by shard workers at batch boundaries (engines are not
//! `Send`, so only the owning worker thread may touch one):
//!
//! - [`SwapState`] — the publish-drain-flip slot for online model
//!   hot-swap. `Coordinator::swap_model` publishes a new engine factory
//!   and bumps the generation; each worker notices the bump between
//!   batches, builds the new engine *in its own thread*, and flips. A
//!   batch is always served end-to-end by one engine instance, so no
//!   caller ever observes a torn model.
//! - [`ElasticCtx::targets`] — per-shard MC-replica targets. The
//!   dispatcher raises them under queue pressure; idle workers decay
//!   them toward `server.min_mc_workers`. Workers apply the target with
//!   `InferenceEngine::set_replicas`, which is O(ε buffers) because the
//!   replica clone shares the calibrated weight/calibration layer behind
//!   `Arc`s (copy-on-calibrate — see `cim::tile`).
//!
//! Determinism: with `server.elastic = false` (the default) none of this
//! machinery runs on the serve path and replay stays bit-identical for a
//! fixed `(die_seed, workers, mc_workers)`. With elasticity on, every
//! replica stream is still a fixed function of its index (regrowth
//! replays the boot-time seed split), but slot→replica assignment and
//! batch→shard routing follow load — the contract is banded (same result
//! *distribution*), not bitwise.

use crate::coordinator::server::EngineFactory;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Idle-poll period of an elastic shard worker: how often an idle worker
/// wakes to steal work or decay its replica pool.
pub(crate) const IDLE_TICK: Duration = Duration::from_millis(5);

/// Consecutive empty idle ticks before a worker lowers its replica
/// target one step toward `server.min_mc_workers` (~25 ms of idleness
/// per step at [`IDLE_TICK`]).
pub(crate) const IDLE_TICKS_PER_DECAY: u32 = 5;

/// Admission-queue depth at which the dispatcher raises every shard's
/// replica target one step toward `server.max_mc_workers`: more requests
/// waiting than the batch being routed means the pool is behind.
pub(crate) const SCALE_UP_DEPTH: usize = 2;

/// The model hot-swap slot: a generation counter plus the engine factory
/// the generation refers to. Workers poll [`SwapState::generation`]
/// (one atomic load) once per batch and only take the lock on a change.
pub(crate) struct SwapState {
    /// Mirror of the generation inside `inner`, readable without the
    /// lock for the per-batch fast path.
    gen: AtomicU64,
    inner: Mutex<(u64, EngineFactory)>,
}

impl SwapState {
    pub fn new(factory: EngineFactory) -> Self {
        Self {
            gen: AtomicU64::new(1),
            inner: Mutex::new((1, factory)),
        }
    }

    fn lock(&self) -> MutexGuard<'_, (u64, EngineFactory)> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn generation(&self) -> u64 {
        self.gen.load(Ordering::Acquire)
    }

    /// The current `(generation, factory)` pair, read atomically under
    /// the lock (so a worker never pairs a new factory with an old
    /// generation or vice versa).
    pub fn current(&self) -> (u64, EngineFactory) {
        let g = self.lock();
        (g.0, Arc::clone(&g.1))
    }

    /// Publish a new factory and return the new generation. Workers flip
    /// at their next batch boundary; supervisor respawns also build from
    /// the published factory, so a shard restarted after a swap comes
    /// back on the new model.
    pub fn publish(&self, factory: EngineFactory) -> u64 {
        let mut g = self.lock();
        g.0 += 1;
        g.1 = factory;
        self.gen.store(g.0, Ordering::Release);
        g.0
    }
}

/// Shared elastic-control state, cloned into the dispatcher and every
/// shard worker context.
#[derive(Clone)]
pub(crate) struct ElasticCtx {
    /// `server.elastic`: gates autoscaling, idle decay, and stealing.
    /// Model hot-swap works in both modes.
    pub enabled: bool,
    pub swap: Arc<SwapState>,
    /// Per-shard MC-replica targets (indexed by shard).
    pub targets: Arc<Vec<AtomicUsize>>,
}

impl ElasticCtx {
    pub fn new(enabled: bool, shards: usize, initial_target: usize, factory: EngineFactory) -> Self {
        Self {
            enabled,
            swap: Arc::new(SwapState::new(factory)),
            targets: Arc::new((0..shards).map(|_| AtomicUsize::new(initial_target)).collect()),
        }
    }

    pub fn target(&self, shard: usize) -> usize {
        // RELAXED: targets are pure hints — the owning worker re-reads
        // at every batch boundary, so a stale value only delays a
        // resize by one batch; no other memory is published through it.
        self.targets[shard].load(Ordering::Relaxed)
    }

    /// Force a shard's target to `n` (operator override / tests); the
    /// owning worker applies it at its next batch boundary or idle tick.
    pub fn set_target(&self, shard: usize, n: usize) {
        // RELAXED: hint store, same contract as `target` — the counter
        // itself is the entire message.
        self.targets[shard].store(n.max(1), Ordering::Relaxed);
    }

    /// Raise the target one step toward `max`; true if it moved.
    pub fn raise_target(&self, shard: usize, max: usize) -> bool {
        self.targets[shard]
            // RELAXED: the RMW itself is atomic (no lost steps); no
            // acquire/release needed because nothing else piggybacks on
            // the target cell.
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |t| {
                (t < max).then_some(t + 1)
            })
            .is_ok()
    }

    /// Lower the target one step toward `min`; true if it moved.
    pub fn lower_target(&self, shard: usize, min: usize) -> bool {
        self.targets[shard]
            // RELAXED: same hint contract as `raise_target`.
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |t| {
                (t > min).then_some(t - 1)
            })
            .is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{InferenceEngine, SimEngine};

    fn noop_factory() -> EngineFactory {
        Arc::new(|_shard| {
            Ok(Box::new(SimEngine::new(1, 4, 2, 2, 7)) as Box<dyn InferenceEngine>)
        })
    }

    #[test]
    fn swap_publish_bumps_generation_and_swaps_factory() {
        let swap = SwapState::new(noop_factory());
        assert_eq!(swap.generation(), 1);
        let (g, f) = swap.current();
        assert_eq!(g, 1);
        assert!(f(0).is_ok());
        let g2 = swap.publish(noop_factory());
        assert_eq!(g2, 2);
        assert_eq!(swap.generation(), 2);
        let (g, _) = swap.current();
        assert_eq!(g, 2);
    }

    #[test]
    fn targets_move_stepwise_within_bounds() {
        let ctx = ElasticCtx::new(true, 2, 4, noop_factory());
        assert_eq!(ctx.target(0), 4);
        assert!(ctx.raise_target(0, 8));
        assert_eq!(ctx.target(0), 5);
        // Clamped at the ceiling.
        ctx.set_target(0, 8);
        assert!(!ctx.raise_target(0, 8));
        // Decay steps down to the floor and stops.
        assert!(ctx.lower_target(0, 1));
        assert_eq!(ctx.target(0), 7);
        ctx.set_target(0, 1);
        assert!(!ctx.lower_target(0, 1));
        // Shard 1 untouched throughout.
        assert_eq!(ctx.target(1), 4);
        // set_target clamps to >= 1.
        ctx.set_target(1, 0);
        assert_eq!(ctx.target(1), 1);
    }
}
