//! L3 coordinator: the serving engine around the runtime — a front-end
//! dispatcher (request router/batcher) feeding a pool of shard workers,
//! each owning its own engine and its own per-shard in-word GRNG bank;
//! Monte-Carlo sample scheduling, deferral policy, and per-shard metrics.
//!
//! Client code should use [`crate::client`] (API v1: builder, typed
//! tickets, `ServeError`) rather than these internals directly.
//!
//! Module layout:
//! - [`batch`] — pure batch-assembly / slot-packing cores (no I/O).
//! - `dispatch` — the dispatcher and shard-worker loops (private).
//! - `elastic` — hot-swap slot + per-shard replica targets (private;
//!   DESIGN.md §10).
//! - [`server`] — the [`Coordinator`] handle (boot/admission/shutdown).
//! - [`supervisor`] — shard health, worker respawn, batch recovery
//!   (DESIGN.md §9; the public face is [`ShardHealth`]).
//! - [`epsilon`] — ε sources, including per-shard seed derivation.
//! - [`metrics`] — global + per-shard counters.

pub mod batch;
mod dispatch;
mod elastic;
pub mod epsilon;
pub mod metrics;
pub mod request;
pub mod server;
pub mod supervisor;

pub use batch::Batch;
pub use epsilon::{
    shard_die_seed, BaselineSource, EpsilonMode, EpsilonSource, EpsilonSupply, GrngBankSource,
    PhiloxSource,
};
pub use metrics::{Metrics, MetricsSnapshot, ShardSnapshot};
pub use request::{InferRequest, InferResponse, RejectReason, Reply};
pub use server::{Coordinator, EngineFactory, SourceFactory};
pub use supervisor::ShardHealth;
