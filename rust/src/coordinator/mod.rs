//! L3 coordinator: the serving engine around the PJRT runtime — request
//! router/batcher, Monte-Carlo sample scheduler, ε sourcing from the
//! in-word GRNG bank, deferral policy, and metrics.

pub mod epsilon;
pub mod metrics;
pub mod request;
pub mod server;

pub use epsilon::{BaselineSource, EpsilonSource, GrngBankSource, PhiloxSource};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{InferRequest, InferResponse, RejectReason};
pub use server::Coordinator;
