//! L3 coordinator: the serving engine around the runtime — a front-end
//! dispatcher (request router/batcher) feeding a pool of shard workers,
//! each owning its own engine and its own per-shard in-word GRNG bank;
//! Monte-Carlo sample scheduling, deferral policy, and per-shard metrics.
//!
//! Module layout:
//! - [`batch`] — pure batch-assembly / slot-packing cores (no I/O).
//! - [`dispatch`] — the dispatcher and shard-worker loops.
//! - [`server`] — the [`Coordinator`] handle (start/submit/shutdown).
//! - [`epsilon`] — ε sources, including per-shard seed derivation.
//! - [`metrics`] — global + per-shard counters.

pub mod batch;
mod dispatch;
pub mod epsilon;
pub mod metrics;
pub mod request;
pub mod server;

pub use batch::Batch;
pub use epsilon::{
    shard_die_seed, BaselineSource, EpsilonMode, EpsilonSource, EpsilonSupply, GrngBankSource,
    PhiloxSource,
};
pub use metrics::{Metrics, MetricsSnapshot, ShardSnapshot};
pub use request::{InferRequest, InferResponse, RejectReason};
pub use server::{Coordinator, EngineFactory, SourceFactory};
