//! Pure batch-assembly and slot-packing cores, extracted from the serving
//! loop so they are unit-testable without an engine, a queue, or a clock.
//!
//! The packing mirrors the chip's dataflow: every artifact call has
//! `art_batch` slots and each slot carries one (request, MC-pass) pair, so
//! the number of engine executions per fused batch is ceil(k·T / B)
//! instead of T (§Perf in EXPERIMENTS.md: ~5× fewer head executions at
//! k=1, T=32, B=8).

use crate::coordinator::request::InferRequest;

/// A fused batch of requests on its way from the dispatcher to a shard
/// worker.
pub struct Batch {
    /// Monotone id assigned by the dispatcher (rides on
    /// `InferResponse::batch_id`; also selects the round-robin shard).
    pub id: u64,
    pub requests: Vec<InferRequest>,
}

/// Effective Monte-Carlo pass count for a fused batch: the max over member
/// requests, where `0` means "server default". `Coordinator::submit` bounds
/// per-request values by `server.max_mc_samples`, so one request can no
/// longer inflate `t` without limit for the whole batch.
pub fn effective_t(mc_samples: &[usize], default_t: usize) -> usize {
    mc_samples
        .iter()
        .map(|&m| if m == 0 { default_t } else { m })
        .max()
        .unwrap_or(default_t)
}

/// Slot-packing plan: returns, per engine call, the request index owning
/// each occupied slot. Pairs are laid out request-major (request 0's T
/// passes first), calls are filled front to back, and only the final call
/// may be partial.
pub fn plan_calls(n_requests: usize, t: usize, art_batch: usize) -> Vec<Vec<usize>> {
    assert!(art_batch > 0, "artifact batch must be > 0");
    let total_slots = n_requests * t;
    let calls = total_slots.div_ceil(art_batch);
    let mut plan = Vec::with_capacity(calls);
    for call in 0..calls {
        let mut owners = Vec::with_capacity(art_batch);
        for slot in 0..art_batch {
            let pair = call * art_batch + slot;
            if pair < total_slots {
                owners.push(pair / t);
            }
        }
        plan.push(owners);
    }
    plan
}

/// Pad per-request images into the artifact's static batch (row-major;
/// unused tail slots are zero-filled).
pub fn pack_images(images: &[&[f32]], art_batch: usize, pixels_per_img: usize) -> Vec<f32> {
    assert!(images.len() <= art_batch, "batch overflows artifact batch");
    let mut out = vec![0.0f32; art_batch * pixels_per_img];
    for (i, img) in images.iter().enumerate() {
        out[i * pixels_per_img..(i + 1) * pixels_per_img].copy_from_slice(img);
    }
    out
}

/// Replicate each owning request's feature row into its slot of the next
/// packed head call. Unoccupied tail slots keep their previous contents —
/// their outputs are never read.
pub fn scatter_features(feats: &[f32], owners: &[usize], feat_dim: usize, out: &mut [f32]) {
    for (slot, &req) in owners.iter().enumerate() {
        out[slot * feat_dim..(slot + 1) * feat_dim]
            .copy_from_slice(&feats[req * feat_dim..(req + 1) * feat_dim]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_t_takes_max_with_default_substitution() {
        assert_eq!(effective_t(&[0, 0], 8), 8);
        assert_eq!(effective_t(&[4, 12, 2], 8), 12);
        assert_eq!(effective_t(&[0, 4], 8), 8);
        assert_eq!(effective_t(&[4, 2], 1), 4);
        assert_eq!(effective_t(&[], 8), 8);
    }

    #[test]
    fn plan_covers_every_pair_exactly_once() {
        // 3 requests × 5 passes over batch-4 calls → 15 slots in 4 calls.
        let plan = plan_calls(3, 5, 4);
        assert_eq!(plan.len(), 4);
        let mut per_request = vec![0usize; 3];
        for owners in &plan {
            assert!(owners.len() <= 4);
            for &r in owners {
                per_request[r] += 1;
            }
        }
        assert_eq!(per_request, vec![5, 5, 5]);
        assert_eq!(plan[0], vec![0, 0, 0, 0]);
        assert_eq!(plan[3], vec![2, 2, 2]);
    }

    #[test]
    fn plan_single_request_single_call() {
        let plan = plan_calls(1, 6, 16);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0], vec![0; 6]);
    }

    #[test]
    fn pack_images_zero_pads_tail_slots() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let packed = pack_images(&[&a, &b], 4, 2);
        assert_eq!(packed, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn scatter_features_replicates_owner_rows() {
        let feats = [10.0f32, 11.0, 20.0, 21.0]; // 2 requests × feat_dim 2
        let mut out = vec![0.0f32; 6]; // 3 slots
        scatter_features(&feats, &[1, 0, 1], 2, &mut out);
        assert_eq!(out, vec![20.0, 21.0, 10.0, 11.0, 20.0, 21.0]);
    }
}
