//! Request/response types for the serving engine.

use crate::bayes::McPrediction;
use std::sync::mpsc::Sender;
use std::time::Instant;

/// A classification request entering the coordinator.
pub struct InferRequest {
    pub id: u64,
    /// Grayscale image, row-major, side×side in [0,1].
    pub pixels: Vec<f32>,
    /// Monte-Carlo samples requested (0 = server default).
    pub mc_samples: usize,
    pub enqueued: Instant,
    /// Reply channel.
    pub reply: Sender<InferResponse>,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: u64,
    pub pred: McPrediction,
    /// Entropy exceeded the deferral threshold → route to human /
    /// secondary model (Fig. 1's safety-critical loop).
    pub deferred: bool,
    /// Queue + compute latency.
    pub latency: std::time::Duration,
    /// Which batch this request rode in (diagnostics).
    pub batch_id: u64,
    /// Simulated hardware energy attributed to this request [J]: its
    /// share of the batch's tile-`EnergyLedger` delta. 0 for backends
    /// without an energy model (sim, pjrt).
    pub energy_j: f64,
}

/// Failure modes surfaced to clients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    QueueFull,
    WrongShape { expected: usize, got: usize },
    /// `mc_samples` above `server.max_mc_samples` — rejected up front so
    /// one greedy request cannot inflate the MC pass count of the whole
    /// fused batch.
    McSamplesTooLarge { max: usize, got: usize },
    ShuttingDown,
    Timeout,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full (backpressure)"),
            RejectReason::WrongShape { expected, got } => {
                write!(f, "wrong input shape: expected {expected} pixels, got {got}")
            }
            RejectReason::McSamplesTooLarge { max, got } => {
                write!(f, "mc_samples {got} exceeds server.max_mc_samples {max}")
            }
            RejectReason::ShuttingDown => write!(f, "server shutting down"),
            RejectReason::Timeout => write!(f, "request timed out"),
        }
    }
}
