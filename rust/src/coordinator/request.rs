//! Request/response types for the serving engine.
//!
//! Clients build requests through [`crate::client::Infer`] and receive
//! [`InferResponse`]s through [`crate::client::Ticket`]s; the types here
//! are the wire format between the coordinator's queues and the shard
//! workers.

use crate::bayes::{McPrediction, UncertaintyReport};
use crate::client::ServeError;
use std::sync::mpsc::Sender;
use std::time::Instant;

/// A classification request in flight inside the coordinator.
pub struct InferRequest {
    pub id: u64,
    /// Grayscale image, row-major, side×side in \[0,1\].
    pub pixels: Vec<f32>,
    /// Monte-Carlo samples requested (0 = server default).
    pub mc_samples: usize,
    /// Per-request deferral-threshold override \[nats\]
    /// (`None` = `model.defer_threshold`).
    pub defer_threshold: Option<f64>,
    pub enqueued: Instant,
    /// End-to-end deadline, fixed at admission (`Infer::deadline` or
    /// `server.request_timeout_ms`). A retried request carries its
    /// *original* deadline, so recovery never exceeds the budget the
    /// caller signed up for.
    pub deadline: Instant,
    /// Redeliveries consumed so far (bounded by `server.retry_budget`).
    pub retries: usize,
    /// Reply channel: exactly one [`Reply`] per request — a response, or
    /// a typed failure pushed by the supervisor/recovery path.
    pub reply: Sender<Reply>,
}

/// What comes back over a request's reply channel. Failures are
/// *delivered*, not signalled by dropping the sender, so a
/// [`Ticket`](crate::client::Ticket) blocked in `wait` resolves promptly
/// with the typed error instead of hanging until its own timeout.
#[derive(Clone, Debug)]
pub enum Reply {
    Response(InferResponse),
    Failed(ServeError),
}

impl Reply {
    pub fn into_result(self) -> Result<InferResponse, ServeError> {
        match self {
            Reply::Response(resp) => Ok(resp),
            Reply::Failed(err) => Err(err),
        }
    }
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: u64,
    pub pred: McPrediction,
    /// Why (and whether) this prediction should be deferred to a human /
    /// secondary model: entropy, aleatoric/epistemic split, the
    /// threshold actually used, and the verdict (Fig. 1's
    /// safety-critical loop, made first-class).
    pub uncertainty: UncertaintyReport,
    /// Queue + compute latency.
    pub latency: std::time::Duration,
    /// Which batch this request rode in (diagnostics).
    pub batch_id: u64,
    /// Simulated hardware energy attributed to this request \[J\]: its
    /// share of the batch's tile-`EnergyLedger` delta. 0 for backends
    /// without an energy model (sim, pjrt).
    pub energy_j: f64,
}

impl InferResponse {
    /// The deferral verdict, straight from [`InferResponse::uncertainty`].
    pub fn deferred(&self) -> bool {
        self.uncertainty.deferred
    }
}

/// Admission failure modes (the pre-v1 vocabulary). The client surface
/// absorbs these into [`crate::client::ServeError`] (`From` impl there,
/// messages unchanged); the type remains for one release as the error
/// vocabulary of the deprecated `infer_blocking` shim and of downstream
/// code mid-migration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    QueueFull,
    WrongShape { expected: usize, got: usize },
    /// `mc_samples` above `server.max_mc_samples` — rejected up front so
    /// one greedy request cannot inflate the MC pass count of the whole
    /// fused batch.
    McSamplesTooLarge { max: usize, got: usize },
    ShuttingDown,
    Timeout,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full (backpressure)"),
            RejectReason::WrongShape { expected, got } => {
                write!(f, "wrong input shape: expected {expected} pixels, got {got}")
            }
            RejectReason::McSamplesTooLarge { max, got } => {
                write!(f, "mc_samples {got} exceeds server.max_mc_samples {max}")
            }
            RejectReason::ShuttingDown => write!(f, "server shutting down"),
            RejectReason::Timeout => write!(f, "request timed out"),
        }
    }
}

impl std::error::Error for RejectReason {}
