//! Serving metrics registry (lock-protected, shared between the
//! dispatcher and every shard worker). Aggregates stay global so existing
//! consumers keep working; per-shard counters ride alongside so scaling
//! behavior (and shard imbalance) is visible per engine.

use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Age \[s\] after which a shard's measured ε (or engine-op) rate is
/// considered stale and snapshots report 0 instead of the last interval's
/// value. Generous enough that slow steady record cadences (one record
/// per fused batch) still surface a rate; short enough that an idle shard
/// stops claiming throughput.
const EPSILON_RATE_STALE_S: f64 = 30.0;

/// Paper headline (Tab. II): aggregate GRNG hardware throughput [GSa/s].
pub const PAPER_GSA_PER_S: f64 = 5.12;

/// Paper headline (Tab. II): peak engine compute throughput [GOp/s].
pub const PAPER_GOP_PER_S: f64 = 102.0;

/// Per-shard counters surfaced in [`MetricsSnapshot::per_shard`].
///
/// All energy/ε counters are *absolute cumulative totals* reported by the
/// shard's source or engine; snapshots are non-destructive — reading one
/// never resets a ledger or a counter (pinned by
/// `snapshot_is_non_destructive` below).
#[derive(Clone, Debug, Default)]
pub struct ShardSnapshot {
    pub shard: usize,
    /// Requests served by this shard (sum of its batch fills).
    pub requests: u64,
    /// Responses this shard computed but could not deliver: the caller
    /// had already dropped its `Ticket` (or timed out in `infer`), so
    /// the reply channel was dead when the worker sent. Served work with
    /// no reader — a leak indicator, not a failure.
    pub requests_orphaned: u64,
    /// Requests the network edge refused outright under overload
    /// (429 + `Retry-After`). Shed requests never reach a shard, so the
    /// edge attributes them round-robin for balance — the per-shard split
    /// is advisory; the global sum is exact.
    pub requests_shed: u64,
    /// Requests the edge admitted at reduced fidelity (cheap low-
    /// `mc_samples` pass) because load sat in the degrade band.
    pub requests_degraded: u64,
    /// Degraded requests whose cheap-pass `UncertaintyReport` came back
    /// uncertain and which the edge re-ran at full fidelity.
    pub requests_escalated: u64,
    /// Times the supervisor respawned this shard's worker after a death
    /// (DESIGN.md §9). The restart re-derives the shard's original
    /// deterministic seed split.
    pub shard_restarts: u64,
    /// Requests redelivered through the admission queue after this shard
    /// failed them (worker death or transient engine error), within the
    /// per-request retry budget. Attributed to the *failing* shard.
    pub requests_retried: u64,
    /// Requests that received a typed `ShardFailed`/`Timeout` reply after
    /// this shard failed them with no retry budget (or deadline) left.
    pub requests_failed_shard: u64,
    pub batches: u64,
    pub mc_passes: u64,
    /// Engine executions (PJRT calls, sim-engine or cim-engine calls).
    pub engine_executions: u64,
    pub epsilon_samples: u64,
    pub epsilon_energy_j: f64,
    /// Measured ε generation rate [Sa/s]: `samples_drawn` delta over the
    /// most recent inter-record interval (delivered throughput with a
    /// wall-clock denominator, analogous to `throughput_rps`; 0 until
    /// two records with increasing totals exist, and decays to 0 after
    /// ~30 s without fresh samples). The live counterpart of the paper's
    /// Tab. II 5.12 GSa/s hardware throughput.
    pub epsilon_sa_per_s: f64,
    /// Cumulative tile energy from the engine's `EnergyLedger`s \[J\]
    /// (0 for backends without a hardware model).
    pub engine_energy_j: f64,
    /// Per-tile MVMs executed by the engine.
    pub engine_mvms: u64,
    /// MAC ops represented by those MVMs (J/Op denominator).
    pub engine_ops: u64,
    /// Measured engine compute rate [Op/s]: `engine_ops` delta over the
    /// most recent inter-record interval, same semantics as
    /// `epsilon_sa_per_s` (0 until two increasing records, ~30 s decay).
    /// The live counterpart of the paper's 102 GOp/s peak throughput.
    pub engine_ops_per_s: f64,
    /// Gauge: MC replicas currently live in this shard's engine (the
    /// elastic pool size; `server.mc_workers` when static).
    pub replicas_active: usize,
    /// Gauge: bytes of the engine's Arc-shared immutable layer (μ/σ
    /// words, digit planes, calibration tables, GRNG parameter lanes) —
    /// counted once regardless of replica count.
    pub bytes_shared: usize,
    /// Gauge: bytes of per-replica private state (ε buffers, stream
    /// state, scratch) summed over live replicas.
    pub bytes_private: usize,
    /// Autoscaler raised this shard's replica target (queue pressure).
    pub scale_up: u64,
    /// This shard's worker decayed its replica target (sustained idle).
    pub scale_down: u64,
    /// Batches this shard's idle worker stole from a backed-up peer.
    pub work_stolen: u64,
    /// Times this shard flipped to a newly published model
    /// (`Coordinator::swap_model`, publish-drain-flip).
    pub model_swaps: u64,
}

impl ShardSnapshot {
    /// ε-generation energy per sample \[fJ\] — the paper's headline
    /// fJ/Sample, live at serving time (NaN-free: 0 when no ε drawn).
    pub fn epsilon_fj_per_sample(&self) -> f64 {
        if self.epsilon_samples == 0 {
            0.0
        } else {
            self.epsilon_energy_j / self.epsilon_samples as f64 * 1e15
        }
    }

    /// NN efficiency [J/Op] over the engine's recorded MVMs (0 when the
    /// backend has no energy model).
    pub fn engine_j_per_op(&self) -> f64 {
        if self.engine_ops == 0 {
            0.0
        } else {
            self.engine_energy_j / self.engine_ops as f64
        }
    }

    /// Measured ε generation rate [GSa/s] (paper Tab. II headline: 5.12).
    pub fn epsilon_gsa_per_s(&self) -> f64 {
        self.epsilon_sa_per_s / 1e9
    }

    /// Measured engine compute rate [GOp/s] (paper Tab. II peak: 102).
    pub fn gop_per_s(&self) -> f64 {
        self.engine_ops_per_s / 1e9
    }
}

#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests_total: u64,
    pub requests_rejected: u64,
    /// Responses computed but sent into dead reply channels (dropped
    /// `Ticket`s / timed-out blocking calls), summed across shards.
    pub requests_orphaned: u64,
    /// Requests the network edge shed under overload (429), summed
    /// across shards (per-shard attribution is round-robin/advisory).
    pub requests_shed: u64,
    /// Requests the edge served at reduced `mc_samples` fidelity.
    pub requests_degraded: u64,
    /// Degraded requests escalated back to full sampling after an
    /// uncertain cheap-pass verdict.
    pub requests_escalated: u64,
    /// Worker respawns across all shards (supervisor self-healing).
    pub shard_restarts: u64,
    /// Requests redelivered after a shard failure, across all shards.
    pub requests_retried: u64,
    /// Requests failed typed (`ShardFailed`/recovery `Timeout`) after
    /// exhausting the retry budget, across all shards.
    pub requests_failed_shard: u64,
    pub requests_deferred: u64,
    pub batches: u64,
    pub mc_passes: u64,
    /// Engine executions across all shards (historical name kept: the
    /// default backend is PJRT).
    pub pjrt_executions: u64,
    pub epsilon_samples: u64,
    pub epsilon_energy_j: f64,
    /// Aggregate measured ε rate across shards [Sa/s] — parallel banks
    /// add throughput, so this is the sum of the per-shard rates.
    pub epsilon_sa_per_s: f64,
    /// Cumulative engine tile energy across shards \[J\] (cim backend).
    pub engine_energy_j: f64,
    /// Per-tile MVMs executed by the engines across shards.
    pub engine_mvms: u64,
    /// MAC ops represented by the engines' MVMs across shards.
    pub engine_ops: u64,
    /// Aggregate measured engine compute rate across shards [Op/s].
    pub engine_ops_per_s: f64,
    /// Gauge: live MC replicas across all shards.
    pub replicas_active: usize,
    /// Gauge: Arc-shared immutable bytes across all shards (each shard's
    /// layer counted once, however many replicas share it).
    pub bytes_shared: usize,
    /// Gauge: per-replica private bytes across all shards.
    pub bytes_private: usize,
    /// Scale-up events across all shards.
    pub scale_up: u64,
    /// Scale-down events across all shards.
    pub scale_down: u64,
    /// Batches stolen between shard queues (elastic work stealing).
    pub work_stolen: u64,
    /// Model hot-swap flips across all shards.
    pub model_swaps: u64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_max_ms: f64,
    pub mean_batch_fill: f64,
    pub throughput_rps: f64,
    pub wall_s: f64,
    pub per_shard: Vec<ShardSnapshot>,
}

impl MetricsSnapshot {
    /// ε energy per sample \[fJ\] across all shards (paper headline).
    pub fn epsilon_fj_per_sample(&self) -> f64 {
        if self.epsilon_samples == 0 {
            0.0
        } else {
            self.epsilon_energy_j / self.epsilon_samples as f64 * 1e15
        }
    }

    /// NN efficiency [J/Op] across all shards (0 without an energy model).
    pub fn engine_j_per_op(&self) -> f64 {
        if self.engine_ops == 0 {
            0.0
        } else {
            self.engine_energy_j / self.engine_ops as f64
        }
    }

    /// Aggregate measured ε rate [GSa/s] (paper Tab. II hardware: 5.12).
    pub fn epsilon_gsa_per_s(&self) -> f64 {
        self.epsilon_sa_per_s / 1e9
    }

    /// Aggregate measured engine compute rate [GOp/s] (paper peak: 102).
    pub fn gop_per_s(&self) -> f64 {
        self.engine_ops_per_s / 1e9
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "requests={} rejected={} orphaned={} deferred={} batches={} (fill {:.2})\n\
             edge shed={} degraded={} escalated={}\n\
             faults restarts={} retried={} failed_shard={}\n\
             mc_passes={} pjrt_exec={} eps_samples={} eps_energy={:.3} µJ\n\
             latency p50={:.2} ms p95={:.2} ms max={:.2} ms | throughput={:.1} req/s",
            self.requests_total,
            self.requests_rejected,
            self.requests_orphaned,
            self.requests_deferred,
            self.batches,
            self.mean_batch_fill,
            self.requests_shed,
            self.requests_degraded,
            self.requests_escalated,
            self.shard_restarts,
            self.requests_retried,
            self.requests_failed_shard,
            self.mc_passes,
            self.pjrt_executions,
            self.epsilon_samples,
            self.epsilon_energy_j * 1e6,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_max_ms,
            self.throughput_rps,
        );
        if self.epsilon_samples > 0 {
            out.push_str(&format!(
                "\nepsilon {:.1} fJ/Sample (paper: 360)",
                self.epsilon_fj_per_sample()
            ));
            if self.epsilon_sa_per_s > 0.0 {
                out.push_str(&format!(
                    " | {:.4} GSa/s measured (paper hw: 5.12)",
                    self.epsilon_gsa_per_s()
                ));
            }
        }
        if self.engine_energy_j > 0.0 {
            out.push_str(&format!(
                " | tile energy {:.3} µJ ({:.0} fJ/Op, paper: 672)",
                self.engine_energy_j * 1e6,
                self.engine_j_per_op() * 1e15,
            ));
        }
        // Elastic capacity: always-on like the fault line, so operators
        // see the live pool shape (and the shared-vs-private footprint
        // split that makes replica scaling cheap) at a glance.
        out.push_str(&format!(
            "\nelastic replicas={} shared={} B private={} B scale_up={} scale_down={} \
             stolen={} swaps={}",
            self.replicas_active,
            self.bytes_shared,
            self.bytes_private,
            self.scale_up,
            self.scale_down,
            self.work_stolen,
            self.model_swaps,
        ));
        // Always-on gap to the paper's Tab. II throughput headlines, so
        // every render answers "how far is software from the silicon".
        out.push_str(&format!(
            "\npaper gap: epsilon {:.4} GSa/s measured vs {PAPER_GSA_PER_S} hw ({:.1}%) | \
             engine {:.4} GOp/s measured vs {PAPER_GOP_PER_S} hw ({:.1}%)",
            self.epsilon_gsa_per_s(),
            self.epsilon_gsa_per_s() / PAPER_GSA_PER_S * 100.0,
            self.gop_per_s(),
            self.gop_per_s() / PAPER_GOP_PER_S * 100.0,
        ));
        if self.per_shard.len() > 1 {
            for s in &self.per_shard {
                out.push_str(&format!(
                    "\n  shard {}: requests={} batches={} exec={} eps={} ({:.3} µJ)",
                    s.shard,
                    s.requests,
                    s.batches,
                    s.engine_executions,
                    s.epsilon_samples,
                    s.epsilon_energy_j * 1e6,
                ));
                if s.requests_orphaned > 0 {
                    out.push_str(&format!(" orphaned={}", s.requests_orphaned));
                }
                if s.requests_shed + s.requests_degraded + s.requests_escalated > 0 {
                    out.push_str(&format!(
                        " shed={} degraded={} escalated={}",
                        s.requests_shed, s.requests_degraded, s.requests_escalated
                    ));
                }
                if s.shard_restarts + s.requests_retried + s.requests_failed_shard > 0 {
                    out.push_str(&format!(
                        " restarts={} retried={} failed={}",
                        s.shard_restarts, s.requests_retried, s.requests_failed_shard
                    ));
                }
                if s.replicas_active > 0 {
                    out.push_str(&format!(" replicas={}", s.replicas_active));
                }
                if s.scale_up + s.scale_down + s.work_stolen + s.model_swaps > 0 {
                    out.push_str(&format!(
                        " scale_up={} scale_down={} stolen={} swaps={}",
                        s.scale_up, s.scale_down, s.work_stolen, s.model_swaps
                    ));
                }
                if s.engine_energy_j > 0.0 {
                    out.push_str(&format!(
                        " tiles {:.3} µJ, {:.0} fJ/Sa",
                        s.engine_energy_j * 1e6,
                        s.epsilon_fj_per_sample(),
                    ));
                }
            }
        }
        out
    }
}

/// Shared registry. Latencies kept as a bounded reservoir.
#[derive(Clone)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Default)]
struct ShardInner {
    requests: u64,
    requests_orphaned: u64,
    requests_shed: u64,
    requests_degraded: u64,
    requests_escalated: u64,
    shard_restarts: u64,
    requests_retried: u64,
    requests_failed_shard: u64,
    batches: u64,
    mc_passes: u64,
    engine_executions: u64,
    epsilon_samples: u64,
    epsilon_energy_j: f64,
    /// Measured ε rate [Sa/s] from the last pair of records with an
    /// increasing `samples_drawn` total.
    epsilon_sa_per_s: f64,
    /// (when, total) of the last ε record — the delta base.
    epsilon_last: Option<(std::time::Instant, u64)>,
    engine_energy_j: f64,
    engine_mvms: u64,
    engine_ops: u64,
    /// Measured engine compute rate [Op/s] from the last pair of records
    /// with an increasing `engine_ops` total.
    engine_ops_per_s: f64,
    /// (when, total ops) of the last engine record — the delta base.
    engine_last: Option<(std::time::Instant, u64)>,
    replicas_active: usize,
    bytes_shared: usize,
    bytes_private: usize,
    scale_up: u64,
    scale_down: u64,
    work_stolen: u64,
    model_swaps: u64,
}

struct Inner {
    requests_total: u64,
    requests_rejected: u64,
    requests_deferred: u64,
    batch_fill_sum: f64,
    latencies_ms: Vec<f64>,
    started: std::time::Instant,
    shards: Vec<ShardInner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new(1)
    }
}

impl Metrics {
    pub fn new(shards: usize) -> Self {
        Self {
            inner: Arc::new(Mutex::new(Inner {
                requests_total: 0,
                requests_rejected: 0,
                requests_deferred: 0,
                batch_fill_sum: 0.0,
                latencies_ms: Vec::new(),
                started: crate::util::clock::now(),
                shards: (0..shards.max(1)).map(|_| ShardInner::default()).collect(),
            })),
        }
    }

    pub fn record_reject(&self) {
        self.inner.lock().unwrap().requests_rejected += 1;
    }

    /// A shard computed a response but the reply channel was dead (the
    /// caller dropped its `Ticket` or timed out): served work with no
    /// reader. Counted per shard and summed globally.
    pub fn record_orphaned(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].requests_orphaned += 1;
    }

    /// The network edge refused a request under overload (429 +
    /// `Retry-After`). Shed requests never reach a shard; the edge passes
    /// a round-robin shard hint so per-shard counters stay balanced and
    /// the global sum stays exact.
    pub fn record_shed(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].requests_shed += 1;
    }

    /// The edge admitted a request at reduced `mc_samples` fidelity.
    /// Shard is derived from the response's `batch_id` routing.
    pub fn record_degraded(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].requests_degraded += 1;
    }

    /// A degraded request's cheap-pass verdict was uncertain and the edge
    /// re-ran it at full fidelity.
    pub fn record_escalated(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].requests_escalated += 1;
    }

    /// The supervisor respawned this shard's worker after a death
    /// (DESIGN.md §9).
    pub fn record_shard_restart(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].shard_restarts += 1;
    }

    /// A request was redelivered after shard `shard` failed it (worker
    /// death or transient engine error), within the retry budget.
    pub fn record_retried(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].requests_retried += 1;
    }

    /// A request was failed typed (`ShardFailed`, or `Timeout` during
    /// recovery) after shard `shard` failed it with no budget left.
    pub fn record_failed_shard(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].requests_failed_shard += 1;
    }

    pub fn record_batch(
        &self,
        shard: usize,
        fill: usize,
        capacity: usize,
        mc_passes: u64,
        engine_executions: u64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.batch_fill_sum += fill as f64 / capacity.max(1) as f64;
        let s = &mut g.shards[shard];
        s.requests += fill as u64;
        s.batches += 1;
        s.mc_passes += mc_passes;
        s.engine_executions += engine_executions;
    }

    pub fn record_response(&self, latency: Duration, deferred: bool) {
        let mut g = self.inner.lock().unwrap();
        g.requests_total += 1;
        if deferred {
            g.requests_deferred += 1;
        }
        if g.latencies_ms.len() < 100_000 {
            g.latencies_ms.push(latency.as_secs_f64() * 1e3);
        }
    }

    /// Absolute ε counters for one shard (sources report totals, not
    /// deltas); the global snapshot sums across shards. The measured
    /// sample *rate* (the paper's GSa/s headline, live) is derived from
    /// the `samples_drawn` delta between consecutive records; re-records
    /// of an unchanged total (idle worker loops) keep the last rate, so
    /// snapshots stay idempotent.
    pub fn record_epsilon(&self, shard: usize, samples: u64, energy_j: f64) {
        let now = crate::util::clock::now();
        let mut g = self.inner.lock().unwrap();
        let s = &mut g.shards[shard];
        match s.epsilon_last {
            Some((t0, prev)) if samples > prev => {
                let dt = now.duration_since(t0).as_secs_f64();
                // Mean rate over the most recent inter-record interval —
                // the *delivered* sample throughput, wall-clock
                // denominator included, analogous to `throughput_rps`.
                // dt == 0 (same timer tick) keeps the old base, so those
                // samples land in the next measurable delta instead of
                // silently dropping out of the rate.
                if dt > 0.0 {
                    s.epsilon_sa_per_s = (samples - prev) as f64 / dt;
                    s.epsilon_last = Some((now, samples));
                }
            }
            Some(_) => {} // unchanged total: keep rate and delta base
            None => s.epsilon_last = Some((now, samples)),
        }
        s.epsilon_samples = samples;
        s.epsilon_energy_j = energy_j;
    }

    /// Absolute engine-energy counters for one shard (cumulative ledger
    /// totals, never deltas — so snapshot reads stay non-destructive and
    /// idempotent even if a report is recorded twice). The measured
    /// compute *rate* (the paper's GOp/s headline, live) is derived from
    /// the `ops` delta between consecutive records, exactly like
    /// [`Metrics::record_epsilon`] derives the GSa/s rate.
    pub fn record_engine_energy(&self, shard: usize, total_j: f64, mvms: u64, ops: u64) {
        let now = crate::util::clock::now();
        let mut g = self.inner.lock().unwrap();
        let s = &mut g.shards[shard];
        match s.engine_last {
            Some((t0, prev)) if ops > prev => {
                let dt = now.duration_since(t0).as_secs_f64();
                if dt > 0.0 {
                    s.engine_ops_per_s = (ops - prev) as f64 / dt;
                    s.engine_last = Some((now, ops));
                }
            }
            Some(_) => {} // unchanged total: keep rate and delta base
            None => s.engine_last = Some((now, ops)),
        }
        s.engine_energy_j = total_j;
        s.engine_mvms = mvms;
        s.engine_ops = ops;
    }

    /// Capacity gauges for one shard: live replica count plus the
    /// shared/private byte split of its engine. Overwrites, not adds —
    /// the worker re-records at every batch boundary and on scale
    /// events, so the gauges track the pool's current shape.
    pub fn record_replicas(&self, shard: usize, active: usize, shared: usize, private: usize) {
        let mut g = self.inner.lock().unwrap();
        let s = &mut g.shards[shard];
        s.replicas_active = active;
        s.bytes_shared = shared;
        s.bytes_private = private;
    }

    /// The autoscaler raised this shard's replica target (queue
    /// pressure; dispatcher side).
    pub fn record_scale_up(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].scale_up += 1;
    }

    /// This shard's worker decayed its replica target after sustained
    /// idleness.
    pub fn record_scale_down(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].scale_down += 1;
    }

    /// This shard's idle worker stole a queued batch from a backed-up
    /// peer (attributed to the *thief*).
    pub fn record_work_stolen(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].work_stolen += 1;
    }

    /// This shard flipped to a newly published model (hot swap).
    pub fn record_model_swap(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].model_swaps += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_ms.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            let idx = ((lat.len() - 1) as f64 * p).round() as usize;
            lat[idx]
        };
        let wall = g.started.elapsed().as_secs_f64();
        let per_shard: Vec<ShardSnapshot> = g
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| ShardSnapshot {
                shard: i,
                requests: s.requests,
                requests_orphaned: s.requests_orphaned,
                requests_shed: s.requests_shed,
                requests_degraded: s.requests_degraded,
                requests_escalated: s.requests_escalated,
                shard_restarts: s.shard_restarts,
                requests_retried: s.requests_retried,
                requests_failed_shard: s.requests_failed_shard,
                batches: s.batches,
                mc_passes: s.mc_passes,
                engine_executions: s.engine_executions,
                epsilon_samples: s.epsilon_samples,
                epsilon_energy_j: s.epsilon_energy_j,
                // A *current* rate: decay to 0 once the shard has drawn
                // nothing for EPSILON_RATE_STALE_S, so idle shards stop
                // reporting their last burst as live throughput.
                epsilon_sa_per_s: match s.epsilon_last {
                    Some((t0, _)) if t0.elapsed().as_secs_f64() < EPSILON_RATE_STALE_S => {
                        s.epsilon_sa_per_s
                    }
                    _ => 0.0,
                },
                engine_energy_j: s.engine_energy_j,
                engine_mvms: s.engine_mvms,
                engine_ops: s.engine_ops,
                engine_ops_per_s: match s.engine_last {
                    Some((t0, _)) if t0.elapsed().as_secs_f64() < EPSILON_RATE_STALE_S => {
                        s.engine_ops_per_s
                    }
                    _ => 0.0,
                },
                replicas_active: s.replicas_active,
                bytes_shared: s.bytes_shared,
                bytes_private: s.bytes_private,
                scale_up: s.scale_up,
                scale_down: s.scale_down,
                work_stolen: s.work_stolen,
                model_swaps: s.model_swaps,
            })
            .collect();
        let batches: u64 = per_shard.iter().map(|s| s.batches).sum();
        MetricsSnapshot {
            requests_total: g.requests_total,
            requests_rejected: g.requests_rejected,
            requests_orphaned: per_shard.iter().map(|s| s.requests_orphaned).sum(),
            requests_shed: per_shard.iter().map(|s| s.requests_shed).sum(),
            requests_degraded: per_shard.iter().map(|s| s.requests_degraded).sum(),
            requests_escalated: per_shard.iter().map(|s| s.requests_escalated).sum(),
            shard_restarts: per_shard.iter().map(|s| s.shard_restarts).sum(),
            requests_retried: per_shard.iter().map(|s| s.requests_retried).sum(),
            requests_failed_shard: per_shard.iter().map(|s| s.requests_failed_shard).sum(),
            requests_deferred: g.requests_deferred,
            batches,
            mc_passes: per_shard.iter().map(|s| s.mc_passes).sum(),
            pjrt_executions: per_shard.iter().map(|s| s.engine_executions).sum(),
            epsilon_samples: per_shard.iter().map(|s| s.epsilon_samples).sum(),
            epsilon_energy_j: per_shard.iter().map(|s| s.epsilon_energy_j).sum(),
            epsilon_sa_per_s: per_shard.iter().map(|s| s.epsilon_sa_per_s).sum(),
            engine_energy_j: per_shard.iter().map(|s| s.engine_energy_j).sum(),
            engine_mvms: per_shard.iter().map(|s| s.engine_mvms).sum(),
            engine_ops: per_shard.iter().map(|s| s.engine_ops).sum(),
            engine_ops_per_s: per_shard.iter().map(|s| s.engine_ops_per_s).sum(),
            replicas_active: per_shard.iter().map(|s| s.replicas_active).sum(),
            bytes_shared: per_shard.iter().map(|s| s.bytes_shared).sum(),
            bytes_private: per_shard.iter().map(|s| s.bytes_private).sum(),
            scale_up: per_shard.iter().map(|s| s.scale_up).sum(),
            scale_down: per_shard.iter().map(|s| s.scale_down).sum(),
            work_stolen: per_shard.iter().map(|s| s.work_stolen).sum(),
            model_swaps: per_shard.iter().map(|s| s.model_swaps).sum(),
            latency_p50_ms: pct(0.50),
            latency_p95_ms: pct(0.95),
            latency_max_ms: lat.last().copied().unwrap_or(0.0),
            mean_batch_fill: if batches > 0 {
                g.batch_fill_sum / batches as f64
            } else {
                0.0
            },
            throughput_rps: if wall > 0.0 {
                g.requests_total as f64 / wall
            } else {
                0.0
            },
            wall_s: wall,
            per_shard,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate_and_snapshot() {
        let m = Metrics::new(2);
        m.record_batch(0, 6, 8, 32, 33);
        m.record_batch(1, 8, 8, 32, 33);
        for i in 0..10 {
            m.record_response(Duration::from_millis(10 + i), i % 3 == 0);
        }
        m.record_reject();
        m.record_epsilon(0, 600, 2.0e-7);
        m.record_epsilon(1, 400, 1.6e-7);
        let s = m.snapshot();
        assert_eq!(s.requests_total, 10);
        assert_eq!(s.requests_rejected, 1);
        assert_eq!(s.requests_deferred, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mc_passes, 64);
        assert_eq!(s.pjrt_executions, 66);
        assert_eq!(s.epsilon_samples, 1000);
        assert!((s.epsilon_energy_j - 3.6e-7).abs() < 1e-15);
        assert!((s.mean_batch_fill - 0.875).abs() < 1e-9);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p95_ms <= 20.0);
        assert!(s.render().contains("requests=10"));
        // Per-shard counters line up with the aggregates.
        assert_eq!(s.per_shard.len(), 2);
        assert_eq!(s.per_shard[0].requests, 6);
        assert_eq!(s.per_shard[1].requests, 8);
        assert_eq!(s.per_shard[0].epsilon_samples, 600);
        assert!(s.render().contains("shard 1"));
    }

    #[test]
    fn orphaned_responses_count_per_shard_and_globally() {
        let m = Metrics::new(2);
        m.record_orphaned(1);
        m.record_orphaned(1);
        m.record_orphaned(0);
        let s = m.snapshot();
        assert_eq!(s.requests_orphaned, 3);
        assert_eq!(s.per_shard[0].requests_orphaned, 1);
        assert_eq!(s.per_shard[1].requests_orphaned, 2);
        assert!(s.render().contains("orphaned=3"));
        // The per-shard render line surfaces nonzero orphan counts.
        assert!(s.render().contains("orphaned=2"));
    }

    #[test]
    fn edge_admission_counters_count_per_shard_and_globally() {
        let m = Metrics::new(2);
        m.record_shed(0);
        m.record_shed(1);
        m.record_shed(1);
        m.record_degraded(0);
        m.record_degraded(0);
        m.record_escalated(0);
        let s = m.snapshot();
        assert_eq!(s.requests_shed, 3);
        assert_eq!(s.requests_degraded, 2);
        assert_eq!(s.requests_escalated, 1);
        assert_eq!(s.per_shard[0].requests_shed, 1);
        assert_eq!(s.per_shard[1].requests_shed, 2);
        assert_eq!(s.per_shard[0].requests_degraded, 2);
        assert_eq!(s.per_shard[1].requests_degraded, 0);
        assert_eq!(s.per_shard[0].requests_escalated, 1);
        let r = s.render();
        assert!(r.contains("shed=3 degraded=2 escalated=1"), "global:\n{r}");
        // Per-shard render line surfaces nonzero admission counters.
        assert!(r.contains("shed=1 degraded=2 escalated=1"), "shard 0:\n{r}");
        // A quiet registry still renders the edge line (zeros, no gating).
        let quiet = Metrics::new(1).snapshot().render();
        assert!(quiet.contains("shed=0 degraded=0 escalated=0"), "{quiet}");
    }

    #[test]
    fn fault_counters_count_per_shard_and_globally() {
        let m = Metrics::new(2);
        m.record_shard_restart(1);
        m.record_retried(1);
        m.record_retried(1);
        m.record_retried(0);
        m.record_failed_shard(1);
        let s = m.snapshot();
        assert_eq!(s.shard_restarts, 1);
        assert_eq!(s.requests_retried, 3);
        assert_eq!(s.requests_failed_shard, 1);
        assert_eq!(s.per_shard[0].shard_restarts, 0);
        assert_eq!(s.per_shard[1].shard_restarts, 1);
        assert_eq!(s.per_shard[0].requests_retried, 1);
        assert_eq!(s.per_shard[1].requests_retried, 2);
        assert_eq!(s.per_shard[1].requests_failed_shard, 1);
        let r = s.render();
        assert!(r.contains("faults restarts=1 retried=3 failed_shard=1"), "{r}");
        // Per-shard render line surfaces nonzero fault counters.
        assert!(r.contains("restarts=1 retried=2 failed=1"), "{r}");
        // A quiet registry still renders the fault line (zeros).
        let quiet = Metrics::new(1).snapshot().render();
        assert!(quiet.contains("faults restarts=0 retried=0 failed_shard=0"), "{quiet}");
    }

    #[test]
    fn elastic_gauges_overwrite_and_counters_accumulate() {
        let m = Metrics::new(2);
        // Gauges overwrite: the second record is the live pool shape.
        m.record_replicas(0, 4, 10_000, 800);
        m.record_replicas(0, 2, 10_000, 400);
        m.record_replicas(1, 3, 10_000, 600);
        m.record_scale_up(0);
        m.record_scale_up(1);
        m.record_scale_down(0);
        m.record_work_stolen(1);
        m.record_model_swap(0);
        m.record_model_swap(1);
        let s = m.snapshot();
        assert_eq!(s.replicas_active, 5);
        assert_eq!(s.bytes_shared, 20_000);
        assert_eq!(s.bytes_private, 1000);
        assert_eq!(s.scale_up, 2);
        assert_eq!(s.scale_down, 1);
        assert_eq!(s.work_stolen, 1);
        assert_eq!(s.model_swaps, 2);
        assert_eq!(s.per_shard[0].replicas_active, 2);
        assert_eq!(s.per_shard[0].bytes_private, 400);
        assert_eq!(s.per_shard[1].work_stolen, 1);
        let r = s.render();
        assert!(
            r.contains("elastic replicas=5") && r.contains("stolen=1 swaps=2"),
            "{r}"
        );
        // Per-shard render line surfaces the pool and its scale events.
        assert!(r.contains("replicas=2 scale_up=1 scale_down=1"), "{r}");
        // A quiet registry still renders the elastic line (zeros).
        let quiet = Metrics::new(1).snapshot().render();
        assert!(quiet.contains("elastic replicas=0"), "{quiet}");
    }

    #[test]
    fn epsilon_rate_derives_from_sample_deltas() {
        let m = Metrics::new(2);
        // First record only sets the delta base: no rate yet.
        m.record_epsilon(0, 1000, 1e-9);
        assert_eq!(m.snapshot().epsilon_sa_per_s, 0.0);
        std::thread::sleep(Duration::from_millis(20));
        m.record_epsilon(0, 513_000, 2e-9);
        let s = m.snapshot();
        let rate = s.per_shard[0].epsilon_sa_per_s;
        assert!(rate > 0.0, "rate must be measured after a delta");
        // 512k samples over ≥20 ms: bounded above by 512k/0.02 Sa/s.
        assert!(rate <= 512_000.0 / 0.020 * 1.01, "rate {rate} too high");
        assert_eq!(s.epsilon_sa_per_s, rate, "global = sum of shards");
        assert!((s.epsilon_gsa_per_s() - rate / 1e9).abs() < 1e-12);
        // Re-recording the same total (idle loop) keeps the rate.
        m.record_epsilon(0, 513_000, 2e-9);
        assert_eq!(m.snapshot().per_shard[0].epsilon_sa_per_s, rate);
        assert!(s.render().contains("GSa/s"));
    }

    #[test]
    fn engine_ops_rate_derives_from_op_deltas() {
        let m = Metrics::new(2);
        // First record only sets the delta base: no rate yet.
        m.record_engine_energy(0, 1e-9, 10, 1_000_000);
        assert_eq!(m.snapshot().engine_ops_per_s, 0.0);
        std::thread::sleep(Duration::from_millis(20));
        m.record_engine_energy(0, 2e-9, 20, 103_000_000);
        let s = m.snapshot();
        let rate = s.per_shard[0].engine_ops_per_s;
        assert!(rate > 0.0, "rate must be measured after a delta");
        // 102M ops over ≥20 ms: bounded above by 102M/0.02 Op/s.
        assert!(rate <= 102.0e6 / 0.020 * 1.01, "rate {rate} too high");
        assert_eq!(s.engine_ops_per_s, rate, "global = sum of shards");
        assert!((s.gop_per_s() - rate / 1e9).abs() < 1e-12);
        assert!((s.per_shard[0].gop_per_s() - rate / 1e9).abs() < 1e-12);
        // Re-recording the same total (idle loop) keeps the rate.
        m.record_engine_energy(0, 2e-9, 20, 103_000_000);
        assert_eq!(m.snapshot().per_shard[0].engine_ops_per_s, rate);
    }

    #[test]
    fn render_always_reports_paper_gap() {
        // Even a fresh, empty snapshot states the distance to the paper's
        // 5.12 GSa/s and 102 GOp/s headlines — the gap line is
        // unconditional, not gated on traffic.
        let empty = Metrics::new(1).snapshot();
        let r = empty.render();
        assert!(r.contains("paper gap:"), "missing gap line:\n{r}");
        assert!(r.contains("5.12"), "missing GSa/s headline:\n{r}");
        assert!(r.contains("102"), "missing GOp/s headline:\n{r}");
        assert!(r.contains("GOp/s"), "missing GOp/s unit:\n{r}");
    }

    #[test]
    fn absolute_epsilon_counters_overwrite_not_add() {
        let m = Metrics::new(1);
        m.record_epsilon(0, 100, 1e-8);
        m.record_epsilon(0, 250, 3e-8);
        let s = m.snapshot();
        assert_eq!(s.epsilon_samples, 250);
        assert!((s.epsilon_energy_j - 3e-8).abs() < 1e-18);
    }

    #[test]
    fn engine_energy_counters_surface_headline_metrics() {
        let m = Metrics::new(2);
        // Shard 0: a cim-like engine reporting cumulative ledger totals —
        // 10 MOp at the paper's 672 fJ/Op, ε at 360 fJ/Sample.
        m.record_engine_energy(0, 6.72e-6, 5000, 10_000_000);
        m.record_epsilon(0, 1000, 3.6e-10);
        let s = m.snapshot();
        assert!((s.engine_energy_j - 6.72e-6).abs() < 1e-17);
        assert_eq!(s.engine_mvms, 5000);
        assert_eq!(s.engine_ops, 10_000_000);
        assert!((s.engine_j_per_op() - 672e-15).abs() < 1e-18);
        assert!((s.epsilon_fj_per_sample() - 360.0).abs() < 1e-6);
        assert!((s.per_shard[0].epsilon_fj_per_sample() - 360.0).abs() < 1e-6);
        assert!((s.per_shard[0].engine_j_per_op() - 672e-15).abs() < 1e-18);
        // Shard 1 has no energy model: derived metrics are 0, not NaN.
        assert_eq!(s.per_shard[1].epsilon_fj_per_sample(), 0.0);
        assert_eq!(s.per_shard[1].engine_j_per_op(), 0.0);
        assert!(s.render().contains("fJ/Sample"));
    }

    /// Regression: reading a snapshot must not reset any counter — ε and
    /// engine-energy totals are absolute, so two consecutive reads (and a
    /// re-recorded identical report) return identical values.
    #[test]
    fn snapshot_is_non_destructive() {
        let m = Metrics::new(2);
        m.record_batch(0, 4, 8, 16, 17);
        m.record_epsilon(0, 640, 2.3e-7);
        m.record_engine_energy(0, 5.5e-9, 123, 456_000);
        for i in 0..4 {
            m.record_response(Duration::from_millis(5 + i), false);
        }
        let a = m.snapshot();
        let b = m.snapshot();
        assert_eq!(a.requests_total, b.requests_total);
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.epsilon_samples, b.epsilon_samples);
        assert_eq!(a.epsilon_energy_j, b.epsilon_energy_j);
        assert_eq!(a.engine_energy_j, b.engine_energy_j);
        assert_eq!(a.engine_ops, b.engine_ops);
        assert_eq!(a.per_shard[0].engine_energy_j, b.per_shard[0].engine_energy_j);
        assert_eq!(a.per_shard[0].engine_mvms, b.per_shard[0].engine_mvms);
        // Recording the same cumulative totals again (idle worker loop)
        // must not double-count either.
        m.record_epsilon(0, 640, 2.3e-7);
        m.record_engine_energy(0, 5.5e-9, 123, 456_000);
        let c = m.snapshot();
        assert_eq!(a.epsilon_energy_j, c.epsilon_energy_j);
        assert_eq!(a.engine_energy_j, c.engine_energy_j);
    }
}
