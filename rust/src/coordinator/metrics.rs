//! Serving metrics registry (lock-protected, shared with the worker).

use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests_total: u64,
    pub requests_rejected: u64,
    pub requests_deferred: u64,
    pub batches: u64,
    pub mc_passes: u64,
    pub pjrt_executions: u64,
    pub epsilon_samples: u64,
    pub epsilon_energy_j: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_max_ms: f64,
    pub mean_batch_fill: f64,
    pub throughput_rps: f64,
    pub wall_s: f64,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} rejected={} deferred={} batches={} (fill {:.2})\n\
             mc_passes={} pjrt_exec={} eps_samples={} eps_energy={:.3} µJ\n\
             latency p50={:.2} ms p95={:.2} ms max={:.2} ms | throughput={:.1} req/s",
            self.requests_total,
            self.requests_rejected,
            self.requests_deferred,
            self.batches,
            self.mean_batch_fill,
            self.mc_passes,
            self.pjrt_executions,
            self.epsilon_samples,
            self.epsilon_energy_j * 1e6,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_max_ms,
            self.throughput_rps,
        )
    }
}

/// Shared registry. Latencies kept as a bounded reservoir.
#[derive(Clone)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

struct Inner {
    requests_total: u64,
    requests_rejected: u64,
    requests_deferred: u64,
    batches: u64,
    batch_fill_sum: f64,
    mc_passes: u64,
    pjrt_executions: u64,
    epsilon_samples: u64,
    epsilon_energy_j: f64,
    latencies_ms: Vec<f64>,
    started: std::time::Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Mutex::new(Inner {
                requests_total: 0,
                requests_rejected: 0,
                requests_deferred: 0,
                batches: 0,
                batch_fill_sum: 0.0,
                mc_passes: 0,
                pjrt_executions: 0,
                epsilon_samples: 0,
                epsilon_energy_j: 0.0,
                latencies_ms: Vec::new(),
                started: std::time::Instant::now(),
            })),
        }
    }

    pub fn record_reject(&self) {
        self.inner.lock().unwrap().requests_rejected += 1;
    }

    pub fn record_batch(&self, fill: usize, capacity: usize, mc_passes: u64, pjrt: u64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_fill_sum += fill as f64 / capacity.max(1) as f64;
        g.mc_passes += mc_passes;
        g.pjrt_executions += pjrt;
    }

    pub fn record_response(&self, latency: Duration, deferred: bool) {
        let mut g = self.inner.lock().unwrap();
        g.requests_total += 1;
        if deferred {
            g.requests_deferred += 1;
        }
        if g.latencies_ms.len() < 100_000 {
            g.latencies_ms.push(latency.as_secs_f64() * 1e3);
        }
    }

    pub fn record_epsilon(&self, samples: u64, energy_j: f64) {
        let mut g = self.inner.lock().unwrap();
        g.epsilon_samples = samples;
        g.epsilon_energy_j = energy_j;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_ms.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            let idx = ((lat.len() - 1) as f64 * p).round() as usize;
            lat[idx]
        };
        let wall = g.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            requests_total: g.requests_total,
            requests_rejected: g.requests_rejected,
            requests_deferred: g.requests_deferred,
            batches: g.batches,
            mc_passes: g.mc_passes,
            pjrt_executions: g.pjrt_executions,
            epsilon_samples: g.epsilon_samples,
            epsilon_energy_j: g.epsilon_energy_j,
            latency_p50_ms: pct(0.50),
            latency_p95_ms: pct(0.95),
            latency_max_ms: lat.last().copied().unwrap_or(0.0),
            mean_batch_fill: if g.batches > 0 {
                g.batch_fill_sum / g.batches as f64
            } else {
                0.0
            },
            throughput_rps: if wall > 0.0 {
                g.requests_total as f64 / wall
            } else {
                0.0
            },
            wall_s: wall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate_and_snapshot() {
        let m = Metrics::new();
        m.record_batch(6, 8, 32, 33);
        m.record_batch(8, 8, 32, 33);
        for i in 0..10 {
            m.record_response(Duration::from_millis(10 + i), i % 3 == 0);
        }
        m.record_reject();
        m.record_epsilon(1000, 3.6e-7);
        let s = m.snapshot();
        assert_eq!(s.requests_total, 10);
        assert_eq!(s.requests_rejected, 1);
        assert_eq!(s.requests_deferred, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 0.875).abs() < 1e-9);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p95_ms <= 20.0);
        assert!(s.render().contains("requests=10"));
    }
}
