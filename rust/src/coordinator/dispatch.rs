//! Dispatcher and shard-worker loops.
//!
//! The front-end dispatcher owns batch assembly only — no engine, no ε.
//! It drains the bounded request queue, fuses requests under the
//! size/deadline policy, and hands each [`Batch`] to one of
//! `server.workers` shard workers over per-shard bounded queues
//! (round-robin on the batch id, so for a serial workload the
//! request→shard routing — and therefore every response — is a pure
//! function of `(die_seed, workers)`).
//!
//! Routing is health-aware (DESIGN.md §9): the round-robin target is
//! preferred, but a shard the supervisor has marked `Restarting`/`Dead`
//! is skipped for the next healthy one. With every shard healthy the
//! scan degenerates to the original pure round-robin, so the
//! deterministic-replay contract is unchanged on the no-fault path. If
//! *no* shard is healthy the dispatcher parks the batch and rescans
//! until the supervisor heals a shard — or fails the batch typed
//! ([`ServeError::ShardFailed`]) once every shard is terminally dead.
//!
//! Each shard worker constructs its own non-`Send` engine and — for
//! external-ε backends — its own independent ε source (a per-shard GRNG
//! bank seeded from a SplitMix64 split of `die_seed`), then runs:
//! features once per batch → packed Monte-Carlo head passes → aggregate →
//! judge (`bayes::UncertaintyReport`, per-request threshold) → reply.
//! Replies into dead channels (dropped `Ticket`s, timed-out blocking
//! calls) are counted as `requests_orphaned` — the worker never crashes
//! on an absent reader. Each batch is parked in the shard's
//! [`InFlight`] slot for the duration of the serve, so a worker panic
//! leaves the batch recoverable; a *transient* engine error (worker
//! still alive) is recovered in place by the worker itself, under the
//! same retry-budget/deadline rules the supervisor applies after a
//! death. Under `EpsilonMode::External` the worker fills ε buffers
//! per head call; under `EpsilonMode::InWord` the engine's own memory
//! arrays generate ε during the MVM (the chip's dataflow) and the worker
//! reads ε/energy totals back from the engine. Either way this is the
//! paper's parallelism in software: replicated in-word GRNG banks feed
//! independent compute lanes with no shared RNG unit on a bus.

use crate::bayes::{aggregate_mc, UncertaintyReport};
use crate::client::ServeError;
use crate::config::Config;
use crate::coordinator::batch::{effective_t, pack_images, plan_calls, scatter_features, Batch};
use crate::coordinator::epsilon::EpsilonSource;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferRequest, InferResponse, Reply};
use crate::coordinator::supervisor::{recover_batch, InFlight, ShardHealth, ShardTable, WorkerCtx};
use crate::runtime::{ArtifactSpec, EpsilonMode, InferenceEngine};
use crate::util::threadpool::Bounded;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Front-end loop: runs until the request queue closes, then closes every
/// shard queue behind itself so the workers drain and exit.
pub(crate) fn run_dispatcher(
    requests: Bounded<InferRequest>,
    table: Arc<ShardTable>,
    metrics: Metrics,
    max_batch: usize,
    deadline: Duration,
) {
    let shards = table.shards().max(1);
    let mut next_batch_id: u64 = 0;
    loop {
        // Block for the first request (or shutdown).
        let first = match requests.recv() {
            Some(r) => r,
            None => break,
        };
        let mut members = vec![first];
        let mut closed = false;
        // Fill up to max_batch until the deadline.
        let cutoff = Instant::now() + deadline;
        while members.len() < max_batch {
            let now = Instant::now();
            if now >= cutoff {
                break;
            }
            match requests.recv_timeout(cutoff - now) {
                Ok(Some(r)) => members.push(r),
                Ok(None) => break, // deadline
                Err(()) => {
                    // Closed mid-assembly: ship what we have, then exit.
                    closed = true;
                    break;
                }
            }
        }
        next_batch_id += 1;
        let target = ((next_batch_id - 1) % shards as u64) as usize;
        let mut pending = Some(Batch {
            id: next_batch_id,
            requests: members,
        });
        'route: loop {
            let health = table.health();
            if health.iter().all(|h| *h == ShardHealth::Dead) {
                // Terminal: no shard will ever come back. Fail every
                // member typed so blocked waits resolve promptly.
                let batch = pending.take().expect("batch still pending");
                for req in batch.requests {
                    metrics.record_failed_shard(target);
                    let _ = req
                        .reply
                        .send(Reply::Failed(ServeError::ShardFailed { shard: target }));
                }
                break 'route;
            }
            for k in 0..shards {
                let i = (target + k) % shards;
                if health[i] != ShardHealth::Healthy {
                    continue;
                }
                // Clone the queue under the table's short lock; block on
                // the send outside it. For a healthy target this is the
                // original backpressure behaviour, unchanged.
                let queue = table.queue(i);
                match queue.send(pending.take().expect("batch still pending")) {
                    Ok(()) => break 'route,
                    // Closed between the health read and the send: the
                    // worker just died — try the next candidate.
                    Err(batch) => pending = Some(batch),
                }
            }
            // No healthy shard accepted (restarts in flight): park
            // briefly and rescan. The supervisor always makes progress —
            // every exit ends in Healthy or Dead — so this terminates.
            std::thread::sleep(Duration::from_millis(1));
        }
        if closed {
            break;
        }
    }
    table.close_all();
}

/// Per-shard metadata resolved once from the engine's manifest.
struct ShardPlan {
    art_batch: usize,
    pixels_per_img: usize,
    classes: usize,
    feat_spec: ArtifactSpec,
    head_spec: ArtifactSpec,
}

/// Worker loop: owns this shard's engine (and, for external-ε backends,
/// its ε source) for its lifetime. Each batch is parked in `slot` while
/// served; on a transient engine error the worker recovers the batch in
/// place (retry budget + original deadline), and on a panic the
/// supervisor recovers it from the slot.
pub(crate) fn run_shard_worker(
    shard: usize,
    mut engine: Box<dyn InferenceEngine>,
    mut source: Option<Box<dyn EpsilonSource>>,
    batches: Bounded<Batch>,
    slot: InFlight,
    ctx: WorkerCtx,
) {
    let manifest = engine.manifest().clone();
    let plan = ShardPlan {
        art_batch: manifest.batch,
        pixels_per_img: manifest.side * manifest.side,
        classes: manifest.classes,
        feat_spec: manifest.entry("features").expect("features entry").clone(),
        head_spec: manifest.entry("head").expect("head entry").clone(),
    };
    while let Some(batch) = batches.recv() {
        // The guard is held across the whole serve: a panic inside
        // poisons the slot with the batch still parked, which is exactly
        // what the supervisor recovers (poison-tolerant lock there).
        let mut guard = slot.lock();
        *guard = Some(batch);
        let served = serve_batch(
            shard,
            engine.as_mut(),
            &mut source,
            guard.as_ref().expect("batch parked"),
            &ctx.metrics,
            &ctx.cfg,
            &plan,
        );
        let batch = guard.take().expect("batch parked");
        drop(guard);
        // serve_batch records before replying (so snapshots taken after a
        // response are current); repeat here so ε/energy drawn by a batch
        // that *failed* mid-way is still counted. Absolute totals make
        // the double-record idempotent.
        record_energy_counters(shard, engine.as_ref(), &source, &ctx.metrics);
        if served.is_err() {
            recover_batch(batch, shard, &ctx);
        }
    }
}

/// Record this shard's absolute ε/energy totals: external supplies report
/// from the source, in-word engines from their own banks. Called *before*
/// a batch's replies are sent, so a snapshot taken after receiving a
/// response always includes that batch's counters (and two consecutive
/// idle-time snapshots are identical).
fn record_energy_counters(
    shard: usize,
    engine: &dyn InferenceEngine,
    source: &Option<Box<dyn EpsilonSource>>,
    metrics: &Metrics,
) {
    if let Some(src) = source.as_ref() {
        metrics.record_epsilon(shard, src.samples_drawn(), src.energy_j());
    }
    if let Some(rep) = engine.energy_report() {
        metrics.record_engine_energy(shard, rep.total_j, rep.mvm_count, rep.total_ops);
        if engine.epsilon_mode() == EpsilonMode::InWord {
            metrics.record_epsilon(shard, rep.grng_samples, rep.grng_j);
        }
    }
}

/// One fused batch: features once, then packed MC head passes — fresh
/// external ε per call, or engine-internal in-word ε per MVM — then
/// aggregate/defer/reply. `Err` means the engine failed before any reply
/// was sent (engine errors happen before the reply loop), so the caller
/// can redeliver the whole batch without double-replying.
fn serve_batch(
    shard: usize,
    engine: &mut dyn InferenceEngine,
    source: &mut Option<Box<dyn EpsilonSource>>,
    batch: &Batch,
    metrics: &Metrics,
    cfg: &Config,
    plan: &ShardPlan,
) -> Result<(), ()> {
    let reqs = &batch.requests;
    let mc: Vec<usize> = reqs.iter().map(|r| r.mc_samples).collect();
    let t = effective_t(&mc, cfg.model.mc_samples);

    let images: Vec<&[f32]> = reqs.iter().map(|r| r.pixels.as_slice()).collect();
    let packed = pack_images(&images, plan.art_batch, plan.pixels_per_img);

    let exec_before = engine.executions();
    let energy_before = engine.energy_report().map(|r| r.total_j).unwrap_or(0.0);
    let feats = match engine.run("features", &[(&packed, &plan.feat_spec.inputs[0].1)]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("[bnn-cim shard {shard}] features execution failed: {e}");
            return Err(());
        }
    };

    let in_word = engine.epsilon_mode() == EpsilonMode::InWord;
    let feat_dim = feats.len() / plan.art_batch;
    let (mut eps1, mut eps2) = if in_word {
        // The engine's memory arrays generate ε; no buffers cross the
        // boundary (the head entry takes features only).
        (Vec::new(), Vec::new())
    } else {
        (
            vec![0.0f32; plan.head_spec.input_len(1)],
            vec![0.0f32; plan.head_spec.input_len(2)],
        )
    };
    let mut packed_feats = vec![0.0f32; feats.len()];
    let mut per_request: Vec<Vec<Vec<f64>>> = vec![Vec::with_capacity(t); reqs.len()];
    for owners in plan_calls(reqs.len(), t, plan.art_batch) {
        scatter_features(&feats, &owners, feat_dim, &mut packed_feats);
        let result = if in_word {
            engine.run("head", &[(&packed_feats, &plan.head_spec.inputs[0].1)])
        } else {
            // Fresh ε for every call (each slot is an independent MC pass).
            let src = source
                .as_mut()
                .expect("external-ε engine requires a source (startup handshake)");
            src.fill(&mut eps1);
            src.fill(&mut eps2);
            engine.run(
                "head",
                &[
                    (&packed_feats, &plan.head_spec.inputs[0].1),
                    (&eps1, &plan.head_spec.inputs[1].1),
                    (&eps2, &plan.head_spec.inputs[2].1),
                ],
            )
        };
        let probs = match result {
            Ok(p) => p,
            Err(e) => {
                eprintln!("[bnn-cim shard {shard}] head execution failed: {e}");
                return Err(());
            }
        };
        for (slot, &req) in owners.iter().enumerate() {
            per_request[req].push(
                probs[slot * plan.classes..(slot + 1) * plan.classes]
                    .iter()
                    .map(|&v| v as f64)
                    .collect(),
            );
        }
    }
    metrics.record_batch(
        shard,
        reqs.len(),
        plan.art_batch,
        t as u64,
        engine.executions() - exec_before,
    );

    // Per-request energy: this batch's tile-energy delta split across its
    // members (each member contributed the same t MC passes). Computed as
    // a delta of cumulative totals — the ledgers are never reset.
    let energy_after = engine.energy_report().map(|r| r.total_j).unwrap_or(0.0);
    let energy_per_req_j = (energy_after - energy_before).max(0.0) / reqs.len().max(1) as f64;

    // Counters must be current before any reply unblocks a caller.
    record_energy_counters(shard, engine, source, metrics);

    for (req, samples) in reqs.iter().zip(per_request.iter()) {
        let pred = aggregate_mc(samples);
        // The deferral policy lives in `UncertaintyReport`, judged per
        // request: a caller's threshold override beats the server-wide
        // default (one fleet, per-caller risk tolerance).
        let threshold = req.defer_threshold.unwrap_or(cfg.model.defer_threshold);
        let uncertainty = UncertaintyReport::from_prediction(&pred, threshold);
        let latency = req.enqueued.elapsed();
        metrics.record_response(latency, uncertainty.deferred);
        // A dead reply channel means the caller dropped its Ticket (or
        // timed out): count the served-but-undeliverable response
        // instead of silently discarding the send error.
        let orphaned = req
            .reply
            .send(Reply::Response(InferResponse {
                id: req.id,
                pred,
                uncertainty,
                latency,
                batch_id: batch.id,
                energy_j: energy_per_req_j,
            }))
            .is_err();
        if orphaned {
            metrics.record_orphaned(shard);
        }
    }
    Ok(())
}
