//! Dispatcher and shard-worker loops.
//!
//! The front-end dispatcher owns batch assembly only — no engine, no ε.
//! It drains the bounded request queue, fuses requests under the
//! size/deadline policy, and hands each [`Batch`] to one of
//! `server.workers` shard workers over per-shard bounded queues
//! (round-robin on the batch id, so for a serial workload the
//! request→shard routing — and therefore every response — is a pure
//! function of `(die_seed, workers)`).
//!
//! Routing is health-aware (DESIGN.md §9): the round-robin target is
//! preferred, but a shard the supervisor has marked `Restarting`/`Dead`
//! is skipped for the next healthy one. With every shard healthy the
//! scan degenerates to the original pure round-robin, so the
//! deterministic-replay contract is unchanged on the no-fault path. If
//! *no* shard is healthy the dispatcher parks the batch and rescans
//! until the supervisor heals a shard — or fails the batch typed
//! ([`ServeError::ShardFailed`]) once every shard is terminally dead.
//!
//! Each shard worker constructs its own non-`Send` engine and — for
//! external-ε backends — its own independent ε source (a per-shard GRNG
//! bank seeded from a SplitMix64 split of `die_seed`), then runs:
//! features once per batch → packed Monte-Carlo head passes → aggregate →
//! judge (`bayes::UncertaintyReport`, per-request threshold) → reply.
//! Replies into dead channels (dropped `Ticket`s, timed-out blocking
//! calls) are counted as `requests_orphaned` — the worker never crashes
//! on an absent reader. Each batch is parked in the shard's
//! [`InFlight`] slot for the duration of the serve, so a worker panic
//! leaves the batch recoverable; a *transient* engine error (worker
//! still alive) is recovered in place by the worker itself, under the
//! same retry-budget/deadline rules the supervisor applies after a
//! death. Under `EpsilonMode::External` the worker fills ε buffers
//! per head call; under `EpsilonMode::InWord` the engine's own memory
//! arrays generate ε during the MVM (the chip's dataflow) and the worker
//! reads ε/energy totals back from the engine. Either way this is the
//! paper's parallelism in software: replicated in-word GRNG banks feed
//! independent compute lanes with no shared RNG unit on a bus.

use crate::bayes::{aggregate_mc, UncertaintyReport};
use crate::client::ServeError;
use crate::config::Config;
use crate::coordinator::batch::{effective_t, pack_images, plan_calls, scatter_features, Batch};
use crate::coordinator::elastic::{ElasticCtx, IDLE_TICK, IDLE_TICKS_PER_DECAY, SCALE_UP_DEPTH};
use crate::coordinator::epsilon::EpsilonSource;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferRequest, InferResponse, Reply};
use crate::coordinator::supervisor::{recover_batch, InFlight, ShardHealth, ShardTable, WorkerCtx};
use crate::runtime::{EpsilonMode, InferenceEngine, Manifest};
use crate::util::threadpool::Bounded;
use std::sync::Arc;
use std::time::Duration;

/// Front-end loop: runs until the request queue closes, then closes every
/// shard queue behind itself so the workers drain and exit.
///
/// In elastic mode the dispatcher doubles as the scale-up half of the
/// autoscaler: whenever the admission queue is still backed up after a
/// batch was assembled, it raises every shard's replica target one step
/// toward `server.max_mc_workers` (workers apply the target at their
/// next batch boundary). Scale-*down* lives in the workers — only they
/// observe idleness, since an idle pool never reaches this loop.
pub(crate) fn run_dispatcher(
    requests: Bounded<InferRequest>,
    table: Arc<ShardTable>,
    metrics: Metrics,
    max_batch: usize,
    deadline: Duration,
    elastic: ElasticCtx,
    max_mc_workers: usize,
) {
    let shards = table.shards().max(1);
    let mut next_batch_id: u64 = 0;
    loop {
        // Block for the first request (or shutdown).
        let first = match requests.recv() {
            Some(r) => r,
            None => break,
        };
        let mut members = vec![first];
        let mut closed = false;
        // Fill up to max_batch until the deadline.
        let cutoff = crate::util::clock::now() + deadline;
        while members.len() < max_batch {
            let now = crate::util::clock::now();
            if now >= cutoff {
                break;
            }
            match requests.recv_timeout(cutoff - now) {
                Ok(Some(r)) => members.push(r),
                Ok(None) => break, // deadline
                Err(()) => {
                    // Closed mid-assembly: ship what we have, then exit.
                    closed = true;
                    break;
                }
            }
        }
        next_batch_id += 1;
        // Scale-up check: requests still queued behind a full batch mean
        // the pool is behind demand — raise the replica targets.
        if elastic.enabled && requests.len() >= SCALE_UP_DEPTH {
            for shard in 0..shards {
                if elastic.raise_target(shard, max_mc_workers) {
                    metrics.record_scale_up(shard);
                }
            }
        }
        let target = ((next_batch_id - 1) % shards as u64) as usize;
        let mut pending = Some(Batch {
            id: next_batch_id,
            requests: members,
        });
        'route: loop {
            let health = table.health();
            if health.iter().all(|h| *h == ShardHealth::Dead) {
                // Terminal: no shard will ever come back. Fail every
                // member typed so blocked waits resolve promptly.
                let batch = pending.take().expect("batch still pending");
                for req in batch.requests {
                    metrics.record_failed_shard(target);
                    let _ = req
                        .reply
                        .send(Reply::Failed(ServeError::ShardFailed { shard: target }));
                }
                break 'route;
            }
            for k in 0..shards {
                let i = (target + k) % shards;
                if health[i] != ShardHealth::Healthy {
                    continue;
                }
                // Clone the queue under the table's short lock; block on
                // the send outside it. For a healthy target this is the
                // original backpressure behaviour, unchanged.
                let queue = table.queue(i);
                match queue.send(pending.take().expect("batch still pending")) {
                    Ok(()) => break 'route,
                    // Closed between the health read and the send: the
                    // worker just died — try the next candidate.
                    Err(batch) => pending = Some(batch),
                }
            }
            // No healthy shard accepted (restarts in flight): park
            // briefly and rescan. The supervisor always makes progress —
            // every exit ends in Healthy or Dead — so this terminates.
            std::thread::sleep(Duration::from_millis(1));
        }
        if closed {
            break;
        }
    }
    table.close_all();
}

/// Per-shard metadata resolved from the engine's manifest: only the
/// scalars and input shapes the serve loop needs — the manifest and its
/// `ArtifactSpec`s are never cloned.
struct ShardPlan {
    art_batch: usize,
    pixels_per_img: usize,
    classes: usize,
    /// Input shape of the `features` entry (one input: pixels).
    feat_shape: Vec<usize>,
    /// Input shapes of the `head` entry (features [, ε_w, ε_b]).
    head_shapes: Vec<Vec<usize>>,
}

impl ShardPlan {
    fn from_manifest(m: &Manifest) -> Self {
        let head = m.entry("head").expect("head entry");
        Self {
            art_batch: m.batch,
            pixels_per_img: m.side * m.side,
            classes: m.classes,
            feat_shape: m.entry("features").expect("features entry").inputs[0].1.clone(),
            head_shapes: head.inputs.iter().map(|(_, shape)| shape.clone()).collect(),
        }
    }

    fn head_input_len(&self, i: usize) -> usize {
        self.head_shapes[i].iter().product()
    }
}

/// Worker loop: owns this shard's engine (and, for external-ε backends,
/// its ε source) for its lifetime. Each batch is parked in `slot` while
/// served; on a transient engine error the worker recovers the batch in
/// place (retry budget + original deadline), and on a panic the
/// supervisor recovers it from the slot.
///
/// Batch boundaries are the control points: the worker checks the swap
/// slot (model hot-swap, any mode) and the replica target (elastic mode)
/// between batches, never mid-serve. In elastic mode an *idle* worker
/// polls with a timeout so it can steal a queued batch from a backed-up
/// peer, and decays its own replica pool toward `min_mc_workers` after
/// sustained idleness.
pub(crate) fn run_shard_worker(
    shard: usize,
    mut engine: Box<dyn InferenceEngine>,
    mut engine_gen: u64,
    mut source: Option<Box<dyn EpsilonSource>>,
    batches: Bounded<Batch>,
    slot: InFlight,
    ctx: WorkerCtx,
) {
    let mut plan = ShardPlan::from_manifest(engine.manifest());
    let mut idle_ticks = 0u32;
    loop {
        let batch = if ctx.elastic.enabled {
            match batches.recv_timeout(IDLE_TICK) {
                Ok(Some(b)) => {
                    idle_ticks = 0;
                    b
                }
                Ok(None) => {
                    // Idle tick: steal from a backed-up healthy peer if
                    // possible, otherwise decay toward the replica floor.
                    if let Some(b) = ctx.table.try_steal(shard) {
                        ctx.metrics.record_work_stolen(shard);
                        idle_ticks = 0;
                        b
                    } else {
                        idle_ticks += 1;
                        if idle_ticks >= IDLE_TICKS_PER_DECAY {
                            idle_ticks = 0;
                            let floor = ctx.cfg.server.min_mc_workers.max(1);
                            if ctx.elastic.lower_target(shard, floor) {
                                ctx.metrics.record_scale_down(shard);
                            }
                            apply_replica_target(engine.as_mut(), shard, &ctx);
                        }
                        continue;
                    }
                }
                // Queue closed and drained: normal exit.
                Err(()) => break,
            }
        } else {
            match batches.recv() {
                Some(b) => b,
                None => break,
            }
        };
        maybe_swap_engine(&mut engine, &mut engine_gen, &mut source, &mut plan, shard, &ctx);
        // Applied in *both* modes: in static mode the target only moves
        // on an explicit `Coordinator::set_replica_target`, so this is a
        // no-op on the replay path (and keeps the capacity gauges fresh
        // across a model swap).
        apply_replica_target(engine.as_mut(), shard, &ctx);
        // The guard is held across the whole serve: a panic inside
        // poisons the slot with the batch still parked, which is exactly
        // what the supervisor recovers (poison-tolerant lock there).
        let mut guard = slot.lock();
        *guard = Some(batch);
        let served = serve_batch(
            shard,
            engine.as_mut(),
            &mut source,
            guard.as_ref().expect("batch parked"),
            &ctx.metrics,
            &ctx.cfg,
            &plan,
        );
        let batch = guard.take().expect("batch parked");
        drop(guard);
        // serve_batch records before replying (so snapshots taken after a
        // response are current); repeat here so ε/energy drawn by a batch
        // that *failed* mid-way is still counted. Absolute totals make
        // the double-record idempotent.
        record_energy_counters(shard, engine.as_ref(), &source, &ctx.metrics);
        if served.is_err() {
            recover_batch(batch, shard, &ctx);
        }
    }
}

/// Bring the engine's replica pool to the shard's published target and
/// refresh the capacity gauges. Growth replays the engine's boot-time
/// per-index seed splits and shrink retires ledgers, so this is safe to
/// call at every batch boundary (no-op when already at target).
fn apply_replica_target(engine: &mut dyn InferenceEngine, shard: usize, ctx: &WorkerCtx) {
    let want = ctx.elastic.target(shard);
    if want != engine.replica_count() {
        engine.set_replicas(want);
    }
    ctx.metrics.record_replicas(
        shard,
        engine.replica_count(),
        engine.bytes_shared(),
        engine.bytes_private(),
    );
}

/// Flip to a newly published model if the swap generation moved
/// (publish-drain-flip: the worker finished its previous batch, so the
/// flip is never observed mid-request). The new engine is built in this
/// thread — engines are not `Send` — and must be compatible with the
/// pool: same ε contract as the supply allows, and an artifact batch no
/// smaller than the current plan's (the dispatcher's fused batches are
/// sized at boot). An incompatible or failing swap keeps the old model
/// serving and consumes the generation so it is not retried every batch.
fn maybe_swap_engine(
    engine: &mut Box<dyn InferenceEngine>,
    engine_gen: &mut u64,
    source: &mut Option<Box<dyn EpsilonSource>>,
    plan: &mut ShardPlan,
    shard: usize,
    ctx: &WorkerCtx,
) {
    if ctx.elastic.swap.generation() == *engine_gen {
        return;
    }
    let (gen, factory) = ctx.elastic.swap.current();
    match factory(shard) {
        Ok(new_engine) => {
            if new_engine.manifest().batch < plan.art_batch {
                eprintln!(
                    "[bnn-cim shard {shard}] model swap rejected: artifact batch {} < pool batch {} — keeping the old model",
                    new_engine.manifest().batch,
                    plan.art_batch
                );
                *engine_gen = gen;
                return;
            }
            let new_source = match (new_engine.epsilon_mode(), ctx.supply.source_for(shard)) {
                (EpsilonMode::InWord, _) => None,
                (EpsilonMode::External, Some(s)) => Some(s),
                (EpsilonMode::External, None) => {
                    eprintln!(
                        "[bnn-cim shard {shard}] model swap rejected: engine '{}' needs \
                         external ε but the supply is in-word — keeping the old model",
                        new_engine.name()
                    );
                    *engine_gen = gen;
                    return;
                }
            };
            *plan = ShardPlan::from_manifest(new_engine.manifest());
            *engine = new_engine;
            *source = new_source;
            *engine_gen = gen;
            ctx.metrics.record_model_swap(shard);
        }
        Err(e) => {
            eprintln!(
                "[bnn-cim shard {shard}] model swap failed: {e} — keeping the old model"
            );
            *engine_gen = gen;
        }
    }
}

/// Record this shard's absolute ε/energy totals: external supplies report
/// from the source, in-word engines from their own banks. Called *before*
/// a batch's replies are sent, so a snapshot taken after receiving a
/// response always includes that batch's counters (and two consecutive
/// idle-time snapshots are identical).
fn record_energy_counters(
    shard: usize,
    engine: &dyn InferenceEngine,
    source: &Option<Box<dyn EpsilonSource>>,
    metrics: &Metrics,
) {
    if let Some(src) = source.as_ref() {
        metrics.record_epsilon(shard, src.samples_drawn(), src.energy_j());
    }
    if let Some(rep) = engine.energy_report() {
        metrics.record_engine_energy(shard, rep.total_j, rep.mvm_count, rep.total_ops);
        if engine.epsilon_mode() == EpsilonMode::InWord {
            metrics.record_epsilon(shard, rep.grng_samples, rep.grng_j);
        }
    }
}

/// One fused batch: features once, then packed MC head passes — fresh
/// external ε per call, or engine-internal in-word ε per MVM — then
/// aggregate/defer/reply. `Err` means the engine failed before any reply
/// was sent (engine errors happen before the reply loop), so the caller
/// can redeliver the whole batch without double-replying.
fn serve_batch(
    shard: usize,
    engine: &mut dyn InferenceEngine,
    source: &mut Option<Box<dyn EpsilonSource>>,
    batch: &Batch,
    metrics: &Metrics,
    cfg: &Config,
    plan: &ShardPlan,
) -> Result<(), ()> {
    let reqs = &batch.requests;
    let mc: Vec<usize> = reqs.iter().map(|r| r.mc_samples).collect();
    let t = effective_t(&mc, cfg.model.mc_samples);

    let images: Vec<&[f32]> = reqs.iter().map(|r| r.pixels.as_slice()).collect();
    let packed = pack_images(&images, plan.art_batch, plan.pixels_per_img);

    let exec_before = engine.executions();
    let energy_before = engine.energy_report().map(|r| r.total_j).unwrap_or(0.0);
    let feats = match engine.run("features", &[(&packed, &plan.feat_shape)]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("[bnn-cim shard {shard}] features execution failed: {e}");
            return Err(());
        }
    };

    let in_word = engine.epsilon_mode() == EpsilonMode::InWord;
    let feat_dim = feats.len() / plan.art_batch;
    let (mut eps1, mut eps2) = if in_word {
        // The engine's memory arrays generate ε; no buffers cross the
        // boundary (the head entry takes features only).
        (Vec::new(), Vec::new())
    } else {
        (
            vec![0.0f32; plan.head_input_len(1)],
            vec![0.0f32; plan.head_input_len(2)],
        )
    };
    let mut packed_feats = vec![0.0f32; feats.len()];
    let mut per_request: Vec<Vec<Vec<f64>>> = vec![Vec::with_capacity(t); reqs.len()];
    for owners in plan_calls(reqs.len(), t, plan.art_batch) {
        scatter_features(&feats, &owners, feat_dim, &mut packed_feats);
        let result = if in_word {
            engine.run("head", &[(&packed_feats, &plan.head_shapes[0])])
        } else {
            // Fresh ε for every call (each slot is an independent MC pass).
            let src = source
                .as_mut()
                .expect("external-ε engine requires a source (startup handshake)");
            src.fill(&mut eps1);
            src.fill(&mut eps2);
            engine.run(
                "head",
                &[
                    (&packed_feats, &plan.head_shapes[0]),
                    (&eps1, &plan.head_shapes[1]),
                    (&eps2, &plan.head_shapes[2]),
                ],
            )
        };
        let probs = match result {
            Ok(p) => p,
            Err(e) => {
                eprintln!("[bnn-cim shard {shard}] head execution failed: {e}");
                return Err(());
            }
        };
        for (slot, &req) in owners.iter().enumerate() {
            per_request[req].push(
                probs[slot * plan.classes..(slot + 1) * plan.classes]
                    .iter()
                    .map(|&v| v as f64)
                    .collect(),
            );
        }
    }
    metrics.record_batch(
        shard,
        reqs.len(),
        plan.art_batch,
        t as u64,
        engine.executions() - exec_before,
    );

    // Per-request energy: this batch's tile-energy delta split across its
    // members (each member contributed the same t MC passes). Computed as
    // a delta of cumulative totals — the ledgers are never reset.
    let energy_after = engine.energy_report().map(|r| r.total_j).unwrap_or(0.0);
    let energy_per_req_j = (energy_after - energy_before).max(0.0) / reqs.len().max(1) as f64;

    // Counters must be current before any reply unblocks a caller.
    record_energy_counters(shard, engine, source, metrics);

    for (req, samples) in reqs.iter().zip(per_request.iter()) {
        let pred = aggregate_mc(samples);
        // The deferral policy lives in `UncertaintyReport`, judged per
        // request: a caller's threshold override beats the server-wide
        // default (one fleet, per-caller risk tolerance).
        let threshold = req.defer_threshold.unwrap_or(cfg.model.defer_threshold);
        let uncertainty = UncertaintyReport::from_prediction(&pred, threshold);
        let latency = req.enqueued.elapsed();
        metrics.record_response(latency, uncertainty.deferred);
        // A dead reply channel means the caller dropped its Ticket (or
        // timed out): count the served-but-undeliverable response
        // instead of silently discarding the send error.
        let orphaned = req
            .reply
            .send(Reply::Response(InferResponse {
                id: req.id,
                pred,
                uncertainty,
                latency,
                batch_id: batch.id,
                energy_j: energy_per_req_j,
            }))
            .is_err();
        if orphaned {
            metrics.record_orphaned(shard);
        }
    }
    Ok(())
}
