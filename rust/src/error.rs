//! Library-wide error type.

use thiserror::Error;

/// Unified error for the bnn-cim library.
#[derive(Error, Debug)]
pub enum Error {
    #[error("configuration error: {0}")]
    Config(String),
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),
    #[error("model error: {0}")]
    Model(String),
    #[error("coordinator error: {0}")]
    Coordinator(String),
    #[error("calibration error: {0}")]
    Calibration(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Artifact(e.to_string())
    }
}

impl From<crate::util::toml::TomlError> for Error {
    fn from(e: crate::util::toml::TomlError) -> Self {
        Error::Config(e.to_string())
    }
}
