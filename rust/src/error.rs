//! Library-wide error type (hand-rolled — `thiserror` is unavailable in
//! the offline build environment, like every other external crate).

/// Unified error for the bnn-cim library.
#[derive(Debug)]
pub enum Error {
    Config(String),
    Artifact(String),
    Runtime(String),
    Model(String),
    Coordinator(String),
    Calibration(String),
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(s) => write!(f, "configuration error: {s}"),
            Error::Artifact(s) => write!(f, "artifact error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::Model(s) => write!(f, "model error: {s}"),
            Error::Coordinator(s) => write!(f, "coordinator error: {s}"),
            Error::Calibration(s) => write!(f, "calibration error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Artifact(e.to_string())
    }
}

impl From<crate::util::toml::TomlError> for Error {
    fn from(e: crate::util::toml::TomlError) -> Self {
        Error::Config(e.to_string())
    }
}
