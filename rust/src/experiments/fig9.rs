//! Fig. 9: GRNG operating points vs bias voltage V_R — average latency,
//! pulse-width σ, and energy/sample all fall as V_R rises. The paper
//! overlays chip measurements (≤ ~110 mV limited by IO) with
//! parasitic-annotated simulation; our "measured" series is the
//! stochastic circuit ODE and the "simulated" series the closed form.

use crate::config::GrngConfig;
use crate::grng::physics;
use crate::grng::GrngCell;
use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct BiasPoint {
    pub bias_v: f64,
    /// Closed-form (the "simulation" series).
    pub model_latency_s: f64,
    pub model_sigma_s: f64,
    pub model_energy_j: f64,
    /// Monte-Carlo over the circuit sim (the "measurement" series);
    /// None for points where only the model is evaluated.
    pub meas_latency_s: Option<f64>,
    pub meas_sigma_s: Option<f64>,
}

/// Sweep bias voltages. `mc_n = 0` skips the circuit-ODE series.
pub fn run_bias_sweep(
    cfg: &GrngConfig,
    biases_v: &[f64],
    mc_n: usize,
    seed: u64,
) -> Vec<BiasPoint> {
    biases_v
        .iter()
        .enumerate()
        .map(|(i, &bias)| {
            let mut c = cfg.clone();
            c.bias_v = bias;
            let op = physics::operating_point(&c, bias, c.temp_c);
            let (meas_latency_s, meas_sigma_s) = if mc_n > 0 {
                let mut cell = GrngCell::ideal(&c, seed ^ (i as u64) << 8);
                let mut lat = Summary::new();
                let mut wid = Summary::new();
                for _ in 0..mc_n {
                    let s = cell.sample_circuit();
                    lat.push(s.latency_s);
                    wid.push(s.signed_width_s);
                }
                (Some(lat.mean()), Some(wid.sample_std()))
            } else {
                (None, None)
            };
            BiasPoint {
                bias_v: bias,
                model_latency_s: op.mu_t,
                model_sigma_s: op.pulse_sigma,
                model_energy_j: op.energy_j,
                meas_latency_s,
                meas_sigma_s,
            }
        })
        .collect()
}

/// Default Fig. 9 sweep grid (mV → V).
pub fn default_biases() -> Vec<f64> {
    (0..=10).map(|i| 0.10 + 0.01 * i as f64).collect()
}

pub fn render(points: &[BiasPoint]) -> String {
    let mut s = String::from(
        "Fig. 9 — bias sweep\n  V_R [mV] | latency model/meas [ns] | σ(T_D) model/meas [ns] | E [fJ/Sa]\n",
    );
    for p in points {
        s.push_str(&format!(
            "  {:>7.0} | {:>10.1} / {:<10} | {:>8.2} / {:<8} | {:>7.0}\n",
            p.bias_v * 1e3,
            p.model_latency_s * 1e9,
            p.meas_latency_s
                .map(|v| format!("{:.1}", v * 1e9))
                .unwrap_or_else(|| "—".into()),
            p.model_sigma_s * 1e9,
            p.meas_sigma_s
                .map(|v| format!("{:.2}", v * 1e9))
                .unwrap_or_else(|| "—".into()),
            p.model_energy_j * 1e15,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_has_paper_monotonicity() {
        // Fig. 9: increasing V_R ⇒ latency ↓, σ ↓, energy ↓.
        let cfg = GrngConfig::default();
        let pts = run_bias_sweep(&cfg, &default_biases(), 0, 3);
        for w in pts.windows(2) {
            assert!(w[1].model_latency_s < w[0].model_latency_s);
            assert!(w[1].model_sigma_s < w[0].model_sigma_s);
            assert!(w[1].model_energy_j < w[0].model_energy_j);
        }
    }

    #[test]
    fn measured_series_tracks_model() {
        let cfg = GrngConfig::default();
        let pts = run_bias_sweep(&cfg, &[0.14, 0.18], 300, 5);
        for p in &pts {
            let lat_ratio = p.meas_latency_s.unwrap() / p.model_latency_s;
            assert!(
                (0.9..1.1).contains(&lat_ratio),
                "latency ratio {lat_ratio} at {} mV",
                p.bias_v * 1e3
            );
            let sd_ratio = p.meas_sigma_s.unwrap() / p.model_sigma_s;
            assert!(
                (0.75..1.3).contains(&sd_ratio),
                "σ ratio {sd_ratio} at {} mV",
                p.bias_v * 1e3
            );
        }
    }

    #[test]
    fn typical_point_is_on_the_curve() {
        // 180 mV row should read ≈69 ns / ≈1 ns / ≈360 fJ.
        let cfg = GrngConfig::default();
        let pts = run_bias_sweep(&cfg, &[0.18], 0, 1);
        let p = &pts[0];
        assert!((p.model_latency_s * 1e9 - 69.0).abs() < 12.0);
        assert!((p.model_sigma_s * 1e9 - 1.0).abs() < 0.4);
        assert!((p.model_energy_j * 1e15 - 360.0).abs() < 60.0);
    }
}
