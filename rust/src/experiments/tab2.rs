//! Tab. II: comparison to other work. Our row is *measured* from the
//! simulator (GRNG bank throughput/energy, tile MVM energy, area model);
//! baseline rows quote the published figures attached to each
//! re-implemented algorithm, plus our software microbenchmark of the
//! algorithm itself.

use crate::config::{ChipConfig, TECH_NODE_NM};
use crate::config::energy::TechScale;
use crate::energy::HeadlineMetrics;
use crate::grng::baselines::{all_sources, GaussianSource};
use crate::grng::GrngBank;

#[derive(Clone, Debug)]
pub struct ComparisonRow {
    pub name: String,
    pub implementation: String,
    pub tech_nm: f64,
    pub rng_kind: String,
    pub area_mm2: Option<f64>,
    pub rng_tput_gsa_s: Option<f64>,
    pub rng_eff_pj_per_sa: Option<f64>,
    pub nn_tput_gops: Option<f64>,
    pub nn_eff_fj_per_op: Option<f64>,
    /// Software throughput of our implementation [MSa/s] (context only).
    pub sw_msa_s: Option<f64>,
}

/// Published rows of Tab. II.
pub fn paper_rows() -> Vec<ComparisonRow> {
    vec![
        ComparisonRow {
            name: "[9] Dorrance JSSC'23".into(),
            implementation: "ASIC".into(),
            tech_nm: 22.0,
            rng_kind: "TI-Hadamard".into(),
            area_mm2: Some(3.88),
            rng_tput_gsa_s: Some(4.65),
            rng_eff_pj_per_sa: Some(1.08),
            nn_tput_gops: Some(1200.0),
            nn_eff_fj_per_op: Some(31.0),
            sw_msa_s: None,
        },
        ComparisonRow {
            name: "[10] Shukla TVLSI'21".into(),
            implementation: "Simulated".into(),
            tech_nm: 45.0,
            rng_kind: "Analog Vth".into(),
            area_mm2: None,
            rng_tput_gsa_s: None,
            rng_eff_pj_per_sa: Some(0.37),
            nn_tput_gops: None,
            nn_eff_fj_per_op: None,
            sw_msa_s: None,
        },
        ComparisonRow {
            name: "[11] VIBNN ASPLOS'18".into(),
            implementation: "FPGA".into(),
            tech_nm: 28.0,
            rng_kind: "Wallace".into(),
            area_mm2: None,
            rng_tput_gsa_s: Some(13.63),
            rng_eff_pj_per_sa: Some(38.8),
            nn_tput_gops: Some(59.6),
            nn_eff_fj_per_op: None,
            sw_msa_s: None,
        },
        ComparisonRow {
            name: "[12] Xu OJCAS'21".into(),
            implementation: "FPGA".into(),
            tech_nm: 16.0,
            rng_kind: "Box-Muller".into(),
            area_mm2: None,
            rng_tput_gsa_s: Some(8.88),
            rng_eff_pj_per_sa: Some(5.40),
            nn_tput_gops: None,
            nn_eff_fj_per_op: None,
            sw_msa_s: None,
        },
        ComparisonRow {
            name: "[13] Fan TCAD'22".into(),
            implementation: "FPGA".into(),
            tech_nm: 20.0,
            rng_kind: "MC Dropout".into(),
            area_mm2: None,
            rng_tput_gsa_s: None,
            rng_eff_pj_per_sa: None,
            nn_tput_gops: Some(533.0),
            nn_eff_fj_per_op: Some(24_000.0),
            sw_msa_s: None,
        },
    ]
}

/// Measure OUR row from the simulator, then assemble the full table.
/// `sw_bench_n` samples per baseline software microbenchmark (0 = skip).
pub fn comparison_table(chip: &ChipConfig, sw_bench_n: usize) -> (Vec<ComparisonRow>, HeadlineMetrics) {
    // --- our row, measured ---
    let bank = GrngBank::for_chip(chip);
    let grng_tput = bank.hardware_throughput_sa_s();
    let grng_eff = bank.mean_energy_per_sample();
    let mvm_j = {
        let rep = super::fig12::run_breakdown(chip, 99);
        rep.mvm_energy_j
    };
    let m = HeadlineMetrics::compute(chip, grng_tput, grng_eff, mvm_j);
    let mut rows = vec![ComparisonRow {
        name: "This work (sim)".into(),
        implementation: "ASIC (behavioral sim)".into(),
        tech_nm: TECH_NODE_NM,
        rng_kind: "Analog (thermal, in-word)".into(),
        area_mm2: Some(m.area_mm2),
        rng_tput_gsa_s: Some(m.rng_tput_gsa_s),
        rng_eff_pj_per_sa: Some(m.rng_eff_pj_per_sa),
        nn_tput_gops: Some(m.nn_tput_gops),
        nn_eff_fj_per_op: Some(m.nn_eff_fj_per_op),
        sw_msa_s: None,
    }];
    // --- baselines: published figures + our software microbench ---
    for mut row in paper_rows() {
        if sw_bench_n > 0 {
            if let Some(source) = matching_source(&row.rng_kind) {
                row.sw_msa_s = Some(software_throughput(source, sw_bench_n));
            }
        }
        rows.push(row);
    }
    (rows, m)
}

fn matching_source(kind: &str) -> Option<Box<dyn GaussianSource>> {
    let sources = all_sources(0xBEEF);
    for s in sources {
        let match_ = match kind {
            "TI-Hadamard" => s.name().contains("hadamard"),
            "Wallace" => s.name().contains("wallace"),
            "Box-Muller" => s.name().contains("box-muller"),
            _ => false,
        };
        if match_ {
            return Some(s);
        }
    }
    None
}

fn software_throughput(mut src: Box<dyn GaussianSource>, n: usize) -> f64 {
    let t0 = crate::util::clock::now();
    let mut acc = 0.0;
    for _ in 0..n {
        acc += src.sample();
    }
    std::hint::black_box(acc);
    n as f64 / t0.elapsed().as_secs_f64() / 1e6
}

/// 22 nm-scaled view of our row (Tab. II footnote †).
pub fn scaled_22nm(m: &HeadlineMetrics) -> (f64, f64, f64) {
    let s = TechScale::to_22nm();
    (
        s.throughput(m.rng_tput_gsa_s * 1e9) / 1e9,
        s.throughput(m.rng_tput_gsa_s * 1e9) / 1e9 / s.area(m.area_mm2),
        s.throughput(m.nn_tput_gops * 1e9) / 1e9 / s.area(m.area_mm2),
    )
}

pub fn render(rows: &[ComparisonRow], m: &HeadlineMetrics) -> String {
    let fmt_opt = |v: Option<f64>, digits: usize| {
        v.map(|x| format!("{x:.*}", digits)).unwrap_or_else(|| "—".into())
    };
    let mut s = String::from(
        "Tab. II — comparison to other work\n\
         design                 | impl                  | nm | RNG                      | area mm² | RNG GSa/s | RNG pJ/Sa | NN GOp/s | NN fJ/Op | sw MSa/s\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<22} | {:<21} | {:>2.0} | {:<24} | {:>8} | {:>9} | {:>9} | {:>8} | {:>8} | {:>8}\n",
            r.name,
            r.implementation,
            r.tech_nm,
            r.rng_kind,
            fmt_opt(r.area_mm2, 2),
            fmt_opt(r.rng_tput_gsa_s, 2),
            fmt_opt(r.rng_eff_pj_per_sa, 2),
            fmt_opt(r.nn_tput_gops, 0),
            fmt_opt(r.nn_eff_fj_per_op, 0),
            fmt_opt(r.sw_msa_s, 1),
        ));
    }
    let (t22, tn22, nn22) = scaled_22nm(m);
    s.push_str(&format!(
        "\nnormalized (this work): RNG {:.1} GSa/s/mm², NN {:.0} GOp/s/mm²\n\
         scaled to 22 nm†: RNG {:.1} GSa/s ({:.1} GSa/s/mm²), NN {:.0} GOp/s/mm²\n\
         paper row:  0.45 mm² | 5.12 GSa/s | 0.36 pJ/Sa | 102 GOp/s | 672 fJ/Op | 11.4 GSa/s/mm²\n",
        m.rng_tput_norm_gsa_s_mm2, m.nn_tput_norm_gops_mm2, t22, tn22, nn22
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn our_row_lands_on_paper_headlines() {
        let chip = ChipConfig::default();
        let (rows, m) = comparison_table(&chip, 0);
        assert_eq!(rows.len(), 6);
        // 5.12 GSa/s, 0.36 pJ/Sa, 102 GOp/s, 672 fJ/Op, 0.45 mm² — shapes.
        assert!((3.0..9.0).contains(&m.rng_tput_gsa_s), "{}", m.rng_tput_gsa_s);
        assert!(
            (0.26..0.46).contains(&m.rng_eff_pj_per_sa),
            "{}",
            m.rng_eff_pj_per_sa
        );
        assert!((95.0..110.0).contains(&m.nn_tput_gops), "{}", m.nn_tput_gops);
        assert!((420.0..1000.0).contains(&m.nn_eff_fj_per_op), "{}", m.nn_eff_fj_per_op);
        assert!((0.43..0.47).contains(&m.area_mm2), "{}", m.area_mm2);
    }

    #[test]
    fn headline_comparisons_hold() {
        // The table's message: lowest RNG energy among ASIC/FPGA rows and
        // the best normalized RNG throughput.
        let chip = ChipConfig::default();
        let (rows, m) = comparison_table(&chip, 0);
        let ours = &rows[0];
        for other in &rows[1..] {
            if let (Some(a), Some(b)) = (ours.rng_eff_pj_per_sa, other.rng_eff_pj_per_sa) {
                // [10] is a simulation at 0.37 pJ — we tie/beat it narrowly.
                assert!(
                    a <= b * 1.05,
                    "{} beats us on RNG energy: {a} vs {b}",
                    other.name
                );
            }
        }
        assert!(m.rng_tput_norm_gsa_s_mm2 > 5.0);
    }

    #[test]
    fn scaling_footnote_increases_throughput() {
        let chip = ChipConfig::default();
        let (_, m) = comparison_table(&chip, 0);
        let (t22, tn22, _) = scaled_22nm(&m);
        assert!(t22 > m.rng_tput_gsa_s);
        assert!(tn22 > m.rng_tput_norm_gsa_s_mm2);
    }

    #[test]
    fn render_contains_all_rows() {
        let chip = ChipConfig::default();
        let (rows, m) = comparison_table(&chip, 0);
        let text = render(&rows, &m);
        assert!(text.contains("This work"));
        assert!(text.contains("VIBNN"));
        assert!(text.contains("paper row"));
    }
}
