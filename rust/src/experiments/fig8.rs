//! Fig. 8: GRNG output pulse-width and latency distributions at one bias
//! and temperature configuration, with the normal-probability-plot
//! r-value (paper: r = 0.9967, N = 2500, sub-1 ns pulses unmeasurable).

use crate::config::GrngConfig;
use crate::grng::{GrngCell, GrngSample, QualityReport};
use crate::util::stats::Histogram;

#[derive(Clone, Debug)]
pub struct CharacterizationReport {
    pub quality: QualityReport,
    /// Pulse-width histogram \[ns\].
    pub width_hist: Histogram,
    /// Latency histogram \[ns\].
    pub latency_hist: Histogram,
    /// Fraction of pulses below the 1 ns IO measurement floor.
    pub sub_1ns_frac: f64,
    pub bias_v: f64,
    pub temp_c: f64,
    /// True if the full circuit ODE was integrated (vs fast sampling).
    pub circuit_mode: bool,
}

/// Run the Fig. 8 characterization: `n` conversions of one GRNG cell.
pub fn run_characterization(
    cfg: &GrngConfig,
    n: usize,
    seed: u64,
    circuit_mode: bool,
) -> CharacterizationReport {
    let mut samples = Vec::new();
    run_characterization_into(cfg, n, seed, circuit_mode, &mut samples)
}

/// Into-buffer variant of [`run_characterization`]: reuses `samples`'
/// allocation, so sweep drivers (the `grng` bench, Fig. 9 / Tab. I style
/// loops) characterize many operating points without a fresh
/// `Vec<GrngSample>` per point.
pub fn run_characterization_into(
    cfg: &GrngConfig,
    n: usize,
    seed: u64,
    circuit_mode: bool,
    samples: &mut Vec<GrngSample>,
) -> CharacterizationReport {
    let mut cell = GrngCell::ideal(cfg, seed);
    if circuit_mode {
        cell.characterize_into(n, samples);
    } else {
        cell.sample_fast_into(n, samples);
    }
    let quality = QualityReport::from_samples(samples);
    // Histogram ranges framed around the measured spread.
    let w_span = 4.5 * quality.width_sd_s * 1e9;
    let mut width_hist = Histogram::new(-w_span, w_span, 40);
    let lat_mean = quality.mean_latency_s * 1e9;
    let lat_span = 6.0 * quality.width_sd_s * 1e9;
    let mut latency_hist = Histogram::new(
        (lat_mean - lat_span).max(0.0),
        lat_mean + lat_span,
        40,
    );
    let mut sub_1ns = 0usize;
    for s in samples.iter() {
        width_hist.push(s.signed_width_s * 1e9);
        latency_hist.push(s.latency_s * 1e9);
        if s.signed_width_s.abs() < 1e-9 {
            sub_1ns += 1;
        }
    }
    CharacterizationReport {
        quality,
        width_hist,
        latency_hist,
        sub_1ns_frac: sub_1ns as f64 / n as f64,
        bias_v: cfg.bias_v,
        temp_c: cfg.temp_c,
        circuit_mode,
    }
}

impl CharacterizationReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "Fig. 8 — GRNG characterization @ V_R={:.0} mV, {:.0} °C ({} mode)\n\
             {}\n  sub-1ns fraction: {:.1}% (IO floor)\n\n\
             pulse-width distribution [ns]:\n{}",
            self.bias_v * 1e3,
            self.temp_c,
            if self.circuit_mode { "circuit-ODE" } else { "fast" },
            self.quality.summary_line(),
            self.sub_1ns_frac * 100.0,
            self.width_hist.ascii(46),
        );
        s.push_str(&format!(
            "\nlatency distribution [ns]:\n{}",
            self.latency_hist.ascii(46)
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn characterization_reproduces_fig8_quality() {
        // Paper: Q–Q r = 0.9967 @ N = 2500.
        let cfg = GrngConfig::default();
        let rep = run_characterization(&cfg, 2500, 42, false);
        assert!(rep.quality.qq_r > 0.985, "r = {}", rep.quality.qq_r);
        // Typical point: σ(T_D) ≈ 1.0 ns, latency ≈ 69 ns.
        let sd_ns = rep.quality.width_sd_s * 1e9;
        assert!((0.6..1.8).contains(&sd_ns), "σ = {sd_ns} ns");
        let lat_ns = rep.quality.mean_latency_s * 1e9;
        assert!((55.0..85.0).contains(&lat_ns), "latency = {lat_ns} ns");
        // Energy ≈ 360 fJ.
        let fj = rep.quality.mean_energy_j * 1e15;
        assert!((280.0..440.0).contains(&fj), "E = {fj} fJ");
        assert!(rep.render().contains("Fig. 8"));
    }

    #[test]
    fn circuit_mode_matches_fast_mode() {
        let cfg = GrngConfig::default();
        let fast = run_characterization(&cfg, 800, 1, false);
        let circ = run_characterization(&cfg, 800, 2, true);
        let ratio = circ.quality.width_sd_s / fast.quality.width_sd_s;
        assert!(
            (0.8..1.25).contains(&ratio),
            "circuit/fast σ ratio {ratio}"
        );
    }
}
