//! Fig. 10 & 11: model uncertainty estimation on the synthetic person
//! dataset.
//!
//! - Fig. 10-left: predictive-entropy distributions for correct /
//!   incorrect / OOD classifications — BNN raises entropy exactly where
//!   the deterministic NN stays confidently wrong (paper: APE of
//!   incorrect 0.350 → 0.513, +46.6 %).
//! - Fig. 10-right: calibration curves (paper: ECE 4.88 → 3.31, −32.2 %).
//! - Fig. 11-left: ECE/accuracy vs σ precision (2–4 bits).
//! - Fig. 11-right: accuracy recovery when deferring high-entropy
//!   classifications (paper: +3.5 % average over thresholds 0–0.6).

use crate::bayes::{
    accuracy, accuracy_recovery_curve, aggregate_mc, ape_by_group, ece_percent, EvalPoint,
};
use crate::config::ChipConfig;
use crate::data::{OodKind, SyntheticPerson};
use crate::nn::Model;

/// Which inference arm produced a report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arm {
    /// Deterministic NN (standard MobileNet head).
    DetNn,
    /// Bayesian head, float reference ε.
    BnnFloat,
    /// Bayesian head on the CIM-simulator (quantized, in-word GRNG).
    BnnHw,
}

#[derive(Clone, Debug)]
pub struct UncertaintyReport {
    pub arm: Arm,
    pub n_id: usize,
    pub n_ood: usize,
    pub mc_samples: usize,
    pub accuracy: f64,
    pub ece_percent: f64,
    pub ape_correct: f64,
    pub ape_incorrect: f64,
    pub ape_ood: f64,
    /// (threshold, accuracy-on-kept, kept-fraction).
    pub recovery: Vec<(f64, f64, f64)>,
}

/// Evaluate one arm over `n_id` in-distribution + `n_ood` OOD samples.
pub fn run_uncertainty(
    model: &mut Model,
    chip: &ChipConfig,
    arm: Arm,
    n_id: usize,
    n_ood: usize,
    mc_samples: usize,
    seed: u64,
) -> UncertaintyReport {
    if arm == Arm::BnnHw && !model.head_is_mapped() {
        let mut c = chip.clone();
        c.tile.sigma_bits = c.tile.sigma_bits.min(model.head[0].in_dim); // no-op guard
        model.map_head_to_hardware(&c);
    }
    let gen = SyntheticPerson::new(model.image_side, seed);
    let mut points = Vec::with_capacity(n_id + n_ood);
    let mut eval_one = |pixels: &[f32], label: usize, ood: bool, model: &mut Model| {
        let pred = match arm {
            Arm::DetNn => {
                let feats = model.forward_features(pixels);
                aggregate_mc(&[model.predict_det(&feats)])
            }
            Arm::BnnFloat => model.predict_bayes(pixels, mc_samples, false),
            Arm::BnnHw => model.predict_bayes(pixels, mc_samples, true),
        };
        points.push(EvalPoint { pred, label, ood });
    };
    for i in 0..n_id {
        let s = gen.sample(i as u64);
        eval_one(&s.pixels, s.label, false, model);
    }
    let kinds = [
        OodKind::Fragment,
        OodKind::Texture,
        OodKind::Inverted,
        OodKind::Noise,
    ];
    for i in 0..n_ood {
        let s = gen.ood_sample(i as u64, kinds[i % kinds.len()]);
        eval_one(&s.pixels, 0, true, model);
    }
    let (c, i, o) = ape_by_group(&points);
    let thresholds: Vec<f64> = (0..=12).map(|k| 0.05 * k as f64).collect();
    UncertaintyReport {
        arm,
        n_id,
        n_ood,
        mc_samples,
        accuracy: accuracy(&points),
        ece_percent: ece_percent(&points, 15),
        ape_correct: c,
        ape_incorrect: i,
        ape_ood: o,
        recovery: accuracy_recovery_curve(&points, &thresholds),
    }
}

impl UncertaintyReport {
    /// Mean accuracy gain over the deferral thresholds 0–0.6 relative to
    /// the no-deferral baseline (paper Fig. 11-right: +3.5 %).
    pub fn mean_recovery_gain(&self) -> f64 {
        let gains: Vec<f64> = self
            .recovery
            .iter()
            .filter(|(t, acc, _)| *t <= 0.6 && acc.is_finite())
            .map(|(_, acc, _)| acc - self.accuracy)
            .collect();
        if gains.is_empty() {
            0.0
        } else {
            gains.iter().sum::<f64>() / gains.len() as f64
        }
    }

    pub fn render(&self) -> String {
        format!(
            "{:?}: acc {:.3} | ECE {:.2}% | APE correct {:.3} / incorrect {:.3} / OOD {:.3} | mean recovery gain {:+.3}",
            self.arm,
            self.accuracy,
            self.ece_percent,
            self.ape_correct,
            self.ape_incorrect,
            self.ape_ood,
            self.mean_recovery_gain(),
        )
    }
}

/// Fig. 11-left: sweep σ precision on the hardware arm.
pub fn sigma_bit_sweep(
    weights_path: &std::path::Path,
    chip: &ChipConfig,
    bits: &[usize],
    n_id: usize,
    mc_samples: usize,
    seed: u64,
) -> Vec<(usize, UncertaintyReport)> {
    bits.iter()
        .map(|&b| {
            let mut c = chip.clone();
            c.tile.sigma_bits = b;
            // Fresh model per point: the head must be re-mapped (requantized)
            // for each σ precision.
            let mut model = Model::load(weights_path).expect("weights.json");
            model.map_head_to_hardware(&c);
            let rep = run_uncertainty(&mut model, &c, Arm::BnnHw, n_id, n_id / 3, mc_samples, seed);
            (b, rep)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn trained_model() -> Option<Model> {
        let p = Path::new("artifacts/weights.json");
        if p.exists() {
            Some(Model::load(p).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn bnn_float_beats_det_on_uncertainty() {
        let Some(mut model) = trained_model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let chip = ChipConfig::default();
        let det = run_uncertainty(&mut model, &chip, Arm::DetNn, 150, 60, 1, 5);
        let bnn = run_uncertainty(&mut model, &chip, Arm::BnnFloat, 150, 60, 16, 5);
        // Fig. 10: the BNN raises incorrect/OOD entropy relative to correct.
        assert!(
            bnn.ape_incorrect > bnn.ape_correct,
            "BNN incorrect APE {} should exceed correct {}",
            bnn.ape_incorrect,
            bnn.ape_correct
        );
        assert!(
            bnn.ape_ood > bnn.ape_correct,
            "BNN OOD APE {} should exceed correct {}",
            bnn.ape_ood,
            bnn.ape_correct
        );
        // BNN incorrect-APE uplift vs det (paper: +46.6%).
        assert!(
            bnn.ape_incorrect > det.ape_incorrect,
            "bnn {} vs det {}",
            bnn.ape_incorrect,
            det.ape_incorrect
        );
        // Fig. 10-right: BNN better calibrated (paper: 4.88 → 3.31).
        assert!(
            bnn.ece_percent < det.ece_percent + 1.0,
            "BNN ECE {} should not exceed det {}",
            bnn.ece_percent,
            det.ece_percent
        );
        // Accuracy must not collapse.
        assert!(bnn.accuracy > det.accuracy - 0.08);
    }

    #[test]
    fn hw_arm_preserves_uncertainty() {
        let Some(mut model) = trained_model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let chip = ChipConfig::default();
        let hw = run_uncertainty(&mut model, &chip, Arm::BnnHw, 80, 40, 10, 7);
        assert!(hw.accuracy > 0.6, "hw accuracy {}", hw.accuracy);
        // Analog noise raises baseline entropy everywhere, diluting the
        // OOD contrast relative to the float arm — require the ordering
        // to hold within sampling error.
        assert!(
            hw.ape_ood > hw.ape_correct - 0.05,
            "hw OOD APE {} vs correct {}",
            hw.ape_ood,
            hw.ape_correct
        );
        assert!(
            hw.ape_incorrect > hw.ape_correct,
            "hw incorrect APE {} vs correct {}",
            hw.ape_incorrect,
            hw.ape_correct
        );
        // Fig. 11-right: deferral should help (or at least not hurt).
        assert!(hw.mean_recovery_gain() > -0.02, "{}", hw.mean_recovery_gain());
    }
}
