//! Fig. 12: tile area and energy breakdown for one complete MVM.
//! Paper: SRAM > 63 % of tile energy and 48 % of tile area; synthesized
//! digital (calibration/reduction control, IO buffers) excluded.

use crate::cim::{CimTile, MvmOptions};
use crate::config::ChipConfig;
use crate::energy::{area_breakdown, AreaBreakdown, Component, EnergyLedger};

#[derive(Clone, Debug)]
pub struct BreakdownReport {
    pub energy: EnergyLedger,
    pub area: AreaBreakdown,
    pub mvm_energy_j: f64,
    pub fj_per_op: f64,
    pub ops_per_mvm: usize,
}

/// Run one programmed, calibrated, fresh-ε MVM and collect the ledgers.
pub fn run_breakdown(chip: &ChipConfig, seed: u64) -> BreakdownReport {
    let mut tile = CimTile::new(chip);
    let _ = crate::cim::calibrate(&mut tile, 8, 16);
    // Program representative weights.
    let mut rng = crate::util::rng::Pcg64::new(seed);
    use crate::util::rng::Rng64;
    let n = chip.tile.rows * chip.tile.words_per_row;
    let mu: Vec<f64> = (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) * 200.0).collect();
    let sg: Vec<f64> = (0..n).map(|_| rng.next_f64() * 12.0).collect();
    tile.program_matrix(&mu, &sg);
    tile.ledger.reset();
    let x: Vec<u8> = (0..chip.tile.rows).map(|_| rng.next_below(16) as u8).collect();
    let _ = tile.mvm(&x, MvmOptions::default());
    let energy = tile.ledger.clone();
    let mvm_energy_j = energy.total_j();
    let ops = chip.tile.ops_per_mvm();
    BreakdownReport {
        energy,
        area: area_breakdown(&chip.tile, &chip.area),
        mvm_energy_j,
        fj_per_op: mvm_energy_j / ops as f64 * 1e15,
        ops_per_mvm: ops,
    }
}

impl BreakdownReport {
    pub fn sram_energy_share(&self) -> f64 {
        self.energy.component_j(Component::Sram) / self.mvm_energy_j
    }

    pub fn sram_area_share(&self) -> f64 {
        let sram = self
            .area
            .items
            .iter()
            .find(|(n, _)| *n == "SRAM")
            .map(|(_, a)| *a)
            .unwrap_or(0.0);
        sram / self.area.tile_mm2
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "Fig. 12 — tile energy breakdown (one MVM, {:.1} pJ total, {:.0} fJ/Op over {} ops):\n{}",
            self.mvm_energy_j * 1e12,
            self.fj_per_op,
            self.ops_per_mvm,
            self.energy.ascii_breakdown()
        );
        s.push_str(&format!(
            "\ntile area breakdown ({:.4} mm² tile, {:.3} mm² chip):\n",
            self.area.tile_mm2, self.area.chip_mm2
        ));
        for (name, mm2) in &self.area.items {
            let share = mm2 / self.area.tile_mm2;
            let bar = "#".repeat((share * 40.0).round() as usize);
            s.push_str(&format!(
                "  {:<10} {:>9.5} mm² {:>6.1}% {}\n",
                name,
                mm2,
                share * 100.0,
                bar
            ));
        }
        s.push_str(&format!(
            "\npaper targets: SRAM >63% of energy (got {:.1}%), ≈48% of area (got {:.1}%)\n",
            self.sram_energy_share() * 100.0,
            self.sram_area_share() * 100.0
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_matches_fig12_shares() {
        let chip = ChipConfig::default();
        let rep = run_breakdown(&chip, 7);
        assert!(
            rep.sram_energy_share() > 0.55,
            "SRAM energy share {:.3}",
            rep.sram_energy_share()
        );
        assert!(
            (0.40..0.56).contains(&rep.sram_area_share()),
            "SRAM area share {:.3}",
            rep.sram_area_share()
        );
        // Tab. II NN efficiency ≈ 672 fJ/Op.
        assert!(
            (420.0..1000.0).contains(&rep.fj_per_op),
            "fJ/Op {}",
            rep.fj_per_op
        );
        // GRNG share should be visible but small (in-word efficiency).
        let grng_share = rep.energy.component_j(Component::Grng) / rep.mvm_energy_j;
        assert!(
            (0.05..0.45).contains(&grng_share),
            "GRNG share {grng_share}"
        );
        assert!(rep.render().contains("Fig. 12"));
    }
}
