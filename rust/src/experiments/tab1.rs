//! Tab. I: measured GRNG temperature stability at the low-bias operating
//! point — Q–Q r-value, pulse-width SD, and average latency at
//! 28/40/50/60 °C. The paper's trends: latency ÷2.49, σ ×2.62 from 28 to
//! 60 °C, with the r-value collapsing at 60 °C.

use crate::config::GrngConfig;
use crate::grng::{GrngCell, GrngSample, QualityReport};

#[derive(Clone, Debug)]
pub struct TempPoint {
    pub temp_c: f64,
    pub qq_r: f64,
    pub width_sd_s: f64,
    pub latency_s: f64,
    pub outlier_frac: f64,
}

/// Paper Tab. I rows for comparison (°C, r, SD ns, latency µs).
pub const PAPER_TAB1: [(f64, f64, f64, f64); 4] = [
    (28.0, 0.9292, 197.1, 1.931),
    (40.0, 0.9916, 201.9, 1.297),
    (50.0, 0.9928, 242.2, 1.051),
    (60.0, 0.0736, 515.5, 0.7749),
];

/// Find the bias whose closed-form latency hits `target_s` at `temp_c`.
pub fn bias_for_latency(cfg: &GrngConfig, target_s: f64, temp_c: f64) -> f64 {
    let (mut lo, mut hi) = (0.0f64, 0.5f64);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        let op = crate::grng::physics::operating_point(cfg, mid, temp_c);
        if op.mu_t > target_s {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Run the temperature sweep at a fixed bias (chosen so the 28 °C row
/// lands on the paper's 1.93 µs latency).
pub fn run_temp_sweep(cfg: &GrngConfig, temps_c: &[f64], n: usize, seed: u64) -> Vec<TempPoint> {
    let bias = bias_for_latency(cfg, 1.931e-6, 28.0);
    // One sample buffer reused across the whole sweep (into-buffer
    // characterization — no fresh Vec<GrngSample> per temperature).
    let mut samples: Vec<GrngSample> = Vec::new();
    temps_c
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            let mut c = cfg.clone();
            c.bias_v = bias;
            c.temp_c = t;
            // σ_unit normalization must stay that of the *rated* point so
            // cross-temperature σ are comparable in absolute time.
            c.sigma_unit_s = 1e-9;
            let mut cell = GrngCell::ideal(&c, seed ^ ((i as u64) << 12));
            cell.sample_fast_into(n, &mut samples);
            let q = QualityReport::from_samples(&samples);
            TempPoint {
                temp_c: t,
                qq_r: q.qq_r,
                width_sd_s: q.width_sd_s,
                latency_s: q.mean_latency_s,
                outlier_frac: q.outlier_frac,
            }
        })
        .collect()
}

pub fn render(points: &[TempPoint]) -> String {
    let mut s = String::from(
        "Tab. I — GRNG temperature stability (measured | paper)\n\
           T [°C] | Q-Q r-value      | T_D SD [ns]      | latency [µs]\n",
    );
    for p in points {
        let paper = PAPER_TAB1
            .iter()
            .find(|(t, ..)| (*t - p.temp_c).abs() < 0.5);
        let (pr, psd, plat) = paper
            .map(|&(_, r, sd, lat)| {
                (
                    format!("{r:.4}"),
                    format!("{sd:.1}"),
                    format!("{lat:.3}"),
                )
            })
            .unwrap_or(("—".into(), "—".into(), "—".into()));
        s.push_str(&format!(
            "  {:>6.0} | {:>7.4} | {:>6} | {:>7.1} | {:>6} | {:>7.3} | {:>6}\n",
            p.temp_c,
            p.qq_r,
            pr,
            p.width_sd_s * 1e9,
            psd,
            p.latency_s * 1e6,
            plat,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temp_sweep_matches_tab1_shape() {
        let cfg = GrngConfig::default();
        let pts = run_temp_sweep(&cfg, &[28.0, 40.0, 50.0, 60.0], 2500, 9);
        // Latency decreases monotonically with temperature.
        for w in pts.windows(2) {
            assert!(
                w[1].latency_s < w[0].latency_s,
                "latency must fall with T"
            );
        }
        // σ increases with temperature.
        assert!(
            pts[3].width_sd_s > pts[0].width_sd_s * 1.8,
            "σ 28→60 ratio {}",
            pts[3].width_sd_s / pts[0].width_sd_s
        );
        // Latency ratio ≈ 2.49 (paper); allow the model's 2.0–3.6.
        let lat_ratio = pts[0].latency_s / pts[3].latency_s;
        assert!((2.0..3.6).contains(&lat_ratio), "latency ratio {lat_ratio}");
        // Normality collapses at 60 °C relative to the colder rows.
        assert!(
            pts[3].qq_r < pts[1].qq_r - 0.02,
            "60 °C r {} should be below 40 °C r {}",
            pts[3].qq_r,
            pts[1].qq_r
        );
        assert!(pts[3].outlier_frac > pts[0].outlier_frac);
    }

    #[test]
    fn latencies_near_paper_rows() {
        let cfg = GrngConfig::default();
        let pts = run_temp_sweep(&cfg, &[28.0, 60.0], 1200, 10);
        // 28 °C row is calibrated to 1.93 µs by construction.
        assert!((pts[0].latency_s * 1e6 - 1.931).abs() < 0.12);
        // 60 °C row should land within ~40 % of 0.775 µs.
        assert!((pts[1].latency_s * 1e6 - 0.7749).abs() < 0.35);
    }

    #[test]
    fn bias_solver_converges() {
        let cfg = GrngConfig::default();
        let b = bias_for_latency(&cfg, 69e-9, 28.0);
        assert!((b - 0.18).abs() < 0.01, "bias {b}");
    }
}
