//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Usage:
//! ```no_run
//! use bnn_cim::util::propcheck::{Gen, property};
//! property("addition commutes", 200, |g| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a deterministic seed derived from the property name and
//! case index, so failures are reproducible; on panic the framework reports
//! the failing seed and re-raises. A lightweight shrinking pass retries the
//! failing case with successively "smaller" generator scales to aid
//! debugging (values shrink toward zero / empty).

use crate::util::rng::{Pcg64, Rng64};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Value generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// Scale in (0, 1]; shrinking lowers this so numeric ranges contract.
    scale: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::new(seed),
            scale: 1.0,
        }
    }

    pub fn with_scale(seed: u64, scale: f64) -> Self {
        Self {
            rng: Pcg64::new(seed),
            scale,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.scale).ceil() as u64;
        lo + self.rng.next_below(span.max(1) + 0) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        let mid = (lo + hi) / 2;
        let half = (((hi - lo) / 2) as f64 * self.scale).ceil() as i64;
        let lo2 = (mid - half).max(lo);
        let hi2 = (mid + half).min(hi);
        lo2 + self.rng.next_below((hi2 - lo2 + 1) as u64) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(hi >= lo);
        let mid = 0.5 * (lo + hi);
        let half = 0.5 * (hi - lo) * self.scale;
        (mid - half) + self.rng.next_f64() * 2.0 * half
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_bool(0.5)
    }

    pub fn gaussian(&mut self) -> f64 {
        self.rng.next_gaussian() * self.scale
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }

    pub fn vec_f64(&mut self, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(0, max_len);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(0, max_len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Non-empty variant.
    pub fn vec_f32_nonempty(&mut self, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(1, max_len.max(1));
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

fn seed_for(name: &str, case: usize) -> u64 {
    // FNV-1a over name, mixed with case index.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Run `cases` random cases of property `f`. Panics (with diagnostics) on
/// the first failure after attempting a shrink.
pub fn property<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = seed_for(name, case);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            f(&mut g);
        }));
        if let Err(panic) = result {
            // Shrink: retry same seed at reduced scales, keep smallest failing.
            let mut smallest_failing_scale = 1.0;
            for &scale in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let fails = catch_unwind(AssertUnwindSafe(|| {
                    let mut g = Gen::with_scale(seed, scale);
                    f(&mut g);
                }))
                .is_err();
                if fails {
                    smallest_failing_scale = scale;
                } else {
                    break;
                }
            }
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}, \
                 smallest failing scale {smallest_failing_scale}): {msg}\n\
                 reproduce with: Gen::with_scale({seed:#x}, {smallest_failing_scale})"
            );
        }
    }
}

/// Assert two f64 are within an absolute-or-relative tolerance (mirrors
/// numpy.allclose semantics used by the python-side tests).
pub fn assert_close(a: f64, b: f64, rtol: f64, atol: f64) {
    let ok = (a - b).abs() <= atol + rtol * b.abs();
    assert!(ok, "assert_close failed: {a} vs {b} (rtol={rtol}, atol={atol})");
}

/// Slice version of [`assert_close`].
pub fn assert_all_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for i in 0..a.len() {
        let ok = (a[i] - b[i]).abs() <= atol + rtol * b[i].abs();
        assert!(
            ok,
            "assert_all_close failed at index {i}: {} vs {} (rtol={rtol}, atol={atol})",
            a[i], b[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("trivially true", 50, |g| {
            let _ = g.f64_in(0.0, 1.0);
        });
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    fn deterministic_seeds() {
        let mut a = Gen::new(seed_for("x", 3));
        let mut b = Gen::new(seed_for("x", 3));
        assert_eq!(a.u64(), b.u64());
        assert_ne!(
            Gen::new(seed_for("x", 3)).u64(),
            Gen::new(seed_for("x", 4)).u64()
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        property("always fails", 10, |g| {
            let v = g.f64_in(0.0, 1.0);
            assert!(v < 0.0, "v={v} is not negative");
        });
    }

    #[test]
    fn ranges_respected() {
        property("usize_in stays in range", 100, |g| {
            let v = g.usize_in(5, 10);
            assert!((5..=10).contains(&v), "v={v}");
        });
        property("f64_in stays in range", 100, |g| {
            let v = g.f64_in(-2.0, 3.0);
            assert!((-2.0..=3.0).contains(&v), "v={v}");
        });
        property("i64_in stays in range", 100, |g| {
            let v = g.i64_in(-7, 4);
            assert!((-7..=4).contains(&v), "v={v}");
        });
    }

    #[test]
    fn assert_close_behaviour() {
        assert_close(1.0, 1.0 + 1e-9, 1e-6, 0.0);
        let r = std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-6, 0.0));
        assert!(r.is_err());
    }
}
