//! Pseudo-random number generation, from scratch.
//!
//! crates.io is unreachable in this build environment, so the library ships
//! its own generators. This is thematically apt: the paper under
//! reproduction is an RNG paper, and several of its comparison baselines
//! (Wallace, Box–Muller, Hadamard) are implemented on top of the uniform
//! sources defined here.
//!
//! Layout:
//! - [`SplitMix64`] — seeding/stream-splitting generator (Steele et al.).
//! - [`Pcg64`] — default general-purpose generator (PCG XSL-RR 128/64).
//! - [`Xoshiro256`] — fast fallback used in hot Monte-Carlo loops.
//! - [`Philox4x32`] — counter-based generator mirroring the L1 Pallas
//!   kernel's in-kernel sampler, so Rust and JAX can cross-check streams.
//! - Gaussian sampling: [`Normal`] (Ziggurat) and [`box_muller`].
//! - Scalar special functions: [`erf`], [`erfc`], [`norm_cdf`],
//!   [`norm_quantile`].

/// Core trait for 64-bit uniform generators.
pub trait Rng64 {
    /// Next raw 64 uniformly distributed bits.
    fn next_u64(&mut self) -> u64;

    /// Next 32 uniform bits (upper half of a 64-bit draw).
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // 53 high bits / 2^53
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform f64 in (0, 1] — never exactly zero (safe for `ln`).
    #[inline]
    fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16777216.0)
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift rejection.
    #[inline]
    fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64_wide(x, n);
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Random boolean with probability `p` of `true`.
    #[inline]
    fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via the Ziggurat tables.
    #[inline]
    fn next_gaussian(&mut self) -> f64 {
        ziggurat_normal(self)
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T])
    where
        Self: Sized,
    {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64_wide(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

// ---------------------------------------------------------------------------
// SplitMix64
// ---------------------------------------------------------------------------

/// SplitMix64: tiny, equidistributed, used for seeding other generators.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

/// The golden-ratio increment of SplitMix64's Weyl sequence.
const SPLITMIX_GAMMA: u64 = 0x9E3779B97F4A7C15;

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent child seed (stream split).
    pub fn split(&mut self) -> u64 {
        self.next_u64()
    }

    /// Advance the stream by `n` draws in O(1): the state is a Weyl
    /// sequence (`state += γ` per draw), so jumping is one multiply.
    /// `jump(n)` followed by `split()` returns exactly the `(n+1)`-th
    /// sequential `split()`.
    pub fn jump(&mut self, n: u64) {
        self.state = self.state.wrapping_add(SPLITMIX_GAMMA.wrapping_mul(n));
    }
}

impl Rng64 for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(SPLITMIX_GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

// ---------------------------------------------------------------------------
// PCG64 (XSL-RR 128/64)
// ---------------------------------------------------------------------------

/// PCG XSL-RR 128/64: the library's default generator. Passes BigCrush,
/// 2^128 period, cheap jump-ahead via stream selection.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E39CB94B95BDB)
    }

    /// Distinct `stream` values give statistically independent sequences.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut pcg = Self {
            state: (s0 << 64) | s1,
            inc: (((stream as u128) << 1) | 1),
        };
        pcg.state = pcg.state.wrapping_mul(PCG_MULT).wrapping_add(pcg.inc);
        pcg
    }

    /// Fork an independent generator (different stream, derived state).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.rotate_left(17);
        Pcg64::with_stream(seed, tag.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
}

impl Rng64 for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }
}

// ---------------------------------------------------------------------------
// xoshiro256++
// ---------------------------------------------------------------------------

/// xoshiro256++ — fastest generator here; used inside tight Monte-Carlo
/// loops (GRNG circuit noise integration) where draw cost matters.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Rebuild a generator from raw state words (the inverse of
    /// [`Xoshiro256::state`]) — how [`XoshiroLanes`] hands a lane back as
    /// a standalone generator.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    /// The raw state words (SoA transposition in [`XoshiroLanes`]).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Jump ahead 2^128 draws — used to partition one seed across threads.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut t = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    t[0] ^= self.s[0];
                    t[1] ^= self.s[1];
                    t[2] ^= self.s[2];
                    t[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = t;
    }
}

impl Rng64 for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = &mut self.s;
        xoshiro_lane_step(s0, s1, s2, s3)
    }
}

/// One xoshiro256++ update on four state words held anywhere — the single
/// definition of the step shared by [`Xoshiro256`], [`XoshiroLanes`], and
/// the remainder loops of the `arch` block kernels, so the scalar and SIMD
/// paths cannot drift apart.
#[inline]
pub fn xoshiro_lane_step(s0: &mut u64, s1: &mut u64, s2: &mut u64, s3: &mut u64) -> u64 {
    let result = (*s0).wrapping_add(*s3).rotate_left(23).wrapping_add(*s0);
    let t = *s1 << 17;
    *s2 ^= *s0;
    *s3 ^= *s1;
    *s1 ^= *s2;
    *s0 ^= *s3;
    *s2 ^= t;
    *s3 = (*s3).rotate_left(45);
    result
}

// ---------------------------------------------------------------------------
// XoshiroLanes — SoA bank of xoshiro256++ streams
// ---------------------------------------------------------------------------

/// A bank of independent xoshiro256++ streams stored
/// structure-of-arrays: state word k of every stream lives in one
/// contiguous `Vec<u64>`, so advancing *all* streams by one draw is a
/// vertical SIMD pass ([`XoshiroLanes::fill_next_u64`], dispatched
/// through `crate::arch`). This is the GRNG bank's state layout: the
/// block fill draws one uniform per cell across the whole bank in one
/// vectorized sweep, then any cell whose ziggurat attempt rejects
/// continues scalar on its own lane via [`XoshiroLanes::lane`] — so every
/// stream's draw *sequence* is exactly what a standalone [`Xoshiro256`]
/// would produce (integer step, bit-identical at every SIMD level).
#[derive(Clone, Debug, Default)]
pub struct XoshiroLanes {
    s0: Vec<u64>,
    s1: Vec<u64>,
    s2: Vec<u64>,
    s3: Vec<u64>,
}

impl XoshiroLanes {
    pub fn with_capacity(n: usize) -> Self {
        Self {
            s0: Vec::with_capacity(n),
            s1: Vec::with_capacity(n),
            s2: Vec::with_capacity(n),
            s3: Vec::with_capacity(n),
        }
    }

    /// Append a stream seeded exactly like `Xoshiro256::new(seed)`.
    pub fn push_seed(&mut self, seed: u64) {
        self.set_push(&Xoshiro256::new(seed));
    }

    fn set_push(&mut self, st: &Xoshiro256) {
        let s = st.state();
        self.s0.push(s[0]);
        self.s1.push(s[1]);
        self.s2.push(s[2]);
        self.s3.push(s[3]);
    }

    pub fn len(&self) -> usize {
        self.s0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.s0.is_empty()
    }

    /// Overwrite stream `i`'s state with `st`'s.
    pub fn set(&mut self, i: usize, st: &Xoshiro256) {
        let s = st.state();
        self.s0[i] = s[0];
        self.s1[i] = s[1];
        self.s2[i] = s[2];
        self.s3[i] = s[3];
    }

    /// Stream `i` as a standalone generator (copy of its state).
    pub fn get(&self, i: usize) -> Xoshiro256 {
        Xoshiro256::from_state([self.s0[i], self.s1[i], self.s2[i], self.s3[i]])
    }

    /// Advance stream `i` by one draw (scalar step on the SoA words).
    #[inline]
    pub fn next_u64(&mut self, i: usize) -> u64 {
        xoshiro_lane_step(
            &mut self.s0[i],
            &mut self.s1[i],
            &mut self.s2[i],
            &mut self.s3[i],
        )
    }

    /// Advance *every* stream by one draw, writing stream `i`'s output to
    /// `out[i]` — the vertical SIMD sweep (AVX2 4 streams/step, NEON 2,
    /// scalar fallback), bit-identical to calling
    /// [`XoshiroLanes::next_u64`] on each stream in turn.
    pub fn fill_next_u64(&mut self, out: &mut [u64]) {
        assert_eq!(out.len(), self.len());
        crate::arch::xoshiro_block(&mut self.s0, &mut self.s1, &mut self.s2, &mut self.s3, out);
    }

    /// Borrow stream `i` as an [`Rng64`] — the per-cell continuation
    /// handle for rejection loops (draws advance the lane in place).
    #[inline]
    pub fn lane(&mut self, i: usize) -> XoshiroLane<'_> {
        debug_assert!(i < self.len());
        XoshiroLane { lanes: self, i }
    }
}

/// Mutable view of one [`XoshiroLanes`] stream as an [`Rng64`].
pub struct XoshiroLane<'a> {
    lanes: &'a mut XoshiroLanes,
    i: usize,
}

impl Rng64 for XoshiroLane<'_> {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.lanes.next_u64(self.i)
    }
}

// ---------------------------------------------------------------------------
// Philox 4x32-10 (counter-based)
// ---------------------------------------------------------------------------

/// Philox 4x32-10 counter-based generator (Salmon et al., SC'11).
///
/// This mirrors the in-kernel sampler used by the L1 Pallas GRNG kernel:
/// both sides derive bits from `(key, counter)` pairs, so the Rust
/// coordinator can reproduce exactly the ε-stream a compiled artifact will
/// see, enabling bit-level cross-checks between L3 and L1.
#[derive(Clone, Debug)]
pub struct Philox4x32 {
    key: [u32; 2],
    counter: [u32; 4],
}

const PHILOX_M0: u32 = 0xD2511F53;
const PHILOX_M1: u32 = 0xCD9E8D57;
const PHILOX_W0: u32 = 0x9E3779B9;
const PHILOX_W1: u32 = 0xBB67AE85;

impl Philox4x32 {
    pub fn new(key: u64) -> Self {
        Self {
            key: [key as u32, (key >> 32) as u32],
            counter: [0; 4],
        }
    }

    /// Position the counter explicitly (random access into the stream).
    pub fn at(key: u64, counter: u128) -> Self {
        Self {
            key: [key as u32, (key >> 32) as u32],
            counter: [
                counter as u32,
                (counter >> 32) as u32,
                (counter >> 64) as u32,
                (counter >> 96) as u32,
            ],
        }
    }

    /// One 10-round block: 128 bits out for the current counter.
    pub fn block(&self) -> [u32; 4] {
        let mut c = self.counter;
        let mut k = self.key;
        for _ in 0..10 {
            let (hi0, lo0) = mul_u32_wide(PHILOX_M0, c[0]);
            let (hi1, lo1) = mul_u32_wide(PHILOX_M1, c[2]);
            c = [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0];
            k[0] = k[0].wrapping_add(PHILOX_W0);
            k[1] = k[1].wrapping_add(PHILOX_W1);
        }
        c
    }

    fn advance(&mut self) {
        for i in 0..4 {
            self.counter[i] = self.counter[i].wrapping_add(1);
            if self.counter[i] != 0 {
                break;
            }
        }
    }
}

#[inline]
fn mul_u32_wide(a: u32, b: u32) -> (u32, u32) {
    let wide = (a as u64) * (b as u64);
    ((wide >> 32) as u32, wide as u32)
}

impl Rng64 for Philox4x32 {
    fn next_u64(&mut self) -> u64 {
        let b = self.block();
        self.advance();
        ((b[0] as u64) << 32) | (b[1] as u64)
    }
}

// ---------------------------------------------------------------------------
// Gaussian sampling
// ---------------------------------------------------------------------------

/// Classic Box–Muller transform: two uniforms → two independent N(0,1).
///
/// Exposed publicly because the paper's comparison table includes an FPGA
/// Box–Muller GRNG ([12] Xu et al.); `grng::baselines::box_muller` wraps
/// this with that design's cost model.
#[inline]
pub fn box_muller<R: Rng64>(rng: &mut R) -> (f64, f64) {
    let u1 = rng.next_f64_open();
    let u2 = rng.next_f64();
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * core::f64::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

// Ziggurat for the standard normal (Marsaglia & Tsang, 128 layers).
const ZIG_LAYERS: usize = 128;
const ZIG_R: f64 = 3.442619855899;
const ZIG_V: f64 = 9.91256303526217e-3;

struct ZigTables {
    x: [f64; ZIG_LAYERS + 1],
    y: [f64; ZIG_LAYERS],
}

fn build_zig_tables() -> ZigTables {
    let mut x = [0.0f64; ZIG_LAYERS + 1];
    let mut y = [0.0f64; ZIG_LAYERS];
    let f = |v: f64| (-0.5 * v * v).exp();
    x[0] = ZIG_R;
    y[0] = f(ZIG_R);
    x[1] = ZIG_R;
    for i in 2..=ZIG_LAYERS {
        let yi = y[i - 2] + ZIG_V / x[i - 1];
        // invert f: x = sqrt(-2 ln y)
        x[i] = if yi >= 1.0 { 0.0 } else { (-2.0 * yi.ln()).sqrt() };
        if i - 1 < ZIG_LAYERS {
            y[i - 1] = yi;
        }
    }
    ZigTables { x, y }
}

fn zig_tables() -> &'static ZigTables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<ZigTables> = OnceLock::new();
    TABLES.get_or_init(build_zig_tables)
}

/// One ziggurat attempt from pre-drawn uniform `bits`. `Some(z)` on
/// accept, `None` on wedge rejection (caller draws fresh bits and
/// retries). Slow branches (tail, wedge test) draw further uniforms from
/// `rng` — the *same* stream the bits came from, so looping this with
/// `bits = rng.next_u64()` consumes exactly [`ziggurat_normal`]'s draw
/// sequence. Split out so the GRNG block fill can feed a SIMD-generated
/// uniform block through the identical accept/reject arithmetic
/// (bit-identical to the scalar sampler by construction).
#[inline]
pub fn ziggurat_step<R: Rng64 + ?Sized>(rng: &mut R, bits: u64) -> Option<f64> {
    let t = zig_tables();
    let i = (bits & 0x7F) as usize; // layer
    let sign = if bits & 0x80 != 0 { -1.0 } else { 1.0 };
    let u = (bits >> 11) as f64 * (1.0 / 9007199254740992.0);
    let x = u * t.x[i];
    if x < t.x[i + 1] {
        return Some(sign * x);
    }
    if i == 0 {
        // tail: Marsaglia's method
        loop {
            let u1 = rng.next_f64_open();
            let u2 = rng.next_f64_open();
            let xt = -u1.ln() / ZIG_R;
            let yt = -u2.ln();
            if 2.0 * yt >= xt * xt {
                return Some(sign * (ZIG_R + xt));
            }
        }
    }
    let f_x = (-0.5 * x * x).exp();
    let y_lo = if i < ZIG_LAYERS { t.y[i] } else { 0.0 };
    let y_above = if i == 0 {
        (-0.5 * ZIG_R * ZIG_R).exp()
    } else {
        t.y[i - 1]
    };
    let v = y_above + rng.next_f64() * (y_lo - y_above);
    if v < f_x {
        Some(sign * x)
    } else {
        None
    }
}

/// Ziggurat normal sampler — ~1.03 uniform draws per sample on average.
pub fn ziggurat_normal<R: Rng64 + ?Sized>(rng: &mut R) -> f64 {
    loop {
        let bits = rng.next_u64();
        if let Some(z) = ziggurat_step(rng, bits) {
            return z;
        }
    }
}

/// Parameterized normal distribution sampler.
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    pub mean: f64,
    pub std: f64,
}

impl Normal {
    pub fn new(mean: f64, std: f64) -> Self {
        Self { mean, std }
    }

    #[inline]
    pub fn sample<R: Rng64>(&self, rng: &mut R) -> f64 {
        self.mean + self.std * ziggurat_normal(rng)
    }

    pub fn sample_n<R: Rng64>(&self, rng: &mut R, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

// ---------------------------------------------------------------------------
// Special functions
// ---------------------------------------------------------------------------

/// Error function, Abramowitz–Stegun 7.1.26 refinement (|err| < 1.2e-7),
/// then one Newton step against the exact derivative for ~1e-12.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    // A&S 7.1.26
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let mut y = 1.0 - poly * (-x * x).exp();
    // Newton refinement: d/dx erf = 2/sqrt(pi) e^{-x^2}; invert via series
    // residual estimated by one halley-free correction using erfc symmetry.
    let deriv = 2.0 / core::f64::consts::PI.sqrt() * (-x * x).exp();
    if deriv > 1e-300 {
        // One fixed-point polish using a higher-order rational approx of erfc
        let e = erfc_rational(x);
        y = 1.0 - e;
    }
    sign * y
}

/// Complementary error function (high accuracy rational approximation).
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        2.0 - erfc(-x)
    } else {
        erfc_rational(x)
    }
}

/// W. J. Cody-style rational approximation of erfc for x >= 0.
fn erfc_rational(x: f64) -> f64 {
    // For small x use 1 - erf series; for large use continued-fraction-like
    // rational approx (Numerical Recipes erfccheb equivalent).
    let z = x.abs();
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    // Chebyshev coefficients (NR 3rd ed. §6.2.2)
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0f64;
    let mut dd = 0.0f64;
    for j in (1..COF.len()).rev() {
        let tmp = d;
        d = ty * d - dd + COF[j];
        dd = tmp;
    }
    let ans = t * (-z * z + 0.5 * (COF[0] + ty * d) - dd).exp();
    ans
}

/// Standard normal CDF Φ(x).
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / core::f64::consts::SQRT_2)
}

/// Standard normal quantile Φ⁻¹(p) — Acklam's algorithm + one Halley step.
pub fn norm_quantile(p: f64) -> f64 {
    assert!(
        p > 0.0 && p < 1.0,
        "norm_quantile requires p in (0,1), got {p}"
    );
    // Acklam coefficients
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    let x = if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Halley refinement
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * core::f64::consts::PI).sqrt() * (0.5 * x * x).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the published SplitMix64 algorithm, seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn splitmix_jump_matches_sequential_draws() {
        for &seed in &[0u64, 1, 42, 0xDEAD_BEEF, u64::MAX] {
            let mut serial = SplitMix64::new(seed);
            let draws: Vec<u64> = (0..16).map(|_| serial.split()).collect();
            for (n, &want) in draws.iter().enumerate() {
                let mut jumped = SplitMix64::new(seed);
                jumped.jump(n as u64);
                assert_eq!(jumped.split(), want, "seed {seed} jump {n}");
            }
        }
    }

    #[test]
    fn pcg_deterministic_and_stream_independent() {
        let a: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b, "same seed must give same stream");
        let c: Vec<u64> = {
            let mut r = Pcg64::with_stream(42, 7);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c, "different streams must differ");
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Pcg64::new(1);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn next_below_unbiased() {
        let mut r = Xoshiro256::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn ziggurat_moments() {
        let mut r = Pcg64::new(3);
        let n = 400_000;
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        let mut m4 = 0.0;
        for _ in 0..n {
            let z = r.next_gaussian();
            m1 += z;
            m2 += z * z;
            m4 += z * z * z * z;
        }
        let nf = n as f64;
        m1 /= nf;
        m2 /= nf;
        m4 /= nf;
        assert!(m1.abs() < 0.01, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var={m2}");
        assert!((m4 - 3.0).abs() < 0.12, "kurtosis={m4}");
    }

    #[test]
    fn box_muller_moments() {
        let mut r = Pcg64::new(11);
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let (a, b) = box_muller(&mut r);
            m1 += a + b;
            m2 += a * a + b * b;
        }
        let nf = (2 * n) as f64;
        assert!((m1 / nf).abs() < 0.02);
        assert!((m2 / nf - 1.0).abs() < 0.03);
    }

    #[test]
    fn philox_counter_random_access() {
        let mut seq = Philox4x32::new(0xDEADBEEF);
        let draws: Vec<u64> = (0..5).map(|_| seq.next_u64()).collect();
        // Random access at counter=3 must match the 4th sequential draw.
        let mut ra = Philox4x32::at(0xDEADBEEF, 3);
        assert_eq!(ra.next_u64(), draws[3]);
    }

    #[test]
    fn erf_reference_points() {
        // Reference values (Mathematica): erf(0.5)=0.5204998778, erf(1)=0.8427007929,
        // erf(2)=0.9953222650
        assert!((erf(0.5) - 0.5204998778).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-7);
        assert!((erf(2.0) - 0.9953222650).abs() < 1e-7);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-7);
    }

    #[test]
    fn quantile_roundtrip() {
        for &p in &[0.001, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999] {
            let x = norm_quantile(p);
            let back = norm_cdf(x);
            assert!((back - p).abs() < 1e-9, "p={p} x={x} back={back}");
        }
    }

    #[test]
    fn xoshiro_lanes_mirror_standalone_generators() {
        let mut lanes = XoshiroLanes::with_capacity(5);
        let mut refs: Vec<Xoshiro256> = Vec::new();
        for i in 0..5u64 {
            lanes.push_seed(100 + i);
            refs.push(Xoshiro256::new(100 + i));
        }
        assert_eq!(lanes.len(), 5);
        // Block sweep == per-lane steps == standalone generators.
        let mut out = vec![0u64; 5];
        lanes.fill_next_u64(&mut out);
        for (i, r) in refs.iter_mut().enumerate() {
            assert_eq!(out[i], r.next_u64(), "lane {i}");
        }
        // Scalar continuation via the Rng64 view keeps the same stream.
        for (i, r) in refs.iter_mut().enumerate() {
            let mut lane = lanes.lane(i);
            assert_eq!(lane.next_u64(), r.next_u64(), "lane {i} continuation");
            assert_eq!(lane.next_gaussian(), r.next_gaussian(), "lane {i} gaussian");
        }
        // get/set round-trip the raw state.
        let snap = lanes.get(3);
        assert_eq!(snap.state(), refs[3].state());
        lanes.set(3, &Xoshiro256::new(9));
        assert_eq!(lanes.get(3).state(), Xoshiro256::new(9).state());
    }

    /// The pre-refactor monolithic sampler, kept verbatim as the oracle
    /// for the `ziggurat_step` split: same arithmetic, same draw order.
    fn ziggurat_normal_reference<R: Rng64>(rng: &mut R) -> f64 {
        let t = zig_tables();
        loop {
            let bits = rng.next_u64();
            let i = (bits & 0x7F) as usize;
            let sign = if bits & 0x80 != 0 { -1.0 } else { 1.0 };
            let u = (bits >> 11) as f64 * (1.0 / 9007199254740992.0);
            let x = u * t.x[i];
            if x < t.x[i + 1] {
                return sign * x;
            }
            if i == 0 {
                loop {
                    let u1 = rng.next_f64_open();
                    let u2 = rng.next_f64_open();
                    let xt = -u1.ln() / ZIG_R;
                    let yt = -u2.ln();
                    if 2.0 * yt >= xt * xt {
                        return sign * (ZIG_R + xt);
                    }
                }
            }
            let f_x = (-0.5 * x * x).exp();
            let y_lo = if i < ZIG_LAYERS { t.y[i] } else { 0.0 };
            let y_above = if i == 0 {
                (-0.5 * ZIG_R * ZIG_R).exp()
            } else {
                t.y[i - 1]
            };
            let v = y_above + rng.next_f64() * (y_lo - y_above);
            if v < f_x {
                return sign * x;
            }
        }
    }

    #[test]
    fn ziggurat_step_refactor_is_bit_identical() {
        // The split sampler (ziggurat_step fed by a fresh draw each
        // attempt — the seam the GRNG block fill injects SIMD uniforms
        // through) must reproduce the pre-refactor monolithic sampler
        // bit for bit, including the stream positions after rejections.
        let mut a = Xoshiro256::new(0xFACE);
        let mut b = Xoshiro256::new(0xFACE);
        for step in 0..50_000 {
            let want = ziggurat_normal_reference(&mut a);
            let got = ziggurat_normal(&mut b);
            assert_eq!(want.to_bits(), got.to_bits(), "sample {step}");
            assert_eq!(a.state(), b.state(), "stream position {step}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
