//! The single sanctioned wall-clock read.
//!
//! Replay-pinned modules (`arch`, `bayes`, `cim`, `fault`, `grng`, `nn`,
//! `edge::json`, `util::rng` — see `tools/invariant-lint/contracts.toml`)
//! must be time-free: `invariant-lint` rule R3 rejects any `Instant` or
//! `SystemTime` token there, and `clippy.toml` disallows
//! `Instant::now`/`SystemTime::now` everywhere else so that timing-aware
//! code (deadlines, metrics, benches) funnels through this one function.
//! That makes "who reads the clock" a one-line grep, which is what keeps
//! the determinism audit in DESIGN.md §11 honest.

use std::time::Instant;

/// Current monotonic instant. The only call site of `Instant::now` in
/// the crate; everything scheduling against wall time goes through here.
#[inline]
#[allow(clippy::disallowed_methods)]
pub fn now() -> Instant {
    Instant::now()
}
