//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with typed accessors and defaults, positional arguments, and generated
//! `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declarative option spec used for help text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{name} expects a number, got '{s}'"))),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{name} expects an integer, got '{s}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{name} expects an integer, got '{s}'"))),
        }
    }

    /// Comma-separated list of floats, e.g. `--temps 28,40,50,60`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{name}: bad number '{p}'")))
                })
                .collect(),
        }
    }
}

/// Parse a raw argument list (no program name) into [`Args`].
///
/// Grammar: `--name=value` | `--name value` | `--flag` (when `value` would
/// start with `--` or the arg list ends) | positional.
pub fn parse_args<I: IntoIterator<Item = String>>(raw: I) -> Args {
    let mut args = Args::default();
    let items: Vec<String> = raw.into_iter().collect();
    let mut i = 0;
    while i < items.len() {
        let item = &items[i];
        if let Some(name) = item.strip_prefix("--") {
            if let Some(eq) = name.find('=') {
                args.opts
                    .insert(name[..eq].to_string(), name[eq + 1..].to_string());
            } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                args.opts.insert(name.to_string(), items[i + 1].clone());
                i += 1;
            } else {
                args.flags.push(name.to_string());
            }
        } else {
            args.positional.push(item.clone());
        }
        i += 1;
    }
    args
}

/// A subcommand with its option specs (for help generation).
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Render help text for a set of commands.
pub fn render_help(program: &str, about: &str, commands: &[Command]) -> String {
    let mut s = format!("{program} — {about}\n\nUSAGE:\n  {program} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n");
    for c in commands {
        s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
    }
    s.push_str("\nRun with <COMMAND> --help for command options.\n");
    s
}

/// Render help for one command.
pub fn render_cmd_help(program: &str, cmd: &Command) -> String {
    let mut s = format!("{program} {} — {}\n\nOPTIONS:\n", cmd.name, cmd.about);
    for o in &cmd.opts {
        let left = if o.is_flag {
            format!("--{}", o.name)
        } else {
            format!("--{} <v>", o.name)
        };
        let default = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  {:<22} {}{}\n", left, o.help, default));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        // NB: a bare `--flag` followed by a non-`--` token consumes it as a
        // value (documented grammar), so positionals come first.
        let a = parse_args(sv(&[
            "pos1", "--samples", "2500", "--bias=0.18", "--verbose", "--temps", "28,40",
        ]));
        assert_eq!(a.get("samples"), Some("2500"));
        assert_eq!(a.get_f64("bias", 0.0).unwrap(), 0.18);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_f64_list("temps", &[]).unwrap(), vec![28.0, 40.0]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse_args(sv(&["--n", "abc"]));
        assert!(a.get_usize("n", 1).is_err());
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn trailing_flag() {
        let a = parse_args(sv(&["--fast"]));
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn help_renders() {
        let cmds = [Command {
            name: "grng-char",
            about: "characterize GRNG",
            opts: vec![OptSpec {
                name: "samples",
                help: "number of samples",
                default: Some("2500"),
                is_flag: false,
            }],
        }];
        let h = render_help("bnn-cim", "BNN accelerator", &cmds);
        assert!(h.contains("grng-char"));
        let ch = render_cmd_help("bnn-cim", &cmds[0]);
        assert!(ch.contains("--samples"));
        assert!(ch.contains("default: 2500"));
    }
}
