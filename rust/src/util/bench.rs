//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target in Cargo.toml sets `harness = false` and drives
//! this module: warmup, calibrated iteration counts, robust statistics
//! (median + MAD), and a machine-readable JSON report appended to
//! `target/bench-results.json` so EXPERIMENTS.md numbers are traceable.

use crate::util::json::Json;
use crate::util::stats;
use std::time::Duration;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Nanoseconds per iteration (median across measurement batches).
    pub ns_per_iter: f64,
    /// Median absolute deviation of the per-batch estimate, ns.
    pub mad_ns: f64,
    pub iters_total: u64,
    /// Optional caller-supplied throughput denominator ("elements per iter").
    pub elements_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.elements_per_iter
            .map(|e| e * 1e9 / self.ns_per_iter.max(1e-12))
    }
}

/// Bench runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub batches: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            batches: 12,
        }
    }
}

/// Quick options for long-running end-to-end cases.
impl BenchOpts {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            batches: 6,
        }
    }
}

/// A bench suite accumulates results and prints a table at the end.
pub struct Suite {
    pub title: String,
    pub results: Vec<BenchResult>,
    pub notes: Vec<(String, String)>,
    opts: BenchOpts,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        // `cargo bench -- --quick` support.
        let quick = std::env::args().any(|a| a == "--quick");
        Self {
            title: title.to_string(),
            results: Vec::new(),
            notes: Vec::new(),
            opts: if quick {
                BenchOpts::quick()
            } else {
                BenchOpts::default()
            },
        }
    }

    pub fn opts(&self) -> BenchOpts {
        self.opts
    }

    /// Time `f` (called once per iteration). `black_box` its output yourself
    /// if the compiler could elide the work.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_elements(name, None, &mut f)
    }

    /// Like [`Suite::bench`], reporting a throughput based on `elements` per iter.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        elements: f64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_with_elements(name, Some(elements), &mut f)
    }

    fn bench_with_elements(
        &mut self,
        name: &str,
        elements: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup and iteration-count calibration.
        let mut iters_per_batch = 1u64;
        let warmup_end = crate::util::clock::now() + self.opts.warmup;
        loop {
            let t0 = crate::util::clock::now();
            for _ in 0..iters_per_batch {
                f();
            }
            let dt = t0.elapsed();
            if crate::util::clock::now() >= warmup_end {
                // Aim for measure/batches per batch.
                let target = self.opts.measure.as_nanos() as f64 / self.opts.batches as f64;
                let per_iter = dt.as_nanos() as f64 / iters_per_batch as f64;
                iters_per_batch = ((target / per_iter.max(1.0)).ceil() as u64).max(1);
                break;
            }
            if dt < Duration::from_millis(5) {
                iters_per_batch = iters_per_batch.saturating_mul(2);
            }
        }
        // Measurement batches.
        let mut estimates = Vec::with_capacity(self.opts.batches);
        let mut total_iters = 0u64;
        for _ in 0..self.opts.batches {
            let t0 = crate::util::clock::now();
            for _ in 0..iters_per_batch {
                f();
            }
            let dt = t0.elapsed();
            estimates.push(dt.as_nanos() as f64 / iters_per_batch as f64);
            total_iters += iters_per_batch;
        }
        let med = stats::median(&estimates);
        let deviations: Vec<f64> = estimates.iter().map(|e| (e - med).abs()).collect();
        let mad = stats::median(&deviations);
        let result = BenchResult {
            name: name.to_string(),
            ns_per_iter: med,
            mad_ns: mad,
            iters_total: total_iters,
            elements_per_iter: elements,
        };
        println!(
            "  {:<44} {:>14}  ±{:<10} {}",
            name,
            fmt_ns(med),
            fmt_ns(mad),
            result
                .throughput_per_sec()
                .map(|t| format!("[{}/s]", fmt_si(t)))
                .unwrap_or_default()
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record a free-form derived metric (energy, area, accuracy) that the
    /// report should carry alongside timings.
    pub fn note(&mut self, key: &str, value: String) {
        println!("  {key:<44} {value}");
        self.notes.push((key.to_string(), value));
    }

    /// Print header. Call once at the start of a bench binary.
    pub fn header(&self) {
        println!("\n=== {} ===", self.title);
    }

    /// This suite's machine-readable report entry.
    fn to_json(&self) -> Json {
        let mut cases = Vec::new();
        for r in &self.results {
            let mut o = Json::obj();
            o.set("name", Json::Str(r.name.clone()))
                .set("ns_per_iter", Json::Num(r.ns_per_iter))
                .set("mad_ns", Json::Num(r.mad_ns))
                .set("iters", Json::Num(r.iters_total as f64));
            if let Some(t) = r.throughput_per_sec() {
                o.set("throughput_per_sec", Json::Num(t));
            }
            cases.push(o);
        }
        let mut notes = Json::obj();
        for (k, v) in &self.notes {
            notes.set(k, Json::Str(v.clone()));
        }
        let mut entry = Json::obj();
        entry
            .set("suite", Json::Str(self.title.clone()))
            .set("cases", Json::Arr(cases))
            .set("notes", notes);
        entry
    }

    /// Write this suite's report (plus caller-supplied `extra` fields) as
    /// a standalone JSON file — e.g. the repo-root `BENCH_serving.json`
    /// that seeds the perf trajectory across PRs. Overwrites.
    pub fn write_report(&self, path: &std::path::Path, extra: Vec<(&str, Json)>) {
        let mut entry = self.to_json();
        for (k, v) in extra {
            entry.set(k, v);
        }
        if let Err(e) = entry.write_file(path) {
            eprintln!("warning: could not write {}: {e}", path.display());
        }
    }

    /// Append machine-readable results to `target/bench-results.json`.
    pub fn finish(&self) {
        let entry = self.to_json();
        let path = std::path::Path::new("target/bench-results.json");
        let mut all = match Json::read_file(path) {
            Ok(Json::Arr(a)) => a,
            _ => Vec::new(),
        };
        // Replace any previous entry for this suite (idempotent re-runs).
        all.retain(|e| e.get("suite").and_then(|s| s.as_str()) != Some(self.title.as_str()));
        all.push(entry);
        let _ = Json::Arr(all).write_file(path);
        println!("=== {} done ({} cases) ===\n", self.title, self.results.len());
    }
}

/// Repo-root path for a standalone bench artifact (e.g.
/// `BENCH_serving.json`): bench and test binaries run with CWD = the
/// crate root (`rust/`), one level below the repo root; fall back to the
/// CWD when run from elsewhere.
pub fn repo_root_artifact(name: &str) -> std::path::PathBuf {
    if std::path::Path::new("../ROADMAP.md").exists() {
        std::path::Path::new("..").join(name)
    } else {
        std::path::PathBuf::from(name)
    }
}

/// One serving-sweep measurement — the single authoritative schema for
/// `BENCH_serving.json` sweep entries, shared by
/// `benches/sharded_serving.rs` (calibrated) and `tests/backend_smoke.rs`
/// (smoke-scale seed).
pub struct ServingSweepPoint {
    pub backend: &'static str,
    pub workers: usize,
    /// MC-parallel replicas per cim engine (`server.mc_workers`).
    pub mc_workers: usize,
    pub requests: usize,
    pub mc_samples: usize,
    pub req_per_s: f64,
    pub batches: u64,
    pub mean_fill: f64,
    pub eps_fj_per_sample: f64,
    pub engine_fj_per_op: f64,
}

impl ServingSweepPoint {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("backend", Json::Str(self.backend.to_string()))
            .set("workers", Json::Num(self.workers as f64))
            .set("mc_workers", Json::Num(self.mc_workers as f64))
            .set("requests", Json::Num(self.requests as f64))
            .set("mc_samples", Json::Num(self.mc_samples as f64))
            .set("req_per_s", Json::Num(self.req_per_s))
            .set("batches", Json::Num(self.batches as f64))
            .set("mean_fill", Json::Num(self.mean_fill))
            .set("eps_fj_per_sample", Json::Num(self.eps_fj_per_sample))
            .set("engine_fj_per_op", Json::Num(self.engine_fj_per_op));
        o
    }
}

/// Drive a pre-queued load of `n_req` synthetic requests through a fresh
/// coordinator pool on `cfg.server.backend` and return the measured sweep
/// point. The single measurement harness behind both writers of
/// `BENCH_serving.json` (`benches/sharded_serving.rs` and
/// `tests/backend_smoke.rs`): engine bring-up happens inside the builder's
/// `start`, excluded from the timed window; the queue is sized so the
/// whole load pre-queues (`submit_many` preserves batch fusion) and
/// throughput measures the pool, not the client.
pub fn measure_serving_sweep(cfg: &crate::config::Config, n_req: usize) -> ServingSweepPoint {
    use crate::client::{Coordinator, Infer};
    use crate::data::SyntheticPerson;

    let mut cfg = cfg.clone();
    cfg.server.queue_capacity = cfg.server.queue_capacity.max(n_req + 8);
    let coord = Coordinator::builder(cfg.clone()).start().expect("boot backend");
    let gen = SyntheticPerson::new(cfg.model.image_side, 7);
    // Pre-generate so the dataset is not on the measured path.
    let imgs: Vec<Vec<f32>> = (0..n_req as u64).map(|i| gen.sample(i).pixels).collect();
    let t0 = crate::util::clock::now();
    let tickets = coord
        .submit_many(imgs.into_iter().map(Infer::new))
        .expect("queue sized for full load");
    for ticket in tickets {
        ticket.wait_timeout(Duration::from_secs(600)).expect("response");
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    coord.shutdown();
    ServingSweepPoint {
        backend: cfg.server.backend.name(),
        workers: cfg.server.workers,
        mc_workers: cfg.server.mc_workers,
        requests: n_req,
        mc_samples: cfg.model.mc_samples,
        req_per_s: n_req as f64 / dt.max(1e-9),
        batches: m.batches,
        mean_fill: m.mean_batch_fill,
        eps_fj_per_sample: m.epsilon_fj_per_sample(),
        engine_fj_per_op: m.engine_j_per_op() * 1e15,
    }
}

/// Quick-and-dirty wallclock estimate: run `f` until `target` elapses
/// (at least `min_iters` times) and return ns/iter. Coarser than
/// [`Suite::bench`] but cheap enough to run inside `cargo test`, where
/// the smoke-scale `BENCH_cim_mvm.json` seed is produced.
pub fn quick_ns_per_iter<F: FnMut()>(mut f: F, min_iters: u64, target: Duration) -> f64 {
    // Untimed warmup so lazy caches (e.g. the tile plane cache) and
    // branch predictors settle before measurement.
    for _ in 0..min_iters.clamp(1, 16) {
        f();
    }
    let t0 = crate::util::clock::now();
    let mut iters = 0u64;
    loop {
        f();
        iters += 1;
        if iters >= min_iters && t0.elapsed() >= target {
            break;
        }
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// One measured case of the MVM hot-path comparison — the single
/// authoritative schema for `BENCH_cim_mvm.json` cases, shared by
/// `benches/cim_mvm.rs` (calibrated, release) and `tests/mvm_props.rs`
/// (smoke-scale seed emitted by `cargo test`).
pub struct MvmBenchCase {
    /// e.g. "legacy_aos", "soa", "soa_batch" — suffixed by ε mode.
    pub case: String,
    pub ns_per_mvm: f64,
    pub mvm_per_s: f64,
    pub ops_per_s: f64,
}

impl MvmBenchCase {
    pub fn new(case: &str, ns_per_mvm: f64, ops_per_mvm: f64) -> Self {
        let mvm_per_s = 1e9 / ns_per_mvm.max(1e-9);
        Self {
            case: case.to_string(),
            ns_per_mvm,
            mvm_per_s,
            ops_per_s: mvm_per_s * ops_per_mvm,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(self.case.clone()))
            .set("ns_per_mvm", Json::Num(self.ns_per_mvm))
            .set("mvm_per_s", Json::Num(self.mvm_per_s))
            .set("ops_per_s", Json::Num(self.ops_per_s));
        o
    }
}

/// Write the repo-root `BENCH_cim_mvm.json` report: the measured cases
/// plus the headline single-thread speedups of the SoA fast path over the
/// pre-PR legacy AoS baseline (same tile, same options). Respects the
/// calibrated-over-smoke precedence via [`is_calibrated_report`] at the
/// caller. The report self-stamps `simd_level` — the dispatch arm active
/// when it was written (`crate::arch::active_level`), which is what the
/// CI bench gate keys its SIMD-speedup requirement on.
pub fn write_mvm_report(
    path: &std::path::Path,
    source: &str,
    rows: usize,
    words: usize,
    cases: &[MvmBenchCase],
    speedups: &[(&str, f64)],
) {
    let mut doc = Json::obj();
    doc.set("source", Json::Str(source.to_string()))
        .set(
            "simd_level",
            Json::Str(crate::arch::active_level().to_string()),
        )
        .set("rows", Json::Num(rows as f64))
        .set("words", Json::Num(words as f64))
        .set(
            "cases",
            Json::Arr(cases.iter().map(|c| c.to_json()).collect()),
        );
    for (k, v) in speedups {
        doc.set(k, Json::Num(*v));
    }
    if let Err(e) = doc.write_file(path) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("  wrote {}", path.display());
    }
}

/// One measured case of the GRNG bank fill comparison — the single
/// authoritative schema for `BENCH_grng_fill.json` cases, shared by
/// `benches/grng.rs` (calibrated, release) and `tests/grng_props.rs`
/// (smoke-scale seed emitted by `cargo test`).
pub struct GrngFillCase {
    /// e.g. "block_soa", "block_soa_planes", "legacy_aos".
    pub case: String,
    /// Wallclock per whole-bank conversion (rows × words samples).
    pub ns_per_fill: f64,
    pub ns_per_sample: f64,
    pub sa_per_s: f64,
}

impl GrngFillCase {
    pub fn new(case: &str, ns_per_fill: f64, cells: usize) -> Self {
        let ns_per_sample = ns_per_fill / (cells as f64).max(1.0);
        Self {
            case: case.to_string(),
            ns_per_fill,
            ns_per_sample,
            sa_per_s: 1e9 / ns_per_sample.max(1e-12),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(self.case.clone()))
            .set("ns_per_fill", Json::Num(self.ns_per_fill))
            .set("ns_per_sample", Json::Num(self.ns_per_sample))
            .set("sa_per_s", Json::Num(self.sa_per_s));
        o
    }
}

/// Write the repo-root `BENCH_grng_fill.json` report: measured bank-fill
/// cases plus headline fields — at minimum `gsa_per_s` (block-path
/// software throughput, comparable against the paper's 5.12 GSa/s
/// hardware number) and `speedup_block_vs_legacy` (SoA block sampler vs
/// the retained per-cell AoS walk, same streams, bit-identical outputs).
/// Self-stamps `simd_level` like [`write_mvm_report`].
pub fn write_grng_fill_report(
    path: &std::path::Path,
    source: &str,
    rows: usize,
    words: usize,
    cases: &[GrngFillCase],
    headlines: &[(&str, f64)],
) {
    let mut doc = Json::obj();
    doc.set("source", Json::Str(source.to_string()))
        .set(
            "simd_level",
            Json::Str(crate::arch::active_level().to_string()),
        )
        .set("rows", Json::Num(rows as f64))
        .set("words", Json::Num(words as f64))
        .set(
            "cases",
            Json::Arr(cases.iter().map(|c| c.to_json()).collect()),
        );
    for (k, v) in headlines {
        doc.set(k, Json::Num(*v));
    }
    if let Err(e) = doc.write_file(path) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("  wrote {}", path.display());
    }
}

/// True when `path` already holds a calibrated (bench-written) serving
/// report that a smoke-scale writer must not overwrite. The precedence
/// rule lives here, in one place: calibrated reports mark themselves with
/// a `source` field that does not contain "smoke"; a file that is absent,
/// unreadable, or missing that mark is fair game for reseeding.
pub fn is_calibrated_report(path: &std::path::Path) -> bool {
    match Json::read_file(path) {
        Ok(doc) => doc
            .get("source")
            .and_then(|s| s.as_str())
            .map(|s| !s.contains("smoke"))
            .unwrap_or(false),
        Err(_) => false,
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box wrapper,
/// kept here so bench code has a single import point).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a rate with SI prefixes.
pub fn fmt_si(x: f64) -> String {
    let (v, p) = if x >= 1e12 {
        (x / 1e12, "T")
    } else if x >= 1e9 {
        (x / 1e9, "G")
    } else if x >= 1e6 {
        (x / 1e6, "M")
    } else if x >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{v:.2} {p}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut suite = Suite::new("selftest");
        suite.opts = BenchOpts {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            batches: 4,
        };
        let r = suite
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..1000u64 {
                    acc = acc.wrapping_add(black_box(i));
                }
                black_box(acc);
            })
            .clone();
        assert!(r.ns_per_iter > 0.0);
        assert!(r.iters_total >= 4);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert_eq!(fmt_ns(12_300.0), "12.30 µs");
        assert!(fmt_si(5.12e9).starts_with("5.12 G"));
    }

    #[test]
    fn calibrated_report_detection() {
        let dir = std::path::Path::new("target");
        let _ = std::fs::create_dir_all(dir);
        let p = dir.join("bench-selftest-report.json");
        let _ = std::fs::remove_file(&p);
        assert!(!is_calibrated_report(&p), "absent file is fair game");
        let mut doc = Json::obj();
        doc.set("source", Json::Str("smoke sweep (test profile)".to_string()));
        doc.write_file(&p).unwrap();
        assert!(!is_calibrated_report(&p), "smoke-marked file is fair game");
        let mut doc = Json::obj();
        doc.set("source", Json::Str("calibrated bench".to_string()));
        doc.write_file(&p).unwrap();
        assert!(is_calibrated_report(&p), "calibrated report must win");
        let doc = Json::obj();
        doc.write_file(&p).unwrap();
        assert!(!is_calibrated_report(&p), "unmarked file is fair game");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn sweep_point_serializes_schema() {
        let point = ServingSweepPoint {
            backend: "cim",
            workers: 2,
            mc_workers: 4,
            requests: 24,
            mc_samples: 4,
            req_per_s: 100.0,
            batches: 6,
            mean_fill: 0.75,
            eps_fj_per_sample: 360.0,
            engine_fj_per_op: 672.0,
        };
        let j = point.to_json();
        assert_eq!(j.get("backend").and_then(|v| v.as_str()), Some("cim"));
        assert_eq!(j.get("workers").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("req_per_s").and_then(|v| v.as_f64()), Some(100.0));
        assert_eq!(j.get("eps_fj_per_sample").and_then(|v| v.as_f64()), Some(360.0));
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            ns_per_iter: 100.0,
            mad_ns: 0.0,
            iters_total: 1,
            elements_per_iter: Some(50.0),
        };
        // 50 elements / 100 ns = 5e8 per second
        assert!((r.throughput_per_sec().unwrap() - 5e8).abs() < 1.0);
    }
}
