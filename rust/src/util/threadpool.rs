//! Work-stealing-free, fixed-size thread pool plus a `scope`-style parallel
//! map. Tokio is unavailable offline; the Monte-Carlo sweeps use these
//! primitives (std threads + channels), and the sharded coordinator is
//! built on [`Bounded`]: one request queue in front of the dispatcher and
//! one small batch queue per shard worker.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool. Jobs are closures; results flow back through
/// whatever channel the caller captures.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// `n = 0` means "number of available CPUs".
    pub fn new(n: usize) -> Self {
        let n = if n == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
        } else {
            n
        };
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("bnn-cim-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                job();
                                pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx,
            workers,
            pending,
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("thread pool has shut down");
    }

    /// Busy-ish wait until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        while self.pending.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map over an index range: applies `f(i)` for `i in 0..n` on up to
/// `threads` OS threads, returning results in index order. Falls back to a
/// serial loop for `threads <= 1` or tiny `n` (avoids spawn overhead — this
/// matters on the single-core CI machine this reproduction targets).
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let threads = threads.min(n);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let v = f(i);
                // Each index is written exactly once; the mutex serializes
                // only the (cheap) pointer write, not `f`.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("par_map slot")).collect()
}

/// Parallel map over *disjoint mutable slots*: applies `f(i, &mut items[i])`
/// for every slot on up to `threads` OS threads, returning results in slot
/// order. Each slot is handed to exactly one worker (a mutex-guarded
/// `iter_mut` dispenses disjoint `&mut` borrows), so stateful items — e.g.
/// MC-sampling replicas that advance private RNG streams — run in parallel
/// without interior mutability. Results depend only on which slots each
/// item processes, never on thread scheduling. Serial fallback for
/// `threads <= 1` or a single slot (avoids spawn overhead on the
/// single-core CI machine).
pub fn par_map_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let threads = threads.min(n);
    let dispenser = Mutex::new(items.iter_mut().enumerate());
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let next = {
                    let mut it = dispenser.lock().unwrap();
                    it.next()
                };
                match next {
                    Some((i, item)) => {
                        let v = f(i, item);
                        // One writer per index; the mutex serializes only
                        // the (cheap) slot write, not `f`.
                        let mut guard = slots.lock().unwrap();
                        guard[i] = Some(v);
                    }
                    None => break,
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("par_map_mut slot")).collect()
}

/// A simple bounded MPMC channel built on std primitives, used by the
/// coordinator for backpressure (send blocks when the queue is full).
pub struct Bounded<T> {
    inner: Arc<BoundedInner<T>>,
}

struct BoundedInner<T> {
    queue: Mutex<std::collections::VecDeque<T>>,
    cap: usize,
    not_full: std::sync::Condvar,
    not_empty: std::sync::Condvar,
    closed: Mutex<bool>,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> Bounded<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            inner: Arc::new(BoundedInner {
                queue: Mutex::new(std::collections::VecDeque::new()),
                cap,
                not_full: std::sync::Condvar::new(),
                not_empty: std::sync::Condvar::new(),
                closed: Mutex::new(false),
            }),
        }
    }

    /// Blocking send; returns Err(item) if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if *self.inner.closed.lock().unwrap() {
                return Err(item);
            }
            if q.len() < self.inner.cap {
                q.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            q = self.inner.not_full.wait(q).unwrap();
        }
    }

    /// Non-blocking send; Err(item) if full or closed.
    pub fn try_send(&self, item: T) -> Result<(), T> {
        if *self.inner.closed.lock().unwrap() {
            return Err(item);
        }
        let mut q = self.inner.queue.lock().unwrap();
        if q.len() < self.inner.cap {
            q.push_back(item);
            self.inner.not_empty.notify_one();
            Ok(())
        } else {
            Err(item)
        }
    }

    /// Blocking receive; None when closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if *self.inner.closed.lock().unwrap() {
                return None;
            }
            q = self.inner.not_empty.wait(q).unwrap();
        }
    }

    /// Receive with a timeout; Ok(None) on timeout, Err(()) when closed.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Result<Option<T>, ()> {
        let deadline = crate::util::clock::now() + dur;
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                self.inner.not_full.notify_one();
                return Ok(Some(item));
            }
            if *self.inner.closed.lock().unwrap() {
                return Err(());
            }
            let now = crate::util::clock::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self
                .inner
                .not_empty
                .wait_timeout(q, deadline - now)
                .unwrap();
            q = guard;
            if res.timed_out() && q.is_empty() {
                return Ok(None);
            }
        }
    }

    /// Drain up to `max` items without blocking (batcher fast path).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut q = self.inner.queue.lock().unwrap();
        let take = q.len().min(max);
        let items: Vec<T> = q.drain(..take).collect();
        if take > 0 {
            self.inner.not_full.notify_all();
        }
        items
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`Bounded::close`] has been called (queued items may
    /// still be draining via `recv`).
    pub fn is_closed(&self) -> bool {
        *self.inner.closed.lock().unwrap()
    }

    pub fn close(&self) {
        *self.inner.closed.lock().unwrap() = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_ordered() {
        let out = par_map(100, 4, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_serial_fallback() {
        let out = par_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn par_map_mut_mutates_each_slot_once_in_order() {
        let mut items: Vec<u64> = (0..64).collect();
        let out = par_map_mut(&mut items, 4, |i, v| {
            *v += 100;
            (i as u64, *v)
        });
        assert_eq!(out.len(), 64);
        for (i, (idx, v)) in out.iter().enumerate() {
            assert_eq!(*idx, i as u64);
            assert_eq!(*v, i as u64 + 100);
        }
        assert_eq!(items, (100..164).collect::<Vec<u64>>());
    }

    #[test]
    fn par_map_mut_serial_fallback() {
        let mut items = vec![1, 2, 3];
        let out = par_map_mut(&mut items, 1, |_, v| *v * 2);
        assert_eq!(out, vec![2, 4, 6]);
    }

    #[test]
    fn bounded_backpressure() {
        let ch = Bounded::new(2);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert!(ch.try_send(3).is_err(), "queue should be full");
        assert_eq!(ch.recv(), Some(1));
        ch.try_send(3).unwrap();
        assert_eq!(ch.drain_up_to(10), vec![2, 3]);
        assert!(ch.is_empty());
    }

    #[test]
    fn bounded_close_drains() {
        let ch = Bounded::new(4);
        assert!(!ch.is_closed());
        ch.send("a").unwrap();
        ch.close();
        assert!(ch.is_closed());
        assert!(ch.send("b").is_err());
        assert_eq!(ch.recv(), Some("a"));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn bounded_cross_thread() {
        let ch = Bounded::new(1);
        let ch2 = ch.clone();
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = ch2.recv() {
                got.push(v);
            }
            got
        });
        for i in 0..50 {
            ch.send(i).unwrap();
        }
        ch.close();
        let got = h.join().unwrap();
        assert_eq!(got, (0..50).collect::<Vec<i32>>());
    }

    #[test]
    fn recv_timeout_returns_none() {
        let ch: Bounded<u8> = Bounded::new(1);
        let r = ch.recv_timeout(std::time::Duration::from_millis(10));
        assert_eq!(r, Ok(None));
    }
}
