//! Statistics utilities: summary statistics, histograms, linear regression,
//! normality diagnostics (Q–Q r-value as used in the paper's Fig. 8/Tab. I,
//! Kolmogorov–Smirnov, Jarque–Bera), and calibration binning support.

use crate::util::rng::{norm_cdf, norm_quantile};

/// Running summary of a sample (Welford's algorithm — numerically stable).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    m3: f64,
    m4: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            m3: 0.0,
            m4: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        let n1 = self.n as f64;
        self.n += 1;
        let n = self.n as f64;
        let delta = x - self.mean;
        let delta_n = delta / n;
        let delta_n2 = delta_n * delta_n;
        let term1 = delta * delta_n * n1;
        self.mean += delta_n;
        self.m4 += term1 * delta_n2 * (n * n - 3.0 * n + 3.0) + 6.0 * delta_n2 * self.m2
            - 4.0 * delta_n * self.m3;
        self.m3 += term1 * delta_n * (n - 2.0) - 3.0 * delta_n * self.m2;
        self.m2 += term1;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        s.extend(xs);
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn sample_std(&self) -> f64 {
        self.sample_variance().sqrt()
    }

    /// Sample skewness g1.
    pub fn skewness(&self) -> f64 {
        let n = self.n as f64;
        if self.m2 == 0.0 {
            return 0.0;
        }
        (n.sqrt() * self.m3) / self.m2.powf(1.5)
    }

    /// Excess kurtosis g2 (0 for a normal distribution).
    pub fn excess_kurtosis(&self) -> f64 {
        let n = self.n as f64;
        if self.m2 == 0.0 {
            return 0.0;
        }
        n * self.m4 / (self.m2 * self.m2) - 3.0
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        self.sample_std() / (self.n as f64).sqrt()
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    Summary::from_slice(xs).std()
}

/// Percentile via linear interpolation on the sorted copy, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Simple least-squares linear regression y = a + b·x.
/// Returns (intercept a, slope b, correlation r).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..x.len() {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r = if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt() * (n / n)
    };
    (a, b, r)
}

/// Q–Q (normal probability plot) r-value: the Pearson correlation between
/// sorted sample values and the theoretical normal quantiles at plotting
/// positions (i − 0.375)/(n + 0.25) (Blom). This is the normality statistic
/// the paper reports in Fig. 8 (r = 0.9967, N = 2500) and Tab. I.
pub fn qq_r_value(samples: &[f64]) -> f64 {
    let n = samples.len();
    assert!(n >= 3, "qq_r_value needs at least 3 samples");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let theo: Vec<f64> = (0..n)
        .map(|i| {
            let p = (i as f64 + 1.0 - 0.375) / (n as f64 + 0.25);
            norm_quantile(p)
        })
        .collect();
    let (_, _, r) = linreg(&theo, &sorted);
    r
}

/// One-sample Kolmogorov–Smirnov statistic against N(mean, std).
pub fn ks_statistic_normal(samples: &[f64], mu: f64, sigma: f64) -> f64 {
    let n = samples.len();
    assert!(n > 0 && sigma > 0.0);
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut d = 0.0f64;
    for (i, &x) in sorted.iter().enumerate() {
        let cdf = norm_cdf((x - mu) / sigma);
        let ecdf_hi = (i + 1) as f64 / n as f64;
        let ecdf_lo = i as f64 / n as f64;
        d = d.max((ecdf_hi - cdf).abs()).max((cdf - ecdf_lo).abs());
    }
    d
}

/// Approximate p-value for the KS statistic (asymptotic Kolmogorov dist).
pub fn ks_p_value(d: f64, n: usize) -> f64 {
    let en = (n as f64).sqrt();
    let lambda = (en + 0.12 + 0.11 / en) * d;
    // Two-term sum is plenty for the sizes used here.
    let mut p = 0.0;
    let mut sign = 1.0;
    for j in 1..=100 {
        let term = sign * (-2.0 * (j as f64 * lambda).powi(2)).exp();
        p += term;
        sign = -sign;
        if term.abs() < 1e-12 {
            break;
        }
    }
    (2.0 * p).clamp(0.0, 1.0)
}

/// Jarque–Bera normality statistic: n/6 (S² + K²/4).
pub fn jarque_bera(samples: &[f64]) -> f64 {
    let s = Summary::from_slice(samples);
    let n = s.count() as f64;
    let sk = s.skewness();
    let ku = s.excess_kurtosis();
    n / 6.0 * (sk * sk + ku * ku / 4.0)
}

/// A fixed-width histogram.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let bins = self.counts.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64) as usize;
            let idx = idx.min(bins - 1);
            self.counts[idx] += 1;
        }
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.counts.len() as f64
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) * self.bin_width()
    }

    /// Normalized density per bin.
    pub fn density(&self) -> Vec<f64> {
        let total = self.total().max(1) as f64;
        let w = self.bin_width();
        self.counts
            .iter()
            .map(|&c| c as f64 / (total * w))
            .collect()
    }

    /// Render an ASCII bar chart (for CLI characterization subcommands).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!("{:>10.3} | {:<width$} {}\n", self.bin_center(i), bar, c));
        }
        out
    }
}

/// Pearson correlation of two equal-length slices.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    linreg(x, y).2
}

/// Shannon entropy of a discrete probability vector, natural log.
pub fn entropy_nats(p: &[f64]) -> f64 {
    p.iter()
        .filter(|&&pi| pi > 0.0)
        .map(|&pi| -pi * pi.ln())
        .sum()
}

/// Shannon entropy in bits.
pub fn entropy_bits(p: &[f64]) -> f64 {
    entropy_nats(p) / std::f64::consts::LN_2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng64};

    #[test]
    fn summary_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let s = Summary::from_slice(&xs);
        assert_eq!(s.count(), 6);
        assert!((s.mean() - 3.5).abs() < 1e-12);
        assert!((s.sample_variance() - 3.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 6.0);
    }

    #[test]
    fn qq_r_high_for_gaussian_low_for_uniform() {
        let mut rng = Pcg64::new(7);
        let gauss: Vec<f64> = (0..2500).map(|_| rng.next_gaussian()).collect();
        let unif: Vec<f64> = (0..2500).map(|_| rng.next_f64()).collect();
        let bimodal: Vec<f64> = (0..2500)
            .map(|_| if rng.next_bool(0.5) { -3.0 } else { 3.0 })
            .collect();
        let r_g = qq_r_value(&gauss);
        let r_u = qq_r_value(&unif);
        let r_b = qq_r_value(&bimodal);
        assert!(r_g > 0.998, "gaussian r={r_g}");
        assert!(r_u < r_g, "uniform r={r_u} should be below gaussian");
        assert!(r_b < 0.95, "bimodal r={r_b}");
    }

    #[test]
    fn ks_accepts_gaussian_rejects_shifted() {
        let mut rng = Pcg64::new(21);
        let gauss: Vec<f64> = (0..4000).map(|_| rng.next_gaussian()).collect();
        let d_ok = ks_statistic_normal(&gauss, 0.0, 1.0);
        let d_bad = ks_statistic_normal(&gauss, 0.5, 1.0);
        assert!(ks_p_value(d_ok, 4000) > 0.01, "d_ok={d_ok}");
        assert!(ks_p_value(d_bad, 4000) < 1e-6, "d_bad={d_bad}");
    }

    #[test]
    fn linreg_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r) = linreg(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_median() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert!((median(&xs) - 3.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_density() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.extend(&[0.5, 1.5, 1.6, 9.9, -1.0, 10.0]);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 6);
        let d = h.density();
        assert!((d.iter().sum::<f64>() * h.bin_width() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_uniform_max() {
        let p = [0.25; 4];
        assert!((entropy_bits(&p) - 2.0).abs() < 1e-12);
        let certain = [1.0, 0.0, 0.0, 0.0];
        assert_eq!(entropy_bits(&certain), 0.0);
    }

    #[test]
    fn jarque_bera_small_for_gaussian() {
        let mut rng = Pcg64::new(77);
        let gauss: Vec<f64> = (0..5000).map(|_| rng.next_gaussian()).collect();
        assert!(jarque_bera(&gauss) < 15.0);
        let exp: Vec<f64> = (0..5000).map(|_| -rng.next_f64_open().ln()).collect();
        assert!(jarque_bera(&exp) > 100.0);
    }
}
