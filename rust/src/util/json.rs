//! Minimal JSON implementation (parser + writer).
//!
//! serde/serde_json are unavailable offline, so artifacts (weight files,
//! manifests, experiment records) use this hand-rolled implementation.
//! It supports the full JSON grammar with the usual Rust niceties
//! (typed accessors, pretty printing) and round-trips `f64` losslessly
//! enough for weight storage (17 significant digits).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for artifact diffing across builds.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and human-readable line/column.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- constructors ----------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs<I: IntoIterator<Item = (&'static str, Json)>>(pairs: I) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usize_slice(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------------- mutation ----------------

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---------------- typed accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `root.at(&["model", "layers", "0"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Extract a numeric array as Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Extract a numeric array as Vec<f32> (weights are stored f32).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---------------- serialization ----------------

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_number(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        // Keep flat numeric arrays on one line for weights.
                        if !matches!(v, Json::Num(_) | Json::Str(_) | Json::Bool(_) | Json::Null) {
                            out.push('\n');
                            out.push_str(&" ".repeat(w * (depth + 1)));
                        }
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some()
                    && a.iter().any(|v| matches!(v, Json::Arr(_) | Json::Obj(_)))
                {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * depth));
                }
                out.push('}');
            }
        }
    }

    // ---------------- parsing ----------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    pub fn read_file(path: &std::path::Path) -> Result<Json, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?)
    }

    pub fn write_file(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string_pretty())
    }
}

/// Lossless f64 → JSON number text (shortest round-trippable form;
/// NaN/Inf become `null`). `pub(crate)` so the network edge's hand-rolled
/// encoder emits bit-identical floats to this tree writer.
pub(crate) fn write_number(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; persist as null (read back as Null).
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // 17 digits: shortest round-trippable is overkill; this is lossless.
        let s = format!("{x:e}");
        // Prefer plain notation for readability when short.
        let plain = format!("{x}");
        if plain.len() <= s.len() {
            out.push_str(&plain);
        } else {
            out.push_str(&s);
        }
    }
}

/// JSON string literal writer (quotes + escapes); shared with the edge
/// encoder for the same reason as [`write_number`].
pub(crate) fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        let upto = &self.bytes[..self.pos.min(self.bytes.len())];
        let line = upto.iter().filter(|&&b| b == b'\n').count() + 1;
        let col = self.pos - upto.iter().rposition(|&b| b == b'\n').map(|p| p + 1).unwrap_or(0) + 1;
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
            line,
            col,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs
                            if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 2) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 3..self.pos + 7],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    let c = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                                    );
                                    self.pos += 6;
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                s.push(
                                    char::from_u32(code)
                                        .ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null, "x\ny"], "c": {"d": "é"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.at(&["b", "0"]).unwrap().as_f64(), Some(1.5));
        assert_eq!(v.at(&["c", "d"]).unwrap().as_str(), Some("é"));
    }

    #[test]
    fn float_roundtrip_lossless() {
        for &x in &[
            0.1,
            1.0 / 3.0,
            std::f64::consts::PI,
            1e-300,
            -1.7976931348623157e308,
            360e-15,
        ] {
            let s = Json::Num(x).to_string_compact();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "roundtrip of {x} gave {back} via {s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn error_position() {
        let err = Json::parse("{\n  \"a\": @\n}").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.msg.contains("expected a JSON value"));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn typed_vectors() {
        let v = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f64_vec(), Some(vec![1.0, 2.0, 3.5]));
        assert_eq!(v.as_usize_vec(), None); // 3.5 not usize
        let w = Json::parse("[4, 5]").unwrap();
        assert_eq!(w.as_usize_vec(), Some(vec![4, 5]));
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("name", Json::Str("tile".into()))
            .set("rows", Json::Num(64.0))
            .set("sigma_bits", Json::Num(4.0));
        assert_eq!(o.get("rows").unwrap().as_usize(), Some(64));
        let pretty = o.to_string_pretty();
        assert!(pretty.contains("\"name\": \"tile\""));
    }
}
