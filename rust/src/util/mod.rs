//! Hand-rolled infrastructure (crates.io is unreachable in this build
//! environment — see DESIGN.md §6 for the substitution table).

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod toml;
