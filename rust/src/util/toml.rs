//! Minimal TOML-subset parser for configuration files.
//!
//! Supports the subset the config system needs: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / array values, comments, and basic inline arrays. Produces the
//! same [`Json`] value tree the rest of the library consumes, so configs
//! and artifacts share one data model.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TOML error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse TOML text into a Json::Obj tree.
pub fn parse(input: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let lineno = lineno + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| TomlError {
                line: lineno,
                msg: "unterminated section header".into(),
            })?;
            if name.starts_with('[') {
                return Err(TomlError {
                    line: lineno,
                    msg: "array-of-tables ([[..]]) is not supported".into(),
                });
            }
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            if section.iter().any(|s| s.is_empty()) {
                return Err(TomlError {
                    line: lineno,
                    msg: "empty section path component".into(),
                });
            }
            // Ensure the section object exists.
            ensure_path(&mut root, &section, lineno)?;
            continue;
        }
        let eq = line.find('=').ok_or_else(|| TomlError {
            line: lineno,
            msg: format!("expected 'key = value', got '{line}'"),
        })?;
        let key = line[..eq].trim();
        let val_text = line[eq + 1..].trim();
        if key.is_empty() {
            return Err(TomlError {
                line: lineno,
                msg: "empty key".into(),
            });
        }
        let val = parse_value(val_text, lineno)?;
        let target = navigate(&mut root, &section, lineno)?;
        if target.contains_key(key) {
            return Err(TomlError {
                line: lineno,
                msg: format!("duplicate key '{key}'"),
            });
        }
        target.insert(key.trim_matches('"').to_string(), val);
    }
    Ok(Json::Obj(root))
}

/// Read and parse a TOML file.
pub fn read_file(path: &std::path::Path) -> Result<Json, Box<dyn std::error::Error>> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
    Ok(parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_path(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    lineno: usize,
) -> Result<(), TomlError> {
    navigate(root, path, lineno).map(|_| ())
}

fn navigate<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Json>, TomlError> {
    let mut cur = root;
    for comp in path {
        let entry = cur
            .entry(comp.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => {
                return Err(TomlError {
                    line: lineno,
                    msg: format!("'{comp}' is both a value and a section"),
                })
            }
        };
    }
    Ok(cur)
}

fn parse_value(text: &str, lineno: usize) -> Result<Json, TomlError> {
    let err = |msg: String| TomlError { line: lineno, msg };
    if text.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        // Basic escapes
        let mut s = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    other => return Err(err(format!("bad escape: \\{other:?}"))),
                }
            } else {
                s.push(c);
            }
        }
        return Ok(Json::Str(s));
    }
    if text == "true" {
        return Ok(Json::Bool(true));
    }
    if text == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array (arrays must be single-line)".into()))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    // Numbers: allow underscores per TOML.
    let cleaned: String = text.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(format!("cannot parse value '{text}'")))
}

/// Split a string on commas not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        parts.push(&s[start..]);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let src = r#"
# chip config
name = "proto65"    # comment after value
temp_c = 28.0

[grng]
vdd = 1.2
bias_mv = 180
enabled = true
caps_ff = [1.0, 1.1]

[tile.adc]
bits = 6
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("proto65"));
        assert_eq!(v.at(&["grng", "bias_mv"]).unwrap().as_f64(), Some(180.0));
        assert_eq!(v.at(&["grng", "enabled"]).unwrap().as_bool(), Some(true));
        assert_eq!(
            v.at(&["grng", "caps_ff"]).unwrap().as_f64_vec(),
            Some(vec![1.0, 1.1])
        );
        assert_eq!(v.at(&["tile", "adc", "bits"]).unwrap().as_usize(), Some(6));
    }

    #[test]
    fn underscore_numbers() {
        let v = parse("n = 1_000_000").unwrap();
        assert_eq!(v.get("n").unwrap().as_f64(), Some(1e6));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("a = 1\nb =\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("a = 1\na = 2\n").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let v = parse(r##"s = "a#b""##).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn nested_arrays() {
        let v = parse("m = [[1, 2], [3, 4]]").unwrap();
        let rows = v.get("m").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].as_f64_vec(), Some(vec![3.0, 4.0]));
    }
}
