//! 6-bit differential SAR ADC model (§III-B, §III-D).
//!
//! Each bit-column has a dedicated ADC, pitch-matched to the SRAM so no
//! column multiplexing is needed (single-cycle MVM). ADCs share a
//! synchronous controller; what varies per instance is a static offset
//! (corrected digitally by the reduction logic after calibration) and a
//! small per-conversion noise.

use crate::config::AdcConfig;
use crate::util::rng::{Pcg64, Rng64, Xoshiro256};

/// One column ADC instance.
#[derive(Clone, Debug)]
pub struct SarAdc {
    cfg: AdcConfig,
    /// Static input-referred offset \[LSB\].
    pub offset_lsb: f64,
    noise_rng: Xoshiro256,
}

impl SarAdc {
    pub fn new(cfg: &AdcConfig, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0xADC0);
        Self {
            cfg: cfg.clone(),
            offset_lsb: cfg.offset_lsb_sigma * rng.next_gaussian(),
            noise_rng: Xoshiro256::new(seed ^ 0xADC1),
        }
    }

    /// Convert a normalized differential input: `v` in LSB units
    /// (full scale spans the signed code range). Returns the signed code.
    pub fn convert(&mut self, v_lsb: f64) -> i64 {
        let (lo, hi) = self.cfg.code_range();
        let noisy = v_lsb + self.offset_lsb + self.cfg.noise_lsb_sigma * self.noise_rng.next_gaussian();
        (noisy.round() as i64).clamp(lo, hi)
    }

    /// Replace the per-conversion noise stream, keeping the static offset
    /// (an MC-parallel replica of the same physical ADC).
    pub fn reseed_noise(&mut self, seed: u64) {
        self.noise_rng = Xoshiro256::new(seed ^ 0xADC1);
    }

    /// Ideal conversion (no offset/noise) — ablation reference.
    pub fn convert_ideal(&self, v_lsb: f64) -> i64 {
        let (lo, hi) = self.cfg.code_range();
        (v_lsb.round() as i64).clamp(lo, hi)
    }

    pub fn energy_j(&self) -> f64 {
        self.cfg.energy_j
    }

    pub fn bits(&self) -> usize {
        self.cfg.bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adc(seed: u64) -> SarAdc {
        SarAdc::new(&AdcConfig::default(), seed)
    }

    #[test]
    fn codes_clamp_at_rails() {
        let mut a = adc(1);
        assert_eq!(a.convert(1e9), 31);
        assert_eq!(a.convert(-1e9), -32);
    }

    #[test]
    fn ideal_conversion_is_rounding() {
        let a = adc(2);
        assert_eq!(a.convert_ideal(4.4), 4);
        assert_eq!(a.convert_ideal(-4.6), -5);
        assert_eq!(a.convert_ideal(0.0), 0);
    }

    #[test]
    fn offset_is_static_noise_is_not() {
        let mut a = adc(3);
        let codes: Vec<i64> = (0..200).map(|_| a.convert(10.0)).collect();
        // noise jitters but mean ≈ 10 + offset
        let mean = codes.iter().sum::<i64>() as f64 / codes.len() as f64;
        assert!((mean - 10.0 - a.offset_lsb).abs() < 0.2, "mean {mean}");
        // deterministic across instances with same seed
        let b = SarAdc::new(&AdcConfig::default(), 3);
        assert_eq!(a.offset_lsb, b.offset_lsb);
    }

    #[test]
    fn different_seeds_different_offsets() {
        let a = adc(4);
        let b = adc(5);
        assert_ne!(a.offset_lsb, b.offset_lsb);
    }
}
