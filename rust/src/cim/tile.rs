//! The CIM tile (Fig. 3): two crossbar subarrays computing X·μ and
//! X·(σ⊙ε) in one cycle, sharing the 4-bit input X through row IDACs,
//! digitized per bit-column by 6-bit SAR ADCs and recombined (shift-add)
//! by the reduction logic.
//!
//! Signal chain modeled per column j, bit-plane b:
//!
//!   μ path:  q_μ(j,b)  = Σ_i drive(X_i) · d_μ(i,j,b)          d ∈ {−1,+1}
//!   σε path: q_σ(j,b)  = Σ_i drive(X_i) · bit_σ(i,j,b) · ε_ij
//!
//! where `drive` is the IDAC transfer (0..1·15), ε carries the GRNG's
//! sign (BL_P/BL_N steering) and magnitude (pulse width). Charges map to
//! ADC LSBs through a full-scale factor, get offset/noise/clipping from
//! the ADC model, are offset-corrected and shift-added by the reduction
//! logic, and finally scaled back to fixed-point weight units.

use crate::arch::{lane_combine, lane_dot, mul_into};
use crate::cim::adc::SarAdc;
use crate::cim::idac::Idac;
use crate::cim::word::{MuWord, SigmaWord};
use crate::config::ChipConfig;
use crate::energy::{Component, EnergyLedger};
use crate::grng::{DieVariation, GrngBank};
use crate::util::rng::SplitMix64;
use std::sync::Arc;

/// Options controlling an MVM.
#[derive(Clone, Copy, Debug)]
pub struct MvmOptions {
    /// Include the σε path (false = deterministic NN, μ only).
    pub bayesian: bool,
    /// Draw fresh ε for this MVM (false = reuse the last sample matrix).
    pub refresh_epsilon: bool,
    /// Bypass analog non-idealities (ideal ADC, ideal IDAC): ablation.
    pub ideal_analog: bool,
}

impl Default for MvmOptions {
    fn default() -> Self {
        Self {
            bayesian: true,
            refresh_epsilon: true,
            ideal_analog: false,
        }
    }
}

/// MVM output with the two subarray paths kept separate: the reduction
/// logic recombines them with independent shifts (μ and σ words have
/// different LSB weights — 8-bit vs 4-bit grids).
#[derive(Clone, Debug)]
pub struct MvmResult {
    /// X·μ path, fixed-point μ units.
    pub mu: Vec<f64>,
    /// X·(σ⊙ε) path, fixed-point σ units.
    pub sigma: Vec<f64>,
}

impl MvmResult {
    /// Recombine with unit scales (μ LSB = σ LSB) — the simple case used
    /// when both paths share one `WeightScale`.
    pub fn combined(&self) -> Vec<f64> {
        self.mu
            .iter()
            .zip(self.sigma.iter())
            .map(|(m, s)| m + s)
            .collect()
    }

    /// Recombine with independent path scales.
    pub fn combined_scaled(&self, k_mu: f64, k_sigma: f64) -> Vec<f64> {
        self.mu
            .iter()
            .zip(self.sigma.iter())
            .map(|(m, s)| m * k_mu + s * k_sigma)
            .collect()
    }
}

/// Precomputed structure-of-arrays view of the tile's words — the MVM
/// fast path. Built lazily from the AoS `MuWord`/`SigmaWord` storage and
/// invalidated by every word write (`program`, `write_sigma_raw`), so the
/// inner loop of an MVM is a branch-free contiguous multiply-accumulate
/// instead of per-element struct accessor calls.
///
/// Layouts (all row-contiguous, i.e. the MVM reduction dimension is the
/// fastest-moving index):
/// - `mu`:         `[word][bit-plane][row]`, digits as ±1.0
/// - `sigma_mask`: `[word][bit-plane][row]`, bits as 0.0/1.0
/// - `sigma_val`:  `[word][row]`, σ codes as f64 (ε₀ offset correction)
///
/// Exactness contract: ±1.0 factors equal `digit as f64` and masking by
/// 1.0/0.0 is an exact multiply, so the fast path reproduces the legacy
/// per-word path bit for bit (pinned by `tests/mvm_props.rs`).
#[derive(Clone, Debug, Default)]
struct TilePlanes {
    mu: Vec<f64>,
    sigma_mask: Vec<f64>,
    sigma_val: Vec<f64>,
}

impl TilePlanes {
    /// Heap footprint of the cached planes \[bytes\].
    fn bytes(&self) -> usize {
        (self.mu.len() + self.sigma_mask.len() + self.sigma_val.len())
            * std::mem::size_of::<f64>()
    }
}

/// Reusable per-MVM scratch buffers — no `vec!` on the hot path.
#[derive(Clone, Debug, Default)]
struct MvmScratch {
    /// IDAC output per row.
    drives: Vec<f64>,
    /// drives\[r\]·ε\[r\]\[w\] for the word currently being converted, shared
    /// across that word's σ bit-planes.
    row_terms: Vec<f64>,
}

/// Engagement gate for the ε/MVM pipeline in [`CimTile::mvm_batch`]: the
/// batch must be at least this deep *and* the bank at least
/// [`EPSILON_PIPELINE_MIN_CELLS`] cells before ε generation moves onto a
/// producer thread. Below either bound the scoped-thread spawn (~tens of
/// µs) costs more than the overlap saves (one whole-bank fill per extra
/// sample — ~5-10 µs at the default 64×8 bank, far less on the tiny
/// tiles unit tests use). The pipelined and serial arms are
/// bit-identical, so both thresholds are pure performance knobs;
/// recalibrate against `benches/cim_mvm.rs` fresh-ε batch cases.
const EPSILON_PIPELINE_MIN_T: usize = 4;

/// Minimum bank size (rows × words) for the ε/MVM pipeline; the default
/// 64×8 = 512-cell chip qualifies, sub-tile test geometries do not.
const EPSILON_PIPELINE_MIN_CELLS: usize = 256;

// The tile's fixed column-charge reduction spec — eight interleaved
// partial sums (lane = row mod 8) combined pairwise,
// `q = ((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))` — now lives in
// [`crate::arch`] as `lane_combine`/`lane_dot`, where the runtime SIMD
// dispatch maps the eight lanes onto AVX2/NEON registers bit-identically
// to the scalar walk. Both MVM implementations here follow that spec, so
// the legacy word-walk and the SoA fast path stay bit-identical at every
// dispatch level.

/// The tile's ADC conversion chain with its borrows split away from the
/// GRNG bank: everything `convert_words` needs — ADCs (mutable: each
/// conversion advances its private noise stream), correction registers,
/// geometry and full-scale factors — and nothing the ε producer touches.
/// This is what lets `mvm_batch` overlap sample k's conversion with
/// sample k+1's ε generation without any shared state.
struct ConvertUnit<'a> {
    rows: usize,
    words: usize,
    mu_bits: usize,
    sigma_bits: usize,
    adc_lsb_mu: f64,
    adc_lsb_sigma: f64,
    adcs: &'a mut [SarAdc],
    adc_offset_cal: &'a [f64],
    grng_offset_cal: &'a [f64],
}

impl ConvertUnit<'_> {
    /// Convert every word's bit-plane columns through the ADCs and
    /// recombine (the shift-add reduction), reading weights from the SoA
    /// planes and ε from the plane-major `eps_t` (`[word][row]`). The
    /// contiguous inner loops accumulate in the same row order as the
    /// legacy path, so outputs are bit-identical.
    fn convert_words(
        &mut self,
        opts: MvmOptions,
        planes: &TilePlanes,
        scratch: &mut MvmScratch,
        eps_t: &[f64],
        out_mu: &mut [f64],
        out_sigma: &mut [f64],
    ) {
        let rows = self.rows;
        let mu_bits = self.mu_bits;
        let sigma_bits = self.sigma_bits;
        let adc_per_word = mu_bits + sigma_bits;
        let drives = &scratch.drives;
        scratch.row_terms.clear();
        scratch.row_terms.resize(rows, 0.0);
        for w in 0..self.words {
            // ---- μ subarray: one differential column per bit-plane ----
            let mut y_mu = 0.0f64;
            for b in 0..mu_bits {
                let plane = &planes.mu[(w * mu_bits + b) * rows..(w * mu_bits + b + 1) * rows];
                let q = lane_dot(drives, plane);
                let v_lsb = q / self.adc_lsb_mu;
                let adc_idx = w * adc_per_word + b;
                let code = if opts.ideal_analog {
                    self.adcs[adc_idx].convert_ideal(v_lsb)
                } else {
                    self.adcs[adc_idx].convert(v_lsb)
                };
                let corrected = code as f64 - self.adc_offset_cal[adc_idx];
                y_mu += (1u64 << b) as f64 * corrected * self.adc_lsb_mu;
            }

            // ---- σε subarray ----
            let mut y_sigma = 0.0f64;
            if opts.bayesian {
                // drives[r]·ε[r][w] once per word, shared by its planes
                // (dispatched elementwise product, bit-identical: one
                // rounding per element on every arch arm).
                let eps_col = &eps_t[w * rows..(w + 1) * rows];
                mul_into(&mut scratch.row_terms, drives, eps_col);
                for b in 0..sigma_bits {
                    let base = (w * sigma_bits + b) * rows;
                    let mask = &planes.sigma_mask[base..base + rows];
                    let q = lane_dot(&scratch.row_terms, mask);
                    let v_lsb = q / self.adc_lsb_sigma;
                    let adc_idx = w * adc_per_word + mu_bits + b;
                    let code = if opts.ideal_analog {
                        self.adcs[adc_idx].convert_ideal(v_lsb)
                    } else {
                        self.adcs[adc_idx].convert(v_lsb)
                    };
                    let corrected = code as f64 - self.adc_offset_cal[adc_idx];
                    y_sigma += (1u64 << b) as f64 * corrected * self.adc_lsb_sigma;
                }
                // GRNG static-offset correction (Eq. 10): subtract the
                // calibrated Σ_i X_i·σ_ij·ε₀_ij estimate.
                let vals = &planes.sigma_val[w * rows..(w + 1) * rows];
                let mut corr = 0.0f64;
                for r in 0..rows {
                    let c = self.grng_offset_cal[r * self.words + w];
                    if c != 0.0 {
                        corr += drives[r] * vals[r] * c;
                    }
                }
                y_sigma -= corr;
            }

            out_mu[w] = y_mu;
            out_sigma[w] = y_sigma;
        }
    }
}

/// One CIM tile: `rows` inputs × `words` outputs.
///
/// # Shared immutable layer (copy-on-calibrate)
///
/// The chip's whole economy comes from keeping weights resident while
/// only ε changes per sample; the software mirror is that everything
/// *static per die* — programmed μ/σ words, the SoA plane cache, IDAC
/// bows, and the calibration registers — lives behind `Arc`s, so a
/// `Clone` of a calibrated tile shares those planes instead of deep-
/// copying them. Only the per-replica *stream* state (ε buffers, GRNG
/// lane states, ADC noise streams, scratch, the energy ledger) is
/// private. Bring-up mutation (`program`, `write_sigma_raw`,
/// calibration writes) goes through `Arc::make_mut`: in-place while the
/// tile is still uniquely owned, copy-on-write after replicas share it
/// — which is exactly the "copy-on-calibrate" contract.
#[derive(Clone)]
pub struct CimTile {
    pub chip: ChipConfig,
    rows: usize,
    words: usize,
    /// μ words, row-major [rows × words] (shared immutable layer).
    mu: Arc<Vec<MuWord>>,
    /// σ words, row-major [rows × words] (shared immutable layer).
    sigma: Arc<Vec<SigmaWord>>,
    /// In-word GRNG bank (one cell per σ word).
    pub bank: GrngBank,
    /// Current ε matrix in plane-major `[word][row]` layout — filled
    /// directly by the bank's block sampler
    /// (`GrngBank::fill_epsilon_planes`), exactly the layout the σε fast
    /// path consumes, so no row-major intermediate or transpose exists.
    eps_t: Vec<f64>,
    /// Second ε buffer for the double-buffered `mvm_batch` pipeline
    /// (sample k runs from buffer k % 2 while k+1 fills).
    eps_spare: Vec<f64>,
    /// Row IDACs (static die state after construction — shared layer).
    idacs: Arc<Vec<Idac>>,
    /// Column ADCs: [words × (mu_bits + sigma_bits)]. Mutable per
    /// replica: every conversion advances an ADC's private noise stream.
    adcs: Vec<SarAdc>,
    /// Digital offset-correction registers per ADC \[LSB\], set by
    /// calibration (zeros when uncalibrated). Shared layer; mutate via
    /// [`CimTile::adc_offset_cal_mut`].
    pub adc_offset_cal: Arc<Vec<f64>>,
    /// μ-side correction for GRNG static offsets ε₀ (Eq. 10): value to
    /// subtract from the recombined σε word output, in weight LSB units.
    /// Shared layer; mutate via [`CimTile::grng_offset_cal_mut`].
    pub grng_offset_cal: Arc<Vec<f64>>,
    /// Energy ledger.
    pub ledger: EnergyLedger,
    /// ADC full-scale: LSB size in "drive·digit" charge units.
    adc_lsb_mu: f64,
    adc_lsb_sigma: f64,
    /// SoA fast-path cache; `None` after any word write. Behind `Arc`
    /// so replicas cloned after [`CimTile::warm_planes`] share one copy.
    planes: Option<Arc<TilePlanes>>,
    /// Reusable MVM scratch buffers.
    scratch: MvmScratch,
}

impl CimTile {
    pub fn new(chip: &ChipConfig) -> Self {
        let rows = chip.tile.rows;
        let words = chip.tile.words_per_row;
        let die = DieVariation::draw(&chip.grng, rows, words, chip.die_seed);
        let bank = GrngBank::new(&chip.grng, &die, chip.die_seed);
        let mut seeder = SplitMix64::new(chip.die_seed ^ 0x711E_C1A0);
        let idacs = (0..rows).map(|_| Idac::new(&chip.idac, seeder.split())).collect();
        let adc_per_word = chip.tile.mu_bits + chip.tile.sigma_bits;
        let adcs = (0..words * adc_per_word)
            .map(|_| SarAdc::new(&chip.adc, seeder.split()))
            .collect();
        // ADC full scale: worst-case μ column charge is rows·15·(±1); the
        // design centers the transfer so that a typical (quarter-occupancy)
        // column spans the code range — the standard CIM FS compromise
        // between clipping and quantization noise.
        let x_max = (chip.idac.levels() - 1) as f64;
        let half_codes = (1i64 << (chip.adc.bits - 1)) as f64;
        let fs_frac = 0.25;
        let adc_lsb_mu = rows as f64 * x_max * fs_frac / half_codes;
        // σε path: the Gaussian ε spreads column charge wider than the
        // ±1 μ digits (σ codes reach 15 and |ε| tails run past 3), so its
        // differential ADC is ranged 2× — otherwise trained-model σε
        // columns clip and the head collapses to chance.
        let adc_lsb_sigma = 2.0 * adc_lsb_mu;
        Self {
            chip: chip.clone(),
            rows,
            words,
            mu: Arc::new(vec![MuWord { digits: 0, bits: chip.tile.mu_bits as u8 }; rows * words]),
            sigma: Arc::new(vec![
                SigmaWord { code: 0, bits: chip.tile.sigma_bits as u8 };
                rows * words
            ]),
            bank,
            eps_t: vec![0.0; rows * words],
            eps_spare: Vec::new(),
            idacs: Arc::new(idacs),
            adcs,
            adc_offset_cal: Arc::new(vec![0.0; words * adc_per_word]),
            grng_offset_cal: Arc::new(vec![0.0; rows * words]),
            ledger: EnergyLedger::new(),
            adc_lsb_mu,
            adc_lsb_sigma,
            planes: None,
            scratch: MvmScratch::default(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn words(&self) -> usize {
        self.words
    }

    /// Program one weight (fixed-point units; see `word::WeightScale`).
    /// Costs SRAM write energy.
    pub fn program(&mut self, row: usize, word: usize, mu_fixed: f64, sigma_fixed: f64) {
        let idx = row * self.words + word;
        Arc::make_mut(&mut self.mu)[idx] = MuWord::quantize(mu_fixed, self.chip.tile.mu_bits as u8);
        Arc::make_mut(&mut self.sigma)[idx] =
            SigmaWord::quantize(sigma_fixed, self.chip.tile.sigma_bits as u8);
        self.planes = None;
        let cells = 2 * self.chip.tile.mu_bits + self.chip.tile.sigma_bits;
        self.ledger.deposit(
            Component::SramWrite,
            cells as f64 * self.chip.energy.sram_cell_write_j,
        );
    }

    /// Program a full weight matrix (row-major \[rows\]\[words\]).
    pub fn program_matrix(&mut self, mu_fixed: &[f64], sigma_fixed: &[f64]) {
        assert_eq!(mu_fixed.len(), self.rows * self.words);
        assert_eq!(sigma_fixed.len(), self.rows * self.words);
        for r in 0..self.rows {
            for w in 0..self.words {
                let i = r * self.words + w;
                self.program(r, w, mu_fixed[i], sigma_fixed[i]);
            }
        }
    }

    /// Stored μ value (fixed-point) at (row, word) — for tests.
    pub fn mu_value(&self, row: usize, word: usize) -> i32 {
        self.mu[row * self.words + word].value()
    }

    /// Stored σ code at (row, word).
    pub fn sigma_value(&self, row: usize, word: usize) -> u32 {
        self.sigma[row * self.words + word].value()
    }

    /// Direct σ-word write (used by the calibration controller).
    pub fn write_sigma_raw(&mut self, row: usize, word: usize, code: u8) {
        let idx = row * self.words + word;
        Arc::make_mut(&mut self.sigma)[idx] = SigmaWord {
            code: code.min(((1u16 << self.chip.tile.sigma_bits) - 1) as u8),
            bits: self.chip.tile.sigma_bits as u8,
        };
        self.planes = None;
        self.ledger.deposit(
            Component::SramWrite,
            self.chip.tile.sigma_bits as f64 * self.chip.energy.sram_cell_write_j,
        );
    }

    /// The ε matrix used by the last MVM, in the tile's native plane-major
    /// `[word][row]` layout (cell (r, w) at `w * rows + r`) — for
    /// tests/debug.
    pub fn last_epsilon(&self) -> &[f64] {
        &self.eps_t
    }

    /// Perform one matrix-vector multiplication (SoA fast path).
    ///
    /// `x`: input codes (len = rows, values < 2^input_bits).
    /// Returns the two subarray outputs (`mu` ≈ Σ X_i·μ_ij,
    /// `sigma` ≈ Σ X_i·σ_ij·ε_ij, each in its own fixed-point units).
    ///
    /// Bit-identical to [`CimTile::mvm_legacy`]: the plane cache stores
    /// exactly the factors the per-word path computes, accumulated in the
    /// same row order, and all RNG streams (ε refresh, ADC noise) are
    /// consumed in the same sequence.
    pub fn mvm(&mut self, x: &[u8], opts: MvmOptions) -> MvmResult {
        assert_eq!(x.len(), self.rows, "input length must equal tile rows");
        let max_code = (self.chip.idac.levels() - 1) as u8;
        debug_assert!(x.iter().all(|&c| c <= max_code), "input code overflow");

        if opts.bayesian && opts.refresh_epsilon {
            self.refresh_epsilon();
        }
        let planes = self.take_planes();
        let mut scratch = std::mem::take(&mut self.scratch);
        self.fill_drives(x, opts.ideal_analog, &mut scratch.drives);

        let mut out_mu = vec![0.0f64; self.words];
        let mut out_sigma = vec![0.0f64; self.words];
        let (mut unit, eps_t) = self.convert_unit();
        unit.convert_words(opts, &planes, &mut scratch, eps_t, &mut out_mu, &mut out_sigma);
        self.deposit_mvm_energy(opts, 1);

        self.scratch = scratch;
        self.planes = Some(planes);
        MvmResult {
            mu: out_mu,
            sigma: out_sigma,
        }
    }

    /// `t` Monte-Carlo MVMs of the same input vector: the IDAC drives and
    /// the SoA plane cache are computed once and the energy-ledger
    /// deposits are batched, while ε is still refreshed per Bayesian
    /// sample. Output `s` is bit-identical to the `s`-th of `t`
    /// back-to-back [`CimTile::mvm`] calls (the per-tile RNG streams are
    /// consumed in the same order); only the ledger's floating-point
    /// totals may differ in the last ulp (one `t`-scaled deposit instead
    /// of `t` small ones).
    ///
    /// # ε/MVM pipeline (double buffering)
    ///
    /// For `t >= EPSILON_PIPELINE_MIN_T` fresh-ε Bayesian batches, ε
    /// generation is pipelined into the MVM: one scoped producer thread
    /// runs the bank's block sampler while this thread converts, with two
    /// ε buffers in flight (sample k always consumes the k-th conversion
    /// of the bank's streams and runs from buffer k % 2 — the slot →
    /// buffer assignment is static). The GRNG streams live only on the
    /// producer and the ADC streams only on the consumer, each advancing
    /// in the same order as the serial loop, so outputs stay bit-identical
    /// (pinned by `tests/mvm_props.rs`) and replay is still a pure
    /// function of the die seed — thread scheduling cannot leak in.
    pub fn mvm_batch(&mut self, x: &[u8], t: usize, opts: MvmOptions) -> Vec<MvmResult> {
        assert_eq!(x.len(), self.rows, "input length must equal tile rows");
        let max_code = (self.chip.idac.levels() - 1) as u8;
        debug_assert!(x.iter().all(|&c| c <= max_code), "input code overflow");

        let planes = self.take_planes();
        let mut scratch = std::mem::take(&mut self.scratch);
        self.fill_drives(x, opts.ideal_analog, &mut scratch.drives);

        let mut out = Vec::with_capacity(t);
        let refresh = opts.bayesian && opts.refresh_epsilon;
        if refresh
            && t >= EPSILON_PIPELINE_MIN_T
            && self.rows * self.words >= EPSILON_PIPELINE_MIN_CELLS
            && !self.bank.is_empty()
        {
            self.run_batch_pipelined(t, opts, &planes, &mut scratch, &mut out);
        } else {
            for _ in 0..t {
                if refresh {
                    self.refresh_epsilon();
                }
                let mut out_mu = vec![0.0f64; self.words];
                let mut out_sigma = vec![0.0f64; self.words];
                let (mut unit, eps_t) = self.convert_unit();
                unit.convert_words(opts, &planes, &mut scratch, eps_t, &mut out_mu, &mut out_sigma);
                out.push(MvmResult {
                    mu: out_mu,
                    sigma: out_sigma,
                });
            }
        }
        self.deposit_mvm_energy(opts, t as u64);

        self.scratch = scratch;
        self.planes = Some(planes);
        out
    }

    /// The double-buffered ε pipeline behind [`CimTile::mvm_batch`]: a
    /// producer thread fills ε buffers from the in-word bank while this
    /// thread runs the ADC conversion chain — the software mirror of the
    /// chip generating next-sample randomness in parallel with the
    /// current MVM. Channels carry two buffers round-robin; the last
    /// sample's buffer is kept as the tile's current ε (so
    /// `last_epsilon`/`mvm_reference` see the final sample, exactly like
    /// the serial loop).
    fn run_batch_pipelined(
        &mut self,
        t: usize,
        opts: MvmOptions,
        planes: &TilePlanes,
        scratch: &mut MvmScratch,
        out: &mut Vec<MvmResult>,
    ) {
        use std::sync::mpsc::sync_channel;
        let rows = self.rows;
        let words = self.words;
        let mu_bits = self.chip.tile.mu_bits;
        let sigma_bits = self.chip.tile.sigma_bits;
        let (adc_lsb_mu, adc_lsb_sigma) = (self.adc_lsb_mu, self.adc_lsb_sigma);
        let cells = rows * words;
        if self.eps_spare.len() != cells {
            self.eps_spare.resize(cells, 0.0);
        }
        let buf_a = std::mem::take(&mut self.eps_t);
        let buf_b = std::mem::take(&mut self.eps_spare);

        // Split disjoint borrows: the bank samples on the producer thread
        // while the ADC chain converts on this one.
        let Self {
            ref mut bank,
            ref mut adcs,
            ref adc_offset_cal,
            ref grng_offset_cal,
            ..
        } = *self;
        let mut unit = ConvertUnit {
            rows,
            words,
            mu_bits,
            sigma_bits,
            adc_lsb_mu,
            adc_lsb_sigma,
            adcs: adcs.as_mut_slice(),
            adc_offset_cal: adc_offset_cal.as_slice(),
            grng_offset_cal: grng_offset_cal.as_slice(),
        };

        let (filled_tx, filled_rx) = sync_channel::<Vec<f64>>(2);
        let (free_tx, free_rx) = sync_channel::<Vec<f64>>(2);
        free_tx.send(buf_a).expect("fresh channel");
        free_tx.send(buf_b).expect("fresh channel");
        let mut last_eps: Option<Vec<f64>> = None;
        let mut spare: Option<Vec<f64>> = None;
        std::thread::scope(|sc| {
            let producer = sc.spawn(move || {
                for _ in 0..t {
                    let Ok(mut buf) = free_rx.recv() else {
                        return None;
                    };
                    bank.fill_epsilon_planes(&mut buf);
                    // Never blocks: the channel capacity covers both
                    // circulating buffers.
                    if filled_tx.send(buf).is_err() {
                        return None;
                    }
                }
                // Exactly one consumer recycle (the s = t-2 return) is
                // still in flight after the t-th fill; claim it so the
                // buffer survives for the next batch. Errors only if the
                // consumer unwound and dropped its sender.
                free_rx.recv().ok()
            });
            // Owned by this closure so an unwind drops it, releasing the
            // producer's `free_rx.recv()` before the scope joins.
            let recycle = free_tx;
            for s in 0..t {
                let eps = filled_rx.recv().expect("ε pipeline producer died");
                let mut out_mu = vec![0.0f64; words];
                let mut out_sigma = vec![0.0f64; words];
                unit.convert_words(opts, planes, scratch, &eps, &mut out_mu, &mut out_sigma);
                out.push(MvmResult {
                    mu: out_mu,
                    sigma: out_sigma,
                });
                if s + 1 == t {
                    last_eps = Some(eps);
                } else if let Err(ret) = recycle.send(eps) {
                    // Producer died mid-batch (panic path); keep the
                    // buffer for the next batch.
                    spare = Some(ret.0);
                }
            }
            drop(recycle);
            if let Ok(Some(buf)) = producer.join() {
                spare = Some(buf);
            }
        });
        self.eps_t = last_eps.expect("t >= 1 in pipelined batch");
        if let Some(b) = spare {
            self.eps_spare = b;
        }
        // One batched GRNG deposit for the t refreshes (the serial path's
        // per-refresh deposits differ only in the last ulp).
        self.deposit_grng_energy(t as u64);
    }

    /// The pre-SoA reference implementation: walks the AoS
    /// `MuWord`/`SigmaWord` storage per element and allocates per call.
    /// Kept as the A/B baseline for `tests/mvm_props.rs` (bit-exactness)
    /// and `benches/cim_mvm.rs` / `BENCH_cim_mvm.json` (speedup).
    pub fn mvm_legacy(&mut self, x: &[u8], opts: MvmOptions) -> MvmResult {
        assert_eq!(x.len(), self.rows, "input length must equal tile rows");
        let max_code = (self.chip.idac.levels() - 1) as u8;
        debug_assert!(x.iter().all(|&c| c <= max_code), "input code overflow");

        if opts.bayesian && opts.refresh_epsilon {
            self.refresh_epsilon();
        }

        // Row drives through the IDACs (energy: one conversion per row).
        let mut drives = vec![0.0f64; self.rows];
        let x_fs = (self.chip.idac.levels() - 1) as f64;
        for r in 0..self.rows {
            drives[r] = if opts.ideal_analog {
                x[r] as f64
            } else {
                self.idacs[r].drive(x[r]) * x_fs
            };
        }

        let mu_bits = self.chip.tile.mu_bits;
        let sigma_bits = self.chip.tile.sigma_bits;
        let adc_per_word = mu_bits + sigma_bits;
        let mut out_mu = vec![0.0f64; self.words];
        let mut out_sigma = vec![0.0f64; self.words];

        for w in 0..self.words {
            // ---- μ subarray: one differential column per bit-plane ----
            let mut y_mu = 0.0f64;
            for b in 0..mu_bits {
                let mut s = [0.0f64; 8];
                for r in 0..self.rows {
                    s[r & 7] += drives[r] * self.mu[r * self.words + w].digit(b) as f64;
                }
                let q = lane_combine(&s);
                let v_lsb = q / self.adc_lsb_mu;
                let adc_idx = w * adc_per_word + b;
                let code = if opts.ideal_analog {
                    self.adcs[adc_idx].convert_ideal(v_lsb)
                } else {
                    self.adcs[adc_idx].convert(v_lsb)
                };
                let corrected = code as f64 - self.adc_offset_cal[adc_idx];
                y_mu += (1u64 << b) as f64 * corrected * self.adc_lsb_mu;
            }

            // ---- σε subarray ----
            let mut y_sigma = 0.0f64;
            if opts.bayesian {
                for b in 0..sigma_bits {
                    let mut s = [0.0f64; 8];
                    for r in 0..self.rows {
                        let i = r * self.words + w;
                        if self.sigma[i].bit(b) == 1 {
                            s[r & 7] += drives[r] * self.eps_t[w * self.rows + r];
                        }
                    }
                    let q = lane_combine(&s);
                    let v_lsb = q / self.adc_lsb_sigma;
                    let adc_idx = w * adc_per_word + mu_bits + b;
                    let code = if opts.ideal_analog {
                        self.adcs[adc_idx].convert_ideal(v_lsb)
                    } else {
                        self.adcs[adc_idx].convert(v_lsb)
                    };
                    let corrected = code as f64 - self.adc_offset_cal[adc_idx];
                    y_sigma += (1u64 << b) as f64 * corrected * self.adc_lsb_sigma;
                }
                // GRNG static-offset correction (Eq. 10): subtract the
                // calibrated Σ_i X_i·σ_ij·ε₀_ij estimate.
                let mut corr = 0.0f64;
                for r in 0..self.rows {
                    let i = r * self.words + w;
                    if self.grng_offset_cal[i] != 0.0 {
                        corr += drives[r]
                            * self.sigma[i].value() as f64
                            * self.grng_offset_cal[i];
                    }
                }
                y_sigma -= corr;
            }

            out_mu[w] = y_mu;
            out_sigma[w] = y_sigma;
        }

        self.deposit_mvm_energy(opts, 1);

        MvmResult {
            mu: out_mu,
            sigma: out_sigma,
        }
    }

    /// Take the plane cache (building it if a word write invalidated it).
    fn take_planes(&mut self) -> Arc<TilePlanes> {
        match self.planes.take() {
            Some(p) => p,
            None => Arc::new(self.build_planes()),
        }
    }

    /// Build the SoA plane cache eagerly so that subsequent `Clone`s
    /// share it through the `Arc` instead of each replica rebuilding (or
    /// deep-copying) its own. Called once after programming/calibration,
    /// before replica fan-out. Idempotent; a later word write still
    /// invalidates and rebuilds on the next MVM.
    pub fn warm_planes(&mut self) {
        if self.planes.is_none() {
            self.planes = Some(Arc::new(self.build_planes()));
        }
    }

    /// Lower the AoS word storage into the SoA plane layout.
    fn build_planes(&self) -> TilePlanes {
        let rows = self.rows;
        let words = self.words;
        let mu_bits = self.chip.tile.mu_bits;
        let sigma_bits = self.chip.tile.sigma_bits;
        let mut mu = vec![0.0f64; words * mu_bits * rows];
        let mut sigma_mask = vec![0.0f64; words * sigma_bits * rows];
        let mut sigma_val = vec![0.0f64; words * rows];
        for w in 0..words {
            for b in 0..mu_bits {
                let base = (w * mu_bits + b) * rows;
                for r in 0..rows {
                    mu[base + r] = self.mu[r * words + w].digit_f64(b);
                }
            }
            for b in 0..sigma_bits {
                let base = (w * sigma_bits + b) * rows;
                for r in 0..rows {
                    sigma_mask[base + r] = self.sigma[r * words + w].bit_f64(b);
                }
            }
            for r in 0..rows {
                sigma_val[w * rows + r] = self.sigma[r * words + w].value() as f64;
            }
        }
        TilePlanes {
            mu,
            sigma_mask,
            sigma_val,
        }
    }

    /// Compute the row drives into a reusable buffer (IDAC transfer, or
    /// the raw code under `ideal_analog`).
    fn fill_drives(&self, x: &[u8], ideal_analog: bool, drives: &mut Vec<f64>) {
        drives.clear();
        drives.resize(self.rows, 0.0);
        let x_fs = (self.chip.idac.levels() - 1) as f64;
        for r in 0..self.rows {
            drives[r] = if ideal_analog {
                x[r] as f64
            } else {
                self.idacs[r].drive(x[r]) * x_fs
            };
        }
    }

    /// The ADC conversion chain's borrow of the tile, split from the GRNG
    /// bank so the ε pipeline can sample on another thread while this
    /// converts. Paired with the tile's current ε by
    /// [`CimTile::convert_unit`].
    fn convert_unit(&mut self) -> (ConvertUnit<'_>, &[f64]) {
        (
            ConvertUnit {
                rows: self.rows,
                words: self.words,
                mu_bits: self.chip.tile.mu_bits,
                sigma_bits: self.chip.tile.sigma_bits,
                adc_lsb_mu: self.adc_lsb_mu,
                adc_lsb_sigma: self.adc_lsb_sigma,
                adcs: self.adcs.as_mut_slice(),
                adc_offset_cal: self.adc_offset_cal.as_slice(),
                grng_offset_cal: self.grng_offset_cal.as_slice(),
            },
            self.eps_t.as_slice(),
        )
    }

    /// Energy bookkeeping for `n` MVMs (batched: one deposit per
    /// component instead of `n`). ε energy is deposited at refresh time.
    fn deposit_mvm_energy(&mut self, opts: MvmOptions, n: u64) {
        let nf = n as f64;
        let mu_bits = self.chip.tile.mu_bits;
        let sigma_bits = self.chip.tile.sigma_bits;
        let adc_per_word = mu_bits + sigma_bits;
        self.ledger.deposit(
            Component::Idac,
            nf * self.rows as f64 * self.chip.idac.energy_j,
        );
        let e = &self.chip.energy;
        let cells_active = self.rows * self.words * (2 * mu_bits + sigma_bits);
        self.ledger
            .deposit(Component::Sram, nf * cells_active as f64 * e.sram_cell_read_j);
        let adc_used = if opts.bayesian {
            self.words * adc_per_word
        } else {
            self.words * mu_bits
        };
        self.ledger
            .deposit(Component::Adc, nf * adc_used as f64 * self.chip.adc.energy_j);
        // Differential: 2 bitlines per column.
        self.ledger.deposit(
            Component::Bitline,
            nf * 2.0 * adc_used as f64 * e.bitline_precharge_j,
        );
        self.ledger.deposit(
            Component::Reduction,
            nf * self.words as f64 * e.reduction_word_j,
        );
        if opts.bayesian {
            self.ledger.deposit(
                Component::Switches,
                nf * (self.rows * self.words) as f64 * e.switch_word_j,
            );
        }
        self.ledger.deposit(
            Component::Leakage,
            nf * e.tile_leakage_w / self.chip.tile.clock_hz,
        );
        self.ledger.mvm_count += n;
    }

    /// Raw (uncorrected) column codes for one conversion with input `x` —
    /// used by the calibration controller to estimate ADC offsets.
    /// Deposits the corresponding conversion energy.
    pub fn raw_column_codes(&mut self, x: &[u8]) -> crate::error::Result<Vec<i64>> {
        if x.len() != self.rows {
            return Err(crate::error::Error::Calibration(
                "input length must equal tile rows".into(),
            ));
        }
        let mu_bits = self.chip.tile.mu_bits;
        let sigma_bits = self.chip.tile.sigma_bits;
        let adc_per_word = mu_bits + sigma_bits;
        let mut drives = std::mem::take(&mut self.scratch.drives);
        self.fill_drives(x, false, &mut drives);
        let mut codes = vec![0i64; self.words * adc_per_word];
        for w in 0..self.words {
            for b in 0..mu_bits {
                let mut q = 0.0;
                for r in 0..self.rows {
                    q += drives[r] * self.mu[r * self.words + w].digit(b) as f64;
                }
                codes[w * adc_per_word + b] =
                    self.adcs[w * adc_per_word + b].convert(q / self.adc_lsb_mu);
            }
            for b in 0..sigma_bits {
                let mut q = 0.0;
                for r in 0..self.rows {
                    if self.sigma[r * self.words + w].bit(b) == 1 {
                        q += drives[r] * self.eps_t[w * self.rows + r];
                    }
                }
                let idx = w * adc_per_word + mu_bits + b;
                codes[idx] = self.adcs[idx].convert(q / self.adc_lsb_sigma);
            }
        }
        self.scratch.drives = drives;
        self.ledger
            .deposit(Component::Adc, codes.len() as f64 * self.chip.adc.energy_j);
        Ok(codes)
    }

    /// Maximum input code of the IDAC.
    pub fn max_input_code(&self) -> u8 {
        (self.chip.idac.levels() - 1) as u8
    }

    /// The effective row drive for an input code (calibration math).
    pub fn drive_of_row_code(&self, row: usize, code: u8) -> f64 {
        let x_fs = (self.chip.idac.levels() - 1) as f64;
        self.idacs[row].drive(code) * x_fs
    }

    /// Draw a fresh ε matrix without running an MVM (also the per-sample
    /// refresh inside `mvm` and the serial arm of `mvm_batch`). The bank
    /// writes straight into the plane-major layout the MVM consumes.
    pub fn refresh_epsilon(&mut self) {
        self.bank.fill_epsilon_planes(&mut self.eps_t);
        self.deposit_grng_energy(1);
    }

    /// GRNG energy bookkeeping for `t` whole-bank refreshes.
    fn deposit_grng_energy(&mut self, t: u64) {
        let n = self.eps_t.len() as u64 * t;
        self.ledger.grng_samples += n;
        let grng_j = self.bank.mean_energy_per_sample() * n as f64;
        self.ledger.deposit(Component::Grng, grng_j);
    }

    /// Reseed every stochastic stream in the tile (GRNG cells, ADC noise)
    /// from SplitMix64 splits of `seed`, leaving all *static* die state —
    /// ADC offsets, IDAC bows, programmed words, calibration registers —
    /// untouched. This is how an MC-parallel replica models the same
    /// silicon drawing an independent sample sequence (cf. VIBNN's
    /// parallel RNG banks): clone the calibrated tile, reseed its streams.
    pub fn reseed_streams(&mut self, seed: u64) {
        let mut seeder = SplitMix64::new(seed ^ 0x5EED_57EA_4A11_0C95);
        self.bank.reseed_cells(seeder.split());
        for adc in &mut self.adcs {
            adc.reseed_noise(seeder.split());
        }
    }

    /// Bytes of die state this tile holds behind `Arc`s — counted once
    /// per model no matter how many replicas share it (μ/σ words, plane
    /// cache, IDAC bows, calibration registers, GRNG cell parameters).
    pub fn bytes_shared(&self) -> usize {
        self.mu.len() * std::mem::size_of::<MuWord>()
            + self.sigma.len() * std::mem::size_of::<SigmaWord>()
            + self.idacs.len() * std::mem::size_of::<Idac>()
            + (self.adc_offset_cal.len() + self.grng_offset_cal.len())
                * std::mem::size_of::<f64>()
            + self.planes.as_ref().map_or(0, |p| p.bytes())
            + self.bank.bytes_shared()
    }

    /// Bytes each replica of this tile owns privately: ε buffers, ADC
    /// noise streams, GRNG lane states, scratch. O(ε buffers + streams),
    /// not O(weights) — the point of the shared layer.
    pub fn bytes_private(&self) -> usize {
        (self.eps_t.len() + self.eps_spare.len()) * std::mem::size_of::<f64>()
            + self.adcs.len() * std::mem::size_of::<SarAdc>()
            + (self.scratch.drives.capacity() + self.scratch.row_terms.capacity())
                * std::mem::size_of::<f64>()
            + self.bank.bytes_private()
    }

    /// True when `other` shares this tile's immutable layer by pointer
    /// identity (the replica-fan-out invariant pinned by tests): same μ/σ
    /// word allocations, IDACs, calibration tables, plane cache, and GRNG
    /// cell parameters.
    pub fn shares_statics_with(&self, other: &CimTile) -> bool {
        Arc::ptr_eq(&self.mu, &other.mu)
            && Arc::ptr_eq(&self.sigma, &other.sigma)
            && Arc::ptr_eq(&self.idacs, &other.idacs)
            && Arc::ptr_eq(&self.adc_offset_cal, &other.adc_offset_cal)
            && Arc::ptr_eq(&self.grng_offset_cal, &other.grng_offset_cal)
            && match (&self.planes, &other.planes) {
                (Some(a), Some(b)) => Arc::ptr_eq(a, b),
                (None, None) => true,
                _ => false,
            }
            && self.bank.shares_params_with(&other.bank)
    }

    /// Install the calibrated per-cell ε₀ registers (len = rows × words,
    /// row-major). The canonical setter used by the calibration
    /// controller; the registers are read live by every MVM, so no plane
    /// invalidation is needed.
    pub fn set_grng_offset_cal(&mut self, est: &[f64]) {
        assert_eq!(est.len(), self.grng_offset_cal.len());
        Arc::make_mut(&mut self.grng_offset_cal).copy_from_slice(est);
    }

    /// Copy-on-write access to the ADC offset registers (calibration
    /// controller only): in-place during bring-up, a private copy if any
    /// replica still shares the old table.
    pub fn adc_offset_cal_mut(&mut self) -> &mut [f64] {
        Arc::make_mut(&mut self.adc_offset_cal)
    }

    /// Copy-on-write access to the GRNG ε₀ registers (calibration).
    pub fn grng_offset_cal_mut(&mut self) -> &mut [f64] {
        Arc::make_mut(&mut self.grng_offset_cal)
    }

    /// ADC LSB size of the σε path in charge units (calibration math).
    pub fn sigma_lsb(&self) -> f64 {
        self.adc_lsb_sigma
    }

    /// Index of the ADC for (word, σ bit-plane) in the flat ADC array.
    pub fn sigma_adc_index(&self, word: usize, bit: usize) -> usize {
        word * (self.chip.tile.mu_bits + self.chip.tile.sigma_bits) + self.chip.tile.mu_bits + bit
    }

    /// Exact digital reference of what the tile approximates:
    /// mu_j = Σ_i X_i·μ_ij, sigma_j = Σ_i X_i·σ_ij·ε_ij (same ε).
    pub fn mvm_reference(&self, x: &[u8], bayesian: bool) -> MvmResult {
        let mut out_mu = vec![0.0f64; self.words];
        let mut out_sigma = vec![0.0f64; self.words];
        for w in 0..self.words {
            for r in 0..self.rows {
                let i = r * self.words + w;
                out_mu[w] += x[r] as f64 * self.mu[i].value() as f64;
                if bayesian {
                    out_sigma[w] += x[r] as f64
                        * self.sigma[i].value() as f64
                        * self.eps_t[w * self.rows + r];
                }
            }
        }
        MvmResult {
            mu: out_mu,
            sigma: out_sigma,
        }
    }

    /// Per-MVM energy at steady state \[J\] (one fresh-ε Bayesian MVM).
    pub fn energy_per_mvm(&mut self) -> f64 {
        let x = vec![((self.chip.idac.levels() - 1) / 2) as u8; self.rows];
        self.ledger.reset();
        let _ = self.mvm(&x, MvmOptions::default());
        let j = self.ledger.total_j();
        self.ledger.reset();
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng64};
    use crate::util::stats::{pearson, Summary};

    fn make_tile() -> CimTile {
        CimTile::new(&ChipConfig::default())
    }

    fn random_program(tile: &mut CimTile, seed: u64, sigma_scale: f64) {
        let mut rng = Pcg64::new(seed);
        for r in 0..tile.rows() {
            for w in 0..tile.words() {
                let mu = (rng.next_f64() * 2.0 - 1.0) * 200.0;
                let sg = rng.next_f64() * sigma_scale;
                tile.program(r, w, mu, sg);
            }
        }
    }

    fn random_input(tile: &CimTile, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed ^ 0xF00D);
        (0..tile.rows())
            .map(|_| (rng.next_below(16)) as u8)
            .collect()
    }

    #[test]
    fn deterministic_mvm_tracks_reference() {
        let mut tile = make_tile();
        // The chip always runs calibrated (ADC offsets are corrected by
        // the reduction logic, §III-B); calibrate before measuring.
        crate::cim::calibration::calibrate(&mut tile, 16, 4).unwrap();
        random_program(&mut tile, 1, 0.0);
        let opts = MvmOptions {
            bayesian: false,
            refresh_epsilon: false,
            ideal_analog: false,
        };
        let mut ys = Vec::new();
        let mut refs = Vec::new();
        for s in 0..20 {
            let x = random_input(&tile, s);
            ys.extend(tile.mvm(&x, opts).combined());
            refs.extend(tile.mvm_reference(&x, false).combined());
        }
        let r = pearson(&ys, &refs);
        assert!(r > 0.99, "analog MVM should track digital reference, r={r}");
        // Scale should be ≈1 (reduction reconstructs absolute values).
        let sy = Summary::from_slice(&ys);
        let sr = Summary::from_slice(&refs);
        let gain = sy.std() / sr.std();
        assert!((0.9..1.1).contains(&gain), "gain {gain}");
    }

    #[test]
    fn ideal_analog_is_near_exact() {
        let mut tile = make_tile();
        random_program(&mut tile, 2, 0.0);
        let opts = MvmOptions {
            bayesian: false,
            refresh_epsilon: false,
            ideal_analog: true,
        };
        let x = random_input(&tile, 7);
        let y = tile.mvm(&x, opts).combined();
        let r = tile.mvm_reference(&x, false).combined();
        for (a, b) in y.iter().zip(r.iter()) {
            // Only ADC quantization (and clipping) remains.
            let tol = 8.0 * tile.adc_lsb_mu * 128.0; // worst-case bitplane rounding
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn bayesian_mvm_adds_variance_proportional_to_sigma() {
        let mut tile = make_tile();
        random_program(&mut tile, 3, 8.0);
        let x = random_input(&tile, 9);
        let opts = MvmOptions::default();
        let mut outs0 = Vec::new();
        for _ in 0..60 {
            outs0.push(tile.mvm(&x, opts).combined()[0]);
        }
        let var_low = Summary::from_slice(&outs0).variance();
        // Re-program with larger σ → larger output variance.
        random_program(&mut tile, 3, 15.0);
        let mut outs1 = Vec::new();
        for _ in 0..60 {
            outs1.push(tile.mvm(&x, opts).combined()[0]);
        }
        let var_high = Summary::from_slice(&outs1).variance();
        assert!(
            var_high > var_low,
            "σ↑ must increase output variance: {var_low} vs {var_high}"
        );
    }

    #[test]
    fn epsilon_refresh_control() {
        let mut tile = make_tile();
        random_program(&mut tile, 4, 8.0);
        let x = random_input(&tile, 11);
        let refresh = MvmOptions::default();
        let hold = MvmOptions {
            refresh_epsilon: false,
            ..MvmOptions::default()
        };
        let _ = tile.mvm(&x, refresh);
        let e1 = tile.last_epsilon().to_vec();
        let _ = tile.mvm(&x, hold);
        assert_eq!(tile.last_epsilon(), &e1[..], "ε must persist when held");
        let _ = tile.mvm(&x, refresh);
        assert_ne!(tile.last_epsilon(), &e1[..], "ε must change on refresh");
    }

    #[test]
    fn energy_breakdown_sram_dominates() {
        // Fig. 12: SRAM > 63 % of tile energy for one complete MVM.
        let mut tile = make_tile();
        random_program(&mut tile, 5, 8.0);
        let x = random_input(&tile, 13);
        tile.ledger.reset();
        let _ = tile.mvm(&x, MvmOptions::default());
        let total = tile.ledger.total_j();
        let sram = tile.ledger.component_j(Component::Sram);
        let share = sram / total;
        assert!(
            share > 0.55,
            "SRAM share {share:.3} should dominate (paper: >0.63)"
        );
        // NN efficiency ballpark (Tab. II: 672 fJ/Op).
        let fj_per_op = total / tile.chip.tile.ops_per_mvm() as f64 * 1e15;
        assert!(
            (400.0..1000.0).contains(&fj_per_op),
            "efficiency {fj_per_op:.0} fJ/Op should be ≈672"
        );
    }

    #[test]
    fn non_bayesian_mvm_cheaper() {
        let mut tile = make_tile();
        random_program(&mut tile, 6, 8.0);
        let x = random_input(&tile, 17);
        tile.ledger.reset();
        let _ = tile.mvm(&x, MvmOptions::default());
        let bayes_j = tile.ledger.total_j();
        tile.ledger.reset();
        let _ = tile.mvm(
            &x,
            MvmOptions {
                bayesian: false,
                ..MvmOptions::default()
            },
        );
        let det_j = tile.ledger.total_j();
        assert!(det_j < bayes_j, "μ-only MVM must cost less");
    }

    #[test]
    #[should_panic(expected = "input length")]
    fn wrong_input_length_panics() {
        let mut tile = make_tile();
        let _ = tile.mvm(&[0u8; 3], MvmOptions::default());
    }

    #[test]
    fn fast_path_matches_legacy_bitwise() {
        // Two identically seeded tiles: the SoA fast path and the AoS
        // legacy path must consume the same RNG streams and produce
        // bit-identical results (deeper sweep in tests/mvm_props.rs).
        let chip = ChipConfig::default();
        let mut fast = CimTile::new(&chip);
        let mut legacy = CimTile::new(&chip);
        random_program(&mut fast, 21, 9.0);
        random_program(&mut legacy, 21, 9.0);
        for s in 0..4 {
            let x = random_input(&fast, 31 + s);
            let a = fast.mvm(&x, MvmOptions::default());
            let b = legacy.mvm_legacy(&x, MvmOptions::default());
            assert_eq!(a.mu, b.mu);
            assert_eq!(a.sigma, b.sigma);
        }
    }

    #[test]
    fn mvm_batch_matches_sequential_bitwise() {
        let chip = ChipConfig::default();
        let mut batched = CimTile::new(&chip);
        let mut serial = CimTile::new(&chip);
        random_program(&mut batched, 22, 7.0);
        random_program(&mut serial, 22, 7.0);
        let x = random_input(&batched, 5);
        let t = 6;
        let ys = batched.mvm_batch(&x, t, MvmOptions::default());
        assert_eq!(ys.len(), t);
        for y in &ys {
            let r = serial.mvm(&x, MvmOptions::default());
            assert_eq!(y.mu, r.mu);
            assert_eq!(y.sigma, r.sigma);
        }
        assert_eq!(batched.ledger.mvm_count, serial.ledger.mvm_count);
        assert_eq!(batched.ledger.grng_samples, serial.ledger.grng_samples);
    }

    #[test]
    fn mvm_batch_pipelined_matches_sequential_bitwise() {
        // t ≥ EPSILON_PIPELINE_MIN_T engages the double-buffered ε
        // pipeline; outputs must stay bit-identical to back-to-back
        // serial mvm calls, and the tile's final ε must be the last
        // sample's (the mvm_reference/last_epsilon contract).
        let chip = ChipConfig::default();
        let mut batched = CimTile::new(&chip);
        let mut serial = CimTile::new(&chip);
        random_program(&mut batched, 29, 9.0);
        random_program(&mut serial, 29, 9.0);
        let x = random_input(&batched, 31);
        let t = 8;
        assert!(t >= super::EPSILON_PIPELINE_MIN_T);
        assert!(chip.tile.rows * chip.tile.words_per_row >= super::EPSILON_PIPELINE_MIN_CELLS);
        let ys = batched.mvm_batch(&x, t, MvmOptions::default());
        assert_eq!(ys.len(), t);
        for y in &ys {
            let r = serial.mvm(&x, MvmOptions::default());
            assert_eq!(y.mu, r.mu);
            assert_eq!(y.sigma, r.sigma);
        }
        assert_eq!(batched.last_epsilon(), serial.last_epsilon());
        assert_eq!(batched.ledger.grng_samples, serial.ledger.grng_samples);
        assert_eq!(batched.ledger.mvm_count, serial.ledger.mvm_count);
    }

    #[test]
    fn reseed_streams_changes_samples_not_statics() {
        let chip = ChipConfig::default();
        let mut a = CimTile::new(&chip);
        let mut b = CimTile::new(&chip);
        random_program(&mut a, 23, 8.0);
        random_program(&mut b, 23, 8.0);
        b.reseed_streams(0xFEED);
        // Static die state unchanged: μ-only ideal MVMs agree bitwise.
        let x = random_input(&a, 9);
        let det = MvmOptions {
            bayesian: false,
            refresh_epsilon: false,
            ideal_analog: true,
        };
        assert_eq!(a.mvm(&x, det).mu, b.mvm(&x, det).mu);
        // Stochastic streams diverge: fresh ε differs.
        a.refresh_epsilon();
        b.refresh_epsilon();
        assert_ne!(a.last_epsilon(), b.last_epsilon());
        // Reseeding is deterministic: same seed → same stream.
        let mut c = CimTile::new(&chip);
        random_program(&mut c, 23, 8.0);
        c.reseed_streams(0xFEED);
        c.refresh_epsilon();
        assert_eq!(b.last_epsilon(), c.last_epsilon());
    }

    #[test]
    fn clone_shares_immutable_layer_and_cow_detaches_it() {
        let mut tile = make_tile();
        random_program(&mut tile, 41, 8.0);
        crate::cim::calibration::calibrate(&mut tile, 8, 2).unwrap();
        tile.warm_planes();
        let mut replica = tile.clone();
        // The clone shares every static plane by pointer identity and
        // owns only stream-sized private state.
        assert!(tile.shares_statics_with(&replica));
        assert!(
            replica.bytes_private() < tile.bytes_shared(),
            "private {} must be smaller than shared {}",
            replica.bytes_private(),
            tile.bytes_shared()
        );
        // Reseeding streams must not detach the shared layer…
        replica.reseed_streams(0xABCD);
        assert!(tile.shares_statics_with(&replica));
        // …and MVMs on the shared planes stay bit-identical to a private
        // deep copy of the same die (the pre-split behavior).
        let x = random_input(&tile, 3);
        let det = MvmOptions {
            bayesian: false,
            refresh_epsilon: false,
            ideal_analog: true,
        };
        assert_eq!(tile.mvm(&x, det).mu, replica.mvm(&x, det).mu);
        // A word write copies-on-write: the writer detaches, the other
        // replica keeps reading the original planes.
        let before = tile.mu_value(0, 0);
        replica.program(0, 0, 100.0, 1.0);
        assert!(!tile.shares_statics_with(&replica));
        assert_eq!(tile.mu_value(0, 0), before, "CoW must not leak into peers");
    }
}
