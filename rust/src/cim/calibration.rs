//! Calibration controller (§III-C.3, Eq. 8–10).
//!
//! Two static error sources are measured once per die and corrected
//! digitally forever after:
//!
//! 1. **ADC offsets** — with all inputs zero, every column ADC should read
//!    code 0; the measured mean is stored in the reduction logic's
//!    offset-correction registers.
//! 2. **GRNG mean offsets ε₀** — transistor mismatch gives each in-word
//!    GRNG a static nonzero mean (Eq. 8). Following the paper's procedure:
//!    write 1 to all σ words, drive each row with X = 1 sequentially, and
//!    average many conversions; the per-cell offset estimate is then folded
//!    into the weights (Eq. 9–10). In this implementation the correction
//!    is held in a per-cell register applied by the reduction logic, which
//!    is numerically identical to the paper's μ′ = μ − σ·ε₀ fold once the
//!    MVM recombines the paths.
//!
//! The paper reports the whole procedure costs 3.6 nJ once per chip; the
//! ledger records the simulated cost for comparison.

use crate::cim::tile::{CimTile, MvmOptions};
use crate::error::{Error, Result};

/// Calibration report (returned for logging / EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    /// Conversions used per ADC offset estimate.
    pub adc_avg_n: usize,
    /// Conversions used per GRNG cell offset estimate.
    pub grng_avg_n: usize,
    /// RMS of the estimated ADC offsets \[LSB\].
    pub adc_offset_rms_lsb: f64,
    /// RMS of the estimated ε₀ offsets.
    pub grng_offset_rms: f64,
    /// Residual RMS error of the ε₀ estimates vs the die's ground truth.
    pub grng_residual_rms: f64,
    /// Total energy consumed by calibration \[J\] (paper: 3.6 nJ).
    pub energy_j: f64,
}

/// Run the full calibration sequence on a tile.
pub fn calibrate(tile: &mut CimTile, adc_avg_n: usize, grng_avg_n: usize) -> Result<CalibrationReport> {
    if adc_avg_n == 0 || grng_avg_n == 0 {
        return Err(Error::Calibration("averaging counts must be > 0".into()));
    }
    let start_j = tile.ledger.total_j();
    let rows = tile.rows();
    let words = tile.words();

    // ---- Phase 1: ADC offsets (zero input, μ-only path exercises all
    // ADCs when σ=1 written and bayesian on) ----
    // Save σ state? The controller runs before weights are programmed
    // (chip bring-up), so we just use the current state and restore σ=0.
    let zero_x = vec![0u8; rows];
    tile.adc_offset_cal_mut().iter_mut().for_each(|v| *v = 0.0);
    // Write σ = 1 everywhere so σε columns convert too (paper procedure).
    for r in 0..rows {
        for w in 0..words {
            tile.write_sigma_raw(r, w, 1);
        }
    }
    let adc_n = tile.adc_offset_cal.len();
    let mut adc_acc = vec![0.0f64; adc_n];
    for _ in 0..adc_avg_n {
        // With X = 0 every column charge is 0, so raw codes ≈ offsets.
        let codes = tile.raw_column_codes(&zero_x)?;
        for (a, c) in adc_acc.iter_mut().zip(codes.iter()) {
            *a += *c as f64;
        }
    }
    for (cal, acc) in tile.adc_offset_cal_mut().iter_mut().zip(adc_acc.iter()) {
        *cal = *acc / adc_avg_n as f64;
    }
    let adc_offset_rms_lsb = rms(&tile.adc_offset_cal);

    // ---- Phase 2: GRNG ε₀ offsets (σ=1, row-by-row) ----
    // The estimate reads the σε bit-0 *column codes* directly (the
    // reduction logic sees per-column ADC outputs), so the μ subarray
    // contributes nothing and no baseline subtraction is needed. The
    // paper describes "multiplying each row by 1"; with our ADC full
    // scale a unit drive puts |ε| ≈ 0.1 LSB at the converter — far below
    // quantization — so the controller drives the row at FULL input code
    // instead, which is the same measurement at measurable gain (the
    // estimate divides the drive back out).
    tile.grng_offset_cal_mut().iter_mut().for_each(|v| *v = 0.0);
    let mut grng_est = vec![0.0f64; rows * words];
    let lsb = tile.sigma_lsb();
    let max_code = tile.max_input_code();
    for r in 0..rows {
        let mut x = vec![0u8; rows];
        x[r] = max_code;
        let mut acc = vec![0.0f64; words];
        for _ in 0..grng_avg_n {
            tile.refresh_epsilon();
            let codes = tile.raw_column_codes(&x)?;
            for w in 0..words {
                let idx = tile.sigma_adc_index(w, 0);
                acc[w] += codes[idx] as f64 - tile.adc_offset_cal[idx];
            }
        }
        let drive = tile.drive_of_row_code(r, max_code);
        for w in 0..words {
            grng_est[r * words + w] = acc[w] / grng_avg_n as f64 * lsb / drive;
        }
    }
    // Install corrections: the register stores ε₀ per cell; the MVM
    // subtracts drive·σ·ε₀ per active row (numerically Eq. 10). The
    // registers are read live by the SoA fast path, so installing them
    // does not invalidate the plane cache.
    tile.set_grng_offset_cal(&grng_est);

    // Residual vs ground truth.
    let truth = tile.bank.true_offsets();
    let residuals: Vec<f64> = truth
        .iter()
        .zip(grng_est.iter())
        .map(|(t, e)| t - e)
        .collect();

    // Reset σ words to 0 (weights get programmed after calibration).
    for r in 0..rows {
        for w in 0..words {
            tile.write_sigma_raw(r, w, 0);
        }
    }

    Ok(CalibrationReport {
        adc_avg_n,
        grng_avg_n,
        adc_offset_rms_lsb,
        grng_offset_rms: rms(&grng_est),
        grng_residual_rms: rms(&residuals),
        energy_j: tile.ledger.total_j() - start_j,
    })
}

fn rms(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::tile::CimTile;
    use crate::config::ChipConfig;

    #[test]
    fn calibration_reduces_offset_error() {
        let mut chip = ChipConfig::default();
        // Small tile keeps the test fast; physics unchanged.
        chip.tile.rows = 8;
        chip.tile.words_per_row = 4;
        let mut tile = CimTile::new(&chip);
        let truth = tile.bank.true_offsets();
        let truth_rms = rms(&truth);
        let report = calibrate(&mut tile, 16, 64).unwrap();
        assert!(
            report.grng_residual_rms < 0.6 * truth_rms,
            "calibration must cut ε₀ error: residual {:.3} vs raw {:.3}",
            report.grng_residual_rms,
            truth_rms
        );
        assert!(report.energy_j > 0.0);
    }

    #[test]
    fn calibration_energy_order_of_magnitude() {
        // Paper: 3.6 nJ for the full procedure on the 64×8 tile.
        let chip = ChipConfig::default();
        let mut tile = CimTile::new(&chip);
        let report = calibrate(&mut tile, 4, 8).unwrap();
        assert!(
            (1e-10..1e-5).contains(&report.energy_j),
            "calibration energy {:.3e} J should be nJ–µJ scale",
            report.energy_j
        );
    }

    #[test]
    fn zero_average_counts_rejected() {
        let chip = ChipConfig::default();
        let mut tile = CimTile::new(&chip);
        assert!(calibrate(&mut tile, 0, 8).is_err());
        assert!(calibrate(&mut tile, 8, 0).is_err());
    }
}
