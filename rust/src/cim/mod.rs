//! Compute-in-memory tile simulator (§III-B/D): memory-word encodings,
//! data converters, the two-subarray tile, multi-tile arrays, and the
//! static-variation calibration controller.

pub mod adc;
pub mod array;
pub mod calibration;
pub mod idac;
pub mod tile;
pub mod word;

pub use array::TileArray;
pub use calibration::{calibrate, CalibrationReport};
pub use tile::{CimTile, MvmOptions};
pub use word::{MuWord, SigmaWord, WeightScale};
