//! Multi-tile array: maps FC layers larger than one 64×8 tile onto a grid
//! of tiles, accumulating partial sums digitally across row-chunks (the
//! standard CIM tiling scheme; the prototype chip contains one tile, the
//! architecture scales by replication).

use crate::cim::tile::{CimTile, MvmOptions};
use crate::config::ChipConfig;
use crate::energy::EnergyLedger;

/// A grid of CIM tiles implementing a `in_dim × out_dim` matrix.
#[derive(Clone)]
pub struct TileArray {
    pub chip: ChipConfig,
    pub in_dim: usize,
    pub out_dim: usize,
    tiles_x: usize,
    tiles_y: usize,
    /// Row-major over (tile_row, tile_col) = (input chunk, output chunk).
    tiles: Vec<CimTile>,
    /// Reusable zero-padded input chunk (no per-MVM allocation).
    chunk: Vec<u8>,
}

impl TileArray {
    pub fn new(chip: &ChipConfig, in_dim: usize, out_dim: usize) -> Self {
        assert!(in_dim > 0 && out_dim > 0);
        let rows = chip.tile.rows;
        let words = chip.tile.words_per_row;
        let tiles_x = in_dim.div_ceil(rows);
        let tiles_y = out_dim.div_ceil(words);
        let mut tiles = Vec::with_capacity(tiles_x * tiles_y);
        for t in 0..tiles_x * tiles_y {
            let mut c = chip.clone();
            // Distinct die seed per tile: separate silicon instances.
            c.die_seed = chip.die_seed.wrapping_add(1 + t as u64);
            tiles.push(CimTile::new(&c));
        }
        Self {
            chip: chip.clone(),
            in_dim,
            out_dim,
            tiles_x,
            tiles_y,
            tiles,
            chunk: vec![0u8; rows],
        }
    }

    /// Reseed every tile's stochastic streams (GRNG cells, ADC noise)
    /// from SplitMix64 splits of `seed`; static die state is untouched.
    /// See [`CimTile::reseed_streams`].
    pub fn reseed_streams(&mut self, seed: u64) {
        let mut seeder = crate::util::rng::SplitMix64::new(seed ^ 0xA88A_F1E1_D5E2_0B17);
        for t in &mut self.tiles {
            t.reseed_streams(seeder.split());
        }
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    pub fn tiles(&self) -> &[CimTile] {
        &self.tiles
    }

    pub fn tiles_mut(&mut self) -> &mut [CimTile] {
        &mut self.tiles
    }

    /// Program from fixed-point μ/σ matrices (row-major \[in_dim\]\[out_dim\]).
    /// Out-of-matrix tile cells are zero-padded (σ=0, μ≈0).
    pub fn program_matrix(&mut self, mu_fixed: &[f64], sigma_fixed: &[f64]) {
        assert_eq!(mu_fixed.len(), self.in_dim * self.out_dim);
        assert_eq!(sigma_fixed.len(), self.in_dim * self.out_dim);
        let rows = self.chip.tile.rows;
        let words = self.chip.tile.words_per_row;
        for tx in 0..self.tiles_x {
            for ty in 0..self.tiles_y {
                let tile = &mut self.tiles[tx * self.tiles_y + ty];
                for r in 0..rows {
                    let gi = tx * rows + r;
                    for w in 0..words {
                        let go = ty * words + w;
                        if gi < self.in_dim && go < self.out_dim {
                            let idx = gi * self.out_dim + go;
                            tile.program(r, w, mu_fixed[idx], sigma_fixed[idx]);
                        } else {
                            tile.program(r, w, 0.0, 0.0);
                        }
                    }
                }
            }
        }
    }

    /// MVM over the full array: input codes (len = in_dim) → accumulated
    /// per-path outputs (len = out_dim each) in fixed-point units.
    ///
    /// Padding correction: μ cells cannot store exact zero (odd-integer
    /// grid), so padded rows would contribute ±1·X. Padded *inputs* are
    /// zero (X=0 ⇒ no current), so only padded outputs need masking.
    pub fn mvm(&mut self, x_codes: &[u8], opts: MvmOptions) -> crate::cim::tile::MvmResult {
        assert_eq!(x_codes.len(), self.in_dim, "input length mismatch");
        let rows = self.chip.tile.rows;
        let words = self.chip.tile.words_per_row;
        let mut out_mu = vec![0.0f64; self.out_dim];
        let mut out_sigma = vec![0.0f64; self.out_dim];
        let mut chunk = std::mem::take(&mut self.chunk);
        for tx in 0..self.tiles_x {
            fill_chunk(&mut chunk, rows, x_codes, tx);
            for ty in 0..self.tiles_y {
                let tile = &mut self.tiles[tx * self.tiles_y + ty];
                let y = tile.mvm(&chunk, opts);
                for w in 0..words {
                    let go = ty * words + w;
                    if go < self.out_dim {
                        out_mu[go] += y.mu[w];
                        out_sigma[go] += y.sigma[w];
                    }
                }
            }
        }
        self.chunk = chunk;
        crate::cim::tile::MvmResult {
            mu: out_mu,
            sigma: out_sigma,
        }
    }

    /// `t` Monte-Carlo MVMs of the same input across the whole array.
    /// Each tile runs its `t` samples back to back ([`CimTile::mvm_batch`]
    /// — drives and plane caches amortized, and for `t >= 4` on
    /// full-size banks each tile
    /// double-buffers ε generation against its conversions); because
    /// every tile owns its private RNG streams, the per-tile stream order
    /// is identical to `t` sequential [`TileArray::mvm`] calls, so result
    /// `s` is bit-identical to the `s`-th sequential call.
    pub fn mvm_batch(
        &mut self,
        x_codes: &[u8],
        t: usize,
        opts: MvmOptions,
    ) -> Vec<crate::cim::tile::MvmResult> {
        assert_eq!(x_codes.len(), self.in_dim, "input length mismatch");
        let rows = self.chip.tile.rows;
        let words = self.chip.tile.words_per_row;
        let mut out: Vec<crate::cim::tile::MvmResult> = (0..t)
            .map(|_| crate::cim::tile::MvmResult {
                mu: vec![0.0f64; self.out_dim],
                sigma: vec![0.0f64; self.out_dim],
            })
            .collect();
        let mut chunk = std::mem::take(&mut self.chunk);
        for tx in 0..self.tiles_x {
            fill_chunk(&mut chunk, rows, x_codes, tx);
            for ty in 0..self.tiles_y {
                let tile = &mut self.tiles[tx * self.tiles_y + ty];
                let ys = tile.mvm_batch(&chunk, t, opts);
                for (s, y) in ys.iter().enumerate() {
                    for w in 0..words {
                        let go = ty * words + w;
                        if go < self.out_dim {
                            out[s].mu[go] += y.mu[w];
                            out[s].sigma[go] += y.sigma[w];
                        }
                    }
                }
            }
        }
        self.chunk = chunk;
        out
    }

    /// Exact digital reference across the array (same ε as last mvm).
    pub fn mvm_reference(&self, x_codes: &[u8], bayesian: bool) -> crate::cim::tile::MvmResult {
        let rows = self.chip.tile.rows;
        let words = self.chip.tile.words_per_row;
        let mut out_mu = vec![0.0f64; self.out_dim];
        let mut out_sigma = vec![0.0f64; self.out_dim];
        for tx in 0..self.tiles_x {
            let mut chunk = vec![0u8; rows];
            for r in 0..rows {
                let gi = tx * rows + r;
                if gi < self.in_dim {
                    chunk[r] = x_codes[gi];
                }
            }
            for ty in 0..self.tiles_y {
                let tile = &self.tiles[tx * self.tiles_y + ty];
                let y = tile.mvm_reference(&chunk, bayesian);
                for w in 0..words {
                    let go = ty * words + w;
                    if go < self.out_dim {
                        out_mu[go] += y.mu[w];
                        out_sigma[go] += y.sigma[w];
                    }
                }
            }
        }
        crate::cim::tile::MvmResult {
            mu: out_mu,
            sigma: out_sigma,
        }
    }

    /// Eagerly build every tile's SoA plane cache so replica clones share
    /// the planes through their `Arc`s (see [`CimTile::warm_planes`]).
    pub fn warm_planes(&mut self) {
        for t in &mut self.tiles {
            t.warm_planes();
        }
    }

    /// Bytes of `Arc`-shared die state across all tiles (counted once per
    /// model, however many replicas share it).
    pub fn bytes_shared(&self) -> usize {
        self.tiles.iter().map(|t| t.bytes_shared()).sum()
    }

    /// Bytes each replica owns privately (ε buffers + streams + scratch).
    pub fn bytes_private(&self) -> usize {
        self.tiles.iter().map(|t| t.bytes_private()).sum::<usize>()
            + self.chunk.capacity() * std::mem::size_of::<u8>()
    }

    /// True when `other` is a replica sharing this array's immutable
    /// layer tile for tile (pointer identity, not value equality).
    pub fn shares_statics_with(&self, other: &TileArray) -> bool {
        self.tiles.len() == other.tiles.len()
            && self
                .tiles
                .iter()
                .zip(other.tiles.iter())
                .all(|(a, b)| a.shares_statics_with(b))
    }

    /// Aggregate energy ledger across tiles.
    pub fn ledger(&self) -> EnergyLedger {
        let mut total = EnergyLedger::new();
        for t in &self.tiles {
            total.absorb(&t.ledger);
        }
        total
    }

    pub fn reset_ledgers(&mut self) {
        for t in &mut self.tiles {
            t.ledger.reset();
        }
    }
}

/// Zero-padded input chunk for tile row-block `tx` (reusable buffer).
fn fill_chunk(chunk: &mut Vec<u8>, rows: usize, x_codes: &[u8], tx: usize) {
    chunk.clear();
    chunk.resize(rows, 0);
    for (r, slot) in chunk.iter_mut().enumerate() {
        let gi = tx * rows + r;
        if gi < x_codes.len() {
            *slot = x_codes[gi];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng64};
    use crate::util::stats::pearson;

    fn small_chip() -> ChipConfig {
        let mut chip = ChipConfig::default();
        chip.tile.rows = 16;
        chip.tile.words_per_row = 4;
        chip
    }

    #[test]
    fn grid_dimensions() {
        let chip = small_chip();
        let arr = TileArray::new(&chip, 40, 10);
        // ceil(40/16)=3 input chunks × ceil(10/4)=3 output chunks
        assert_eq!(arr.tile_count(), 9);
    }

    #[test]
    fn array_mvm_tracks_reference_across_tiles() {
        let chip = small_chip();
        let in_dim = 40;
        let out_dim = 10;
        let mut arr = TileArray::new(&chip, in_dim, out_dim);
        for t in arr.tiles_mut() {
            crate::cim::calibration::calibrate(t, 16, 4).unwrap();
        }
        let mut rng = Pcg64::new(3);
        let mu: Vec<f64> = (0..in_dim * out_dim)
            .map(|_| (rng.next_f64() * 2.0 - 1.0) * 200.0)
            .collect();
        let sigma = vec![0.0; in_dim * out_dim];
        arr.program_matrix(&mu, &sigma);
        let opts = MvmOptions {
            bayesian: false,
            refresh_epsilon: false,
            ideal_analog: false,
        };
        let mut ys = Vec::new();
        let mut refs = Vec::new();
        for s in 0..12 {
            let x: Vec<u8> = {
                let mut r2 = Pcg64::new(s);
                (0..in_dim).map(|_| r2.next_below(16) as u8).collect()
            };
            ys.extend(arr.mvm(&x, opts).combined());
            refs.extend(arr.mvm_reference(&x, false).combined());
        }
        let r = pearson(&ys, &refs);
        // Each of the 3 input chunks adds an independent ADC conversion
        // per output, so the multi-tile bound is looser than single-tile.
        assert!(r > 0.98, "array output must track reference, r={r}");
    }

    #[test]
    fn ledger_aggregates_tiles() {
        let chip = small_chip();
        let mut arr = TileArray::new(&chip, 32, 8);
        arr.program_matrix(&vec![1.0; 32 * 8], &vec![0.0; 32 * 8]);
        arr.reset_ledgers();
        let x = vec![7u8; 32];
        let _ = arr.mvm(&x, MvmOptions::default());
        let ledger = arr.ledger();
        assert_eq!(ledger.mvm_count, arr.tile_count() as u64);
        assert!(ledger.total_j() > 0.0);
    }

    #[test]
    fn array_mvm_batch_matches_sequential_bitwise() {
        let chip = small_chip();
        let in_dim = 40;
        let out_dim = 10;
        let mut batched = TileArray::new(&chip, in_dim, out_dim);
        let mut serial = TileArray::new(&chip, in_dim, out_dim);
        let mut rng = Pcg64::new(11);
        let mu: Vec<f64> = (0..in_dim * out_dim)
            .map(|_| (rng.next_f64() * 2.0 - 1.0) * 150.0)
            .collect();
        let sg: Vec<f64> = (0..in_dim * out_dim).map(|_| rng.next_f64() * 9.0).collect();
        batched.program_matrix(&mu, &sg);
        serial.program_matrix(&mu, &sg);
        let x: Vec<u8> = (0..in_dim).map(|_| rng.next_below(16) as u8).collect();
        let t = 5;
        let ys = batched.mvm_batch(&x, t, MvmOptions::default());
        for y in &ys {
            let r = serial.mvm(&x, MvmOptions::default());
            assert_eq!(y.mu, r.mu);
            assert_eq!(y.sigma, r.sigma);
        }
        assert_eq!(batched.ledger().mvm_count, serial.ledger().mvm_count);
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn wrong_input_length() {
        let chip = small_chip();
        let mut arr = TileArray::new(&chip, 32, 8);
        let _ = arr.mvm(&[0u8; 5], MvmOptions::default());
    }
}
