//! Row IDAC model (§III-D): 4-bit digital input → read-wordline voltage →
//! cell current linearly proportional to X_i, with a static integral
//! nonlinearity (INL) bow per instance.

use crate::config::IdacConfig;
use crate::util::rng::{Pcg64, Rng64};

/// One row's IDAC. The nonlinearity is static per instance (process
/// variation), drawn at construction from the die seed.
#[derive(Clone, Debug)]
pub struct Idac {
    cfg: IdacConfig,
    /// Static INL bow coefficient (relative, applied as a parabola that
    /// vanishes at 0 and full scale — the classic DAC bow shape).
    bow: f64,
    /// Static gain error (relative).
    gain_err: f64,
}

impl Idac {
    pub fn new(cfg: &IdacConfig, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0x1DAC);
        Self {
            cfg: cfg.clone(),
            bow: cfg.inl_rel * rng.next_gaussian(),
            gain_err: 0.25 * cfg.inl_rel * rng.next_gaussian(),
        }
    }

    /// Ideal transfer: code → normalized drive in [0, 1].
    pub fn ideal_drive(&self, code: u8) -> f64 {
        let max = (self.cfg.levels() - 1) as f64;
        (code.min((self.cfg.levels() - 1) as u8) as f64) / max
    }

    /// Actual normalized drive including INL bow and gain error.
    pub fn drive(&self, code: u8) -> f64 {
        let x = self.ideal_drive(code);
        let bow = self.bow * 4.0 * x * (1.0 - x); // zero at rails, max mid-scale
        (x * (1.0 + self.gain_err) + bow).max(0.0)
    }

    /// Cell current for a given input code \[A\] (per unit cell conductance).
    pub fn current(&self, code: u8) -> f64 {
        self.drive(code) * self.cfg.lsb_current_a * (self.cfg.levels() - 1) as f64
    }

    /// Per-conversion energy \[J\].
    pub fn energy_j(&self) -> f64 {
        self.cfg.energy_j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drive_monotonic_and_bounded() {
        let cfg = IdacConfig::default();
        let idac = Idac::new(&cfg, 3);
        let mut prev = -1.0;
        for code in 0..16u8 {
            let d = idac.drive(code);
            assert!(d >= 0.0 && d <= 1.05, "drive {d} out of range");
            assert!(d > prev, "drive must be monotonic (INL is small)");
            prev = d;
        }
    }

    #[test]
    fn rails_are_exact_up_to_gain() {
        let cfg = IdacConfig::default();
        let idac = Idac::new(&cfg, 4);
        assert_eq!(idac.drive(0), 0.0);
        let fs = idac.drive(15);
        assert!((fs - 1.0).abs() < 0.02, "full scale {fs}");
    }

    #[test]
    fn current_scales_with_code() {
        let cfg = IdacConfig::default();
        let idac = Idac::new(&cfg, 5);
        let i15 = idac.current(15);
        let i1 = idac.current(1);
        assert!(i15 > 10.0 * i1);
        assert!(i15 <= cfg.lsb_current_a * 15.0 * 1.05);
    }

    #[test]
    fn instances_differ_but_deterministic() {
        let cfg = IdacConfig::default();
        let a = Idac::new(&cfg, 1);
        let b = Idac::new(&cfg, 1);
        let c = Idac::new(&cfg, 2);
        assert_eq!(a.drive(7), b.drive(7));
        assert_ne!(a.drive(7), c.drive(7));
    }
}
