//! CIM memory-word encodings (§III-D, Fig. 5).
//!
//! - **μ words**: 8-bit, *differential* — every bit is stored in 2 SRAM
//!   cells; `0,1` encodes a positive bit contribution (+1 on BL_P) and
//!   `1,0` a negative one (−1 on BL_N). The word value is therefore a
//!   signed-digit number Σ_b d_b·2^b with digits d ∈ {−1, +1} — exactly
//!   the set of odd integers in [−(2^B−1), 2^B−1]. Quantizers that target
//!   this grid are provided here.
//! - **σ words**: 4-bit unsigned magnitude, one cell per bit; the sign
//!   comes from the GRNG's P/N steering, the magnitude from the pulse
//!   width, so the stored value only scales the current.

/// A μ word: digits ∈ {−1,+1} per bit (differential encoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MuWord {
    /// Packed digits: bit b set ⇒ digit +1, clear ⇒ digit −1.
    pub digits: u16,
    pub bits: u8,
}

impl MuWord {
    /// Decode to the signed integer value Σ d_b·2^b.
    pub fn value(&self) -> i32 {
        let mut v = 0i32;
        for b in 0..self.bits {
            let d = if (self.digits >> b) & 1 == 1 { 1 } else { -1 };
            v += d << b;
        }
        v
    }

    /// Digit of bit-plane `b` as ±1.
    #[inline]
    pub fn digit(&self, b: usize) -> i32 {
        if (self.digits >> b) & 1 == 1 {
            1
        } else {
            -1
        }
    }

    /// Digit of bit-plane `b` as ±1.0 — the value the SoA plane cache
    /// stores so the MVM inner loop is a branch-free multiply-accumulate.
    /// Must stay exactly `digit(b) as f64` (the fast path is pinned
    /// bit-identical to the per-word path).
    #[inline]
    pub fn digit_f64(&self, b: usize) -> f64 {
        self.digit(b) as f64
    }

    /// Encode the nearest representable value to `x`.
    ///
    /// The representable set for B bits is the odd integers in
    /// [−(2^B−1), 2^B−1]; encoding picks digits greedily from the MSB
    /// (the residual after choosing d_b is always representable).
    pub fn quantize(x: f64, bits: u8) -> MuWord {
        assert!(bits >= 1 && bits <= 15);
        let max = (1i32 << bits) - 1;
        let clamped = x.clamp(-(max as f64), max as f64);
        let mut digits = 0u16;
        let mut residual = clamped;
        for b in (0..bits).rev() {
            let w = 1i32 << b;
            if residual >= 0.0 {
                digits |= 1 << b;
                residual -= w as f64;
            } else {
                residual += w as f64;
            }
        }
        MuWord { digits, bits }
    }

    /// Quantization step of the signed-digit grid (odd integers ⇒ 2).
    pub const STEP: f64 = 2.0;
}

/// A σ word: unsigned magnitude, one SRAM cell per bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SigmaWord {
    pub code: u8,
    pub bits: u8,
}

impl SigmaWord {
    pub fn value(&self) -> u32 {
        self.code as u32
    }

    #[inline]
    pub fn bit(&self, b: usize) -> u32 {
        ((self.code >> b) & 1) as u32
    }

    /// Bit of plane `b` as 0.0/1.0 — the mask the SoA plane cache stores.
    /// Multiplying by 1.0 is exact, so masking keeps the fast path
    /// bit-identical to the skip-if-zero per-word path.
    #[inline]
    pub fn bit_f64(&self, b: usize) -> f64 {
        self.bit(b) as f64
    }

    /// Quantize a non-negative σ to the code grid.
    pub fn quantize(x: f64, bits: u8) -> SigmaWord {
        assert!(bits >= 1 && bits <= 8);
        let max = (1u32 << bits) - 1;
        let code = x.round().clamp(0.0, max as f64) as u8;
        SigmaWord { code, bits }
    }

    pub fn max_code(bits: u8) -> u32 {
        (1u32 << bits) - 1
    }
}

/// Fixed-point scaling plan for mapping float weights onto the words.
///
/// μ and σ live in *separate* subarrays with separate ADCs and separate
/// reduction shifts (Fig. 3), so each path gets its own scale: μ fills
/// the 8-bit signed-digit grid, σ fills the 4-bit magnitude grid. The
/// recombination `y = y_mu/mu_scale + y_sigma/sigma_scale` restores the
/// float decomposition w = μ + σ·ε.
#[derive(Clone, Copy, Debug)]
pub struct WeightScale {
    /// Float → fixed multiplier for μ.
    pub mu_scale: f64,
    /// Float → fixed multiplier for σ.
    pub sigma_scale: f64,
    pub mu_bits: u8,
    pub sigma_bits: u8,
}

impl WeightScale {
    /// Choose scales from the layer's max |μ| and max σ.
    pub fn fit(mu_abs_max: f64, sigma_max: f64, mu_bits: u8, sigma_bits: u8) -> WeightScale {
        let mu_grid = ((1i32 << mu_bits) - 1) as f64;
        let sigma_grid = ((1u32 << sigma_bits) - 1) as f64;
        WeightScale {
            mu_scale: mu_grid / mu_abs_max.max(1e-12),
            sigma_scale: sigma_grid / sigma_max.max(1e-12),
            mu_bits,
            sigma_bits,
        }
    }

    pub fn encode_mu(&self, mu_f: f64) -> MuWord {
        MuWord::quantize(mu_f * self.mu_scale, self.mu_bits)
    }

    pub fn encode_sigma(&self, sigma_f: f64) -> SigmaWord {
        // Small σ quantize to 0 (pruned noise) — the behaviour that the
        // Fig. 11-left σ-precision sweep stresses.
        SigmaWord::quantize(sigma_f.max(0.0) * self.sigma_scale, self.sigma_bits)
    }

    pub fn decode_mu(&self, fixed: f64) -> f64 {
        fixed / self.mu_scale
    }

    pub fn decode_sigma(&self, fixed: f64) -> f64 {
        fixed / self.sigma_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_roundtrip_all_odd_values() {
        for v in (-255..=255).filter(|v| v % 2 != 0) {
            let w = MuWord::quantize(v as f64, 8);
            assert_eq!(w.value(), v, "encode/decode of {v}");
        }
    }

    #[test]
    fn mu_quantize_rounds_to_nearest_odd() {
        // Even values are exactly between two odd grid points.
        let w = MuWord::quantize(4.0, 8);
        assert!((w.value() - 4).abs() == 1);
        let w = MuWord::quantize(0.3, 8);
        assert_eq!(w.value().abs(), 1);
        // Clamps at the rails.
        assert_eq!(MuWord::quantize(1e9, 8).value(), 255);
        assert_eq!(MuWord::quantize(-1e9, 8).value(), -255);
    }

    #[test]
    fn f64_views_match_integer_accessors() {
        let w = MuWord::quantize(-101.0, 8);
        for b in 0..8 {
            assert_eq!(w.digit_f64(b), w.digit(b) as f64);
        }
        let s = SigmaWord::quantize(11.0, 4);
        for b in 0..4 {
            assert_eq!(s.bit_f64(b), s.bit(b) as f64);
        }
    }

    #[test]
    fn mu_digits_match_value() {
        let w = MuWord::quantize(37.0, 8);
        let mut v = 0i32;
        for b in 0..8 {
            v += w.digit(b) << b;
        }
        assert_eq!(v, w.value());
    }

    #[test]
    fn sigma_quantize_clamps() {
        assert_eq!(SigmaWord::quantize(3.4, 4).value(), 3);
        assert_eq!(SigmaWord::quantize(99.0, 4).value(), 15);
        assert_eq!(SigmaWord::quantize(-2.0, 4).value(), 0);
        assert_eq!(SigmaWord::max_code(4), 15);
    }

    #[test]
    fn weight_scale_consistency() {
        let ws = WeightScale::fit(0.5, 0.1, 8, 4);
        let mu = ws.encode_mu(0.37);
        let back = ws.decode_mu(mu.value() as f64);
        assert!(
            (back - 0.37).abs() < 2.0 / ws.mu_scale,
            "μ error too large"
        );
        // σ at its own max fills its own grid.
        assert_eq!(ws.encode_sigma(0.1).value(), 15);
        let sg = ws.encode_sigma(0.05);
        assert!(sg.value() >= 7, "σ grid must resolve mid-range values");
        let back_s = ws.decode_sigma(sg.value() as f64);
        assert!((back_s - 0.05).abs() <= 0.5 / ws.sigma_scale + 1e-12);
    }
}
