//! Admission control for the network edge: backpressure plus
//! uncertainty-aware load shedding.
//!
//! The load signal is the coordinator's own bound —
//! `queue_depth / queue_capacity` — so the edge's thresholds compose with
//! the queue-capacity backpressure that already exists (`try_send` →
//! `QueueFull`) instead of inventing a second accounting. Three bands:
//!
//! ```text
//!   load < degrade_load             → Admit   (full-fidelity pass)
//!   degrade_load <= load < shed     → Degrade (cheap low-mc pass first)
//!   shed_load <= load               → Shed    (429 + Retry-After)
//! ```
//!
//! Degraded requests get the paper's headline feature pointed back at the
//! serving system: the cheap pass's [`UncertaintyReport`] verdict decides
//! what happens next. A confident cheap answer ships as-is (marked
//! `degraded`); an uncertain one is *escalated* to the originally
//! requested fidelity if capacity has recovered, and otherwise ships as
//! an explicit deferral — the response says the system declined to look
//! closer, rather than silently returning a low-quality answer.
//!
//! [`UncertaintyReport`]: crate::client::UncertaintyReport
//!
//! Decision functions are pure (load in, verdict out) so the state
//! machine is pinned by deterministic unit tests; the router samples the
//! live queue depth and applies them.

use crate::config::ServerConfig;

/// Thresholds governing the edge state machine (from `[server]` config).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Load fraction at which requests degrade to cheap passes.
    pub degrade_load: f64,
    /// Load fraction at which requests are refused outright.
    pub shed_load: f64,
    /// MC passes used for a degraded pass.
    pub degraded_mc_samples: usize,
    /// `Retry-After` hint \[ms\] for shed responses.
    pub retry_after_ms: u64,
}

/// What admission decided for one request at one load sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Run at the requested fidelity.
    Admit,
    /// Run a cheap pass at `mc_samples` first; the uncertainty verdict
    /// picks escalation vs explicit deferral.
    Degrade { mc_samples: usize },
    /// Refuse with 429; the client should retry after the hint.
    Shed { retry_after_ms: u64 },
}

impl AdmissionPolicy {
    pub fn from_config(cfg: &ServerConfig) -> Self {
        Self {
            degrade_load: cfg.edge_degrade_load,
            shed_load: cfg.edge_shed_load,
            degraded_mc_samples: cfg.edge_degraded_mc_samples,
            retry_after_ms: cfg.edge_retry_after_ms,
        }
    }

    /// Pure admission decision. `load` is the instantaneous queue-load
    /// fraction; `effective_mc` is the fidelity the request would run at
    /// if admitted (the requested `mc_samples`, or the model default when
    /// the request left it 0). Requests already at or below the degraded
    /// fidelity are admitted as-is inside the degrade band — degrading
    /// them would change nothing.
    pub fn decide(&self, load: f64, effective_mc: usize) -> Decision {
        if load >= self.shed_load {
            Decision::Shed {
                retry_after_ms: self.retry_after_ms,
            }
        } else if load >= self.degrade_load && effective_mc > self.degraded_mc_samples {
            Decision::Degrade {
                mc_samples: self.degraded_mc_samples,
            }
        } else {
            Decision::Admit
        }
    }

    /// After a degraded pass: escalate to full fidelity only when the
    /// cheap verdict came back uncertain (`deferred`) *and* the load has
    /// dropped back out of the shed band — otherwise the response ships
    /// as an explicit deferral.
    pub fn escalate(&self, load: f64, cheap_verdict_deferred: bool) -> bool {
        cheap_verdict_deferred && load < self.shed_load
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AdmissionPolicy {
        AdmissionPolicy {
            degrade_load: 0.6,
            shed_load: 0.9,
            degraded_mc_samples: 4,
            retry_after_ms: 250,
        }
    }

    #[test]
    fn bands_partition_the_load_axis() {
        let p = policy();
        assert_eq!(p.decide(0.0, 64), Decision::Admit);
        assert_eq!(p.decide(0.59, 64), Decision::Admit);
        // Band edges are inclusive: load == threshold trips the band.
        assert_eq!(p.decide(0.6, 64), Decision::Degrade { mc_samples: 4 });
        assert_eq!(p.decide(0.89, 64), Decision::Degrade { mc_samples: 4 });
        assert_eq!(p.decide(0.9, 64), Decision::Shed { retry_after_ms: 250 });
        assert_eq!(p.decide(2.0, 64), Decision::Shed { retry_after_ms: 250 });
    }

    #[test]
    fn cheap_requests_never_degrade() {
        let p = policy();
        // Already at/below the degraded fidelity: nothing to cut.
        assert_eq!(p.decide(0.7, 4), Decision::Admit);
        assert_eq!(p.decide(0.7, 1), Decision::Admit);
        assert_eq!(p.decide(0.7, 5), Decision::Degrade { mc_samples: 4 });
        // But shedding still applies regardless of fidelity.
        assert_eq!(p.decide(0.95, 1), Decision::Shed { retry_after_ms: 250 });
    }

    #[test]
    fn escalation_needs_uncertainty_and_headroom() {
        let p = policy();
        assert!(p.escalate(0.2, true), "uncertain + headroom → escalate");
        assert!(p.escalate(0.89, true), "below shed band still escalates");
        assert!(!p.escalate(0.9, true), "shed band → explicit deferral");
        assert!(!p.escalate(0.2, false), "confident cheap pass ships as-is");
        assert!(!p.escalate(1.5, false));
    }

    #[test]
    fn degenerate_thresholds_are_total() {
        // degrade == shed == 0: everything sheds (drain mode).
        let drain = AdmissionPolicy {
            degrade_load: 0.0,
            shed_load: 0.0,
            degraded_mc_samples: 1,
            retry_after_ms: 1,
        };
        assert_eq!(drain.decide(0.0, 8), Decision::Shed { retry_after_ms: 1 });
        // degrade 0, shed huge: everything (non-cheap) degrades, nothing
        // sheds, every uncertain verdict escalates — the overload test's
        // deterministic forcing mode.
        let degrade_all = AdmissionPolicy {
            degrade_load: 0.0,
            shed_load: 1e9,
            degraded_mc_samples: 2,
            retry_after_ms: 1,
        };
        assert_eq!(
            degrade_all.decide(0.0, 8),
            Decision::Degrade { mc_samples: 2 }
        );
        assert!(degrade_all.escalate(0.0, true));
    }
}
