//! Route table for the network edge: maps the `/v1` wire surface onto
//! the in-process client API (`Coordinator::submit_many` + `Ticket`s),
//! applying the admission policy on the way in.
//!
//! - `POST /v1/infer` — single object or `{"requests": [...]}` batch.
//! - `GET  /v1/metrics` — [`MetricsSnapshot`] as JSON (+ `render` text).
//! - `GET  /v1/health` — liveness + queue state + per-shard health
//!   (`healthy` / `restarting/n` / `dead`, DESIGN.md §9).
//!
//! Every [`ServeError`] has a fixed HTTP status (the taxonomy is part of
//! the wire contract, tested and documented in DESIGN.md §8): `QueueFull`
//! → 429, shape/bounds validation → 400, `ShuttingDown` → 503, `Timeout`
//! → 504, `Disconnected`/`ShardFailed` → 502, config/startup faults →
//! 500. A pool whose shards are *all* terminally dead is a service-level
//! condition, not a per-request one: `POST /v1/infer` then answers 503 +
//! `Retry-After` up front instead of a 502 per request.

use crate::client::{Coordinator, Infer, InferResponse, ServeError, ShardHealth, Ticket};
use crate::coordinator::Metrics;
use crate::edge::admission::{AdmissionPolicy, Decision};
use crate::edge::http::{Request, Response};
use crate::edge::json::{
    error_json, infer_batch_json, infer_response_json, metrics_json, scan_infer_batch, Disposition,
    WireInfer,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub struct Router {
    coord: Arc<Coordinator>,
    policy: AdmissionPolicy,
    metrics: Metrics,
    shards: usize,
    /// Round-robin cursor for attributing shed requests (they never
    /// reach a shard, so the split is advisory; the global sum is exact).
    shed_rr: AtomicUsize,
    /// Model-default MC passes (what `mc_samples: 0` resolves to).
    default_mc: usize,
    request_timeout: Duration,
}

impl Router {
    pub fn new(coord: Arc<Coordinator>) -> Self {
        let cfg = coord.config();
        let policy = AdmissionPolicy::from_config(&cfg.server);
        let metrics = coord.metrics_registry();
        let shards = coord.workers();
        let default_mc = cfg.model.mc_samples;
        let request_timeout = Duration::from_secs_f64(cfg.server.request_timeout_ms / 1e3);
        Self {
            coord,
            policy,
            metrics,
            shards,
            shed_rr: AtomicUsize::new(0),
            default_mc,
            request_timeout,
        }
    }

    pub fn handle(&self, req: &Request) -> Response {
        match (req.method.as_str(), req.path()) {
            ("POST", "/v1/infer") => self.infer(req),
            ("GET", "/v1/metrics") => {
                Response::json(200, metrics_json(&self.coord.metrics()))
            }
            ("GET", "/v1/health") => self.health(),
            (_, "/v1/infer") => method_not_allowed("POST"),
            (_, "/v1/metrics") | (_, "/v1/health") => method_not_allowed("GET"),
            _ => Response::json(
                404,
                error_json("not_found", "unknown path (try /v1/infer)", None),
            ),
        }
    }

    fn health(&self) -> Response {
        let cfg = self.coord.config();
        let health = self.coord.shard_health();
        let healthy = health
            .iter()
            .filter(|h| **h == ShardHealth::Healthy)
            .count();
        // Service-level verdict: `ok` (all serving), `degraded` (some
        // shards down or restarting), `unhealthy` (none serving).
        let status = if healthy == health.len() {
            "ok"
        } else if healthy > 0 {
            "degraded"
        } else {
            "unhealthy"
        };
        let shard_labels = health
            .iter()
            .map(|h| format!("\"{}\"", h.label()))
            .collect::<Vec<_>>()
            .join(",");
        let body = format!(
            "{{\"status\":\"{status}\",\"backend\":\"{}\",\"workers\":{},\
             \"healthy_workers\":{healthy},\"shards\":[{shard_labels}],\
             \"queue_depth\":{},\"queue_capacity\":{}}}",
            cfg.server.backend.name(),
            self.shards,
            self.coord.queue_depth(),
            self.coord.queue_capacity(),
        );
        Response::json(200, body)
    }

    /// Instantaneous queue-load fraction — the admission signal.
    fn load(&self) -> f64 {
        self.coord.queue_depth() as f64 / self.coord.queue_capacity().max(1) as f64
    }

    /// The fidelity a wire request runs at if admitted unmodified.
    fn effective_mc(&self, w: &WireInfer) -> usize {
        if w.mc_samples == 0 {
            self.default_mc
        } else {
            w.mc_samples
        }
    }

    /// Shard a response was computed on: batches route round-robin on
    /// batch id (`target = (batch_id - 1) % shards`), so attribution is
    /// derivable without plumbing shard ids through the reply path.
    fn shard_of(&self, batch_id: u64) -> usize {
        (batch_id.saturating_sub(1) % self.shards.max(1) as u64) as usize
    }

    fn record_shed(&self, n: usize) {
        for _ in 0..n {
            // RELAXED: pure round-robin attribution counter; fetch_add
            // is already atomic and no ordering with other memory is
            // implied by which shard a shed is charged to.
            let shard = self.shed_rr.fetch_add(1, Ordering::Relaxed) % self.shards.max(1);
            self.metrics.record_shed(shard);
        }
    }

    fn infer(&self, req: &Request) -> Response {
        let (wire, was_batch) = match scan_infer_batch(&req.body) {
            Ok(parsed) => parsed,
            Err(msg) => return Response::json(400, error_json("bad_request", &msg, None)),
        };

        // A pool whose shards are all terminally dead can never serve
        // again: answer 503 + Retry-After once, at the service level,
        // instead of submitting and collecting a 502 per request.
        if self.coord.all_shards_dead() {
            return unhealthy_response(self.policy.retry_after_ms);
        }

        // One admission decision per HTTP request (the batch is one
        // caller): the most expensive member sets the band.
        let load = self.load();
        let max_mc = wire.iter().map(|w| self.effective_mc(w)).max().unwrap_or(0);
        let decision = self.policy.decide(load, max_mc);

        if let Decision::Shed { retry_after_ms } = decision {
            self.record_shed(wire.len());
            return shed_response(retry_after_ms, load);
        }

        // Build the admitted submissions; degraded members are clamped to
        // the cheap fidelity (members already at/below it keep their ask).
        let degraded_mc = match decision {
            Decision::Degrade { mc_samples } => Some(mc_samples),
            _ => None,
        };
        let mut admitted_mc = Vec::with_capacity(wire.len());
        let mut was_degraded = Vec::with_capacity(wire.len());
        for w in &wire {
            let eff = self.effective_mc(w);
            match degraded_mc {
                Some(cheap) if eff > cheap => {
                    admitted_mc.push(cheap);
                    was_degraded.push(true);
                }
                _ => {
                    admitted_mc.push(w.mc_samples);
                    was_degraded.push(false);
                }
            }
        }

        let submissions: Vec<Infer> = wire
            .iter()
            .zip(&admitted_mc)
            .map(|(w, &mc)| build_infer(w, mc))
            .collect();
        let mut responses = match self.submit_and_wait(submissions) {
            Ok(r) => r,
            Err(e) => return self.error_response(&e, wire.len()),
        };

        // Uncertainty-aware escalation: a degraded member whose cheap
        // verdict is uncertain gets the full pass it originally asked
        // for — if the load has stayed out of the shed band. Otherwise
        // the degraded response ships as an explicit deferral.
        let mut disposition = vec![Disposition::default(); wire.len()];
        let escalation_load = self.load();
        let mut escalate_idx = Vec::new();
        for (i, resp) in responses.iter().enumerate() {
            if was_degraded[i] {
                self.metrics.record_degraded(self.shard_of(resp.batch_id));
                disposition[i].degraded = true;
                if self
                    .policy
                    .escalate(escalation_load, resp.uncertainty.deferred)
                {
                    escalate_idx.push(i);
                }
            }
        }
        if !escalate_idx.is_empty() {
            let full: Vec<Infer> = escalate_idx
                .iter()
                .map(|&i| build_infer(&wire[i], wire[i].mc_samples))
                .collect();
            // Escalation is best-effort: if capacity vanished between the
            // load sample and the resubmit, the degraded deferral stands.
            if let Ok(upgraded) = self.submit_and_wait(full) {
                for (&i, up) in escalate_idx.iter().zip(upgraded) {
                    self.metrics.record_escalated(self.shard_of(up.batch_id));
                    disposition[i].escalated = true;
                    responses[i] = up;
                }
            }
        }

        let items: Vec<(InferResponse, Disposition)> =
            responses.into_iter().zip(disposition).collect();
        if was_batch {
            Response::json(200, infer_batch_json(&items))
        } else {
            Response::json(200, infer_response_json(&items[0].0, items[0].1))
        }
    }

    /// `submit_many` + sequential waits (each gets the full request
    /// deadline — the coordinator already bounds per-request latency).
    fn submit_and_wait(
        &self,
        submissions: Vec<Infer>,
    ) -> Result<Vec<InferResponse>, ServeError> {
        let tickets: Vec<Ticket> = self.coord.submit_many(submissions)?;
        tickets
            .iter()
            .map(|t| t.wait_timeout(self.request_timeout))
            .collect()
    }

    fn error_response(&self, e: &ServeError, n_requests: usize) -> Response {
        let status = status_for(e);
        if status == 429 {
            // Queue-capacity backpressure is a shed, observably: the
            // admission bands and the hard bound share one ledger.
            self.record_shed(n_requests);
            return shed_response(self.policy.retry_after_ms, self.load());
        }
        Response::json(status, error_json(error_kind(e), &e.to_string(), None))
    }
}

fn build_infer(w: &WireInfer, mc_samples: usize) -> Infer {
    let mut inf = Infer::new(w.pixels.clone()).mc_samples(mc_samples);
    if let Some(t) = w.defer_threshold {
        inf = inf.defer_threshold(t);
    }
    inf
}

fn method_not_allowed(allow: &str) -> Response {
    Response::json(
        405,
        error_json("method_not_allowed", &format!("use {allow}"), None),
    )
    .with_header("Allow", allow)
}

fn shed_response(retry_after_ms: u64, load: f64) -> Response {
    // The HTTP header speaks whole seconds; the body carries the exact
    // millisecond hint.
    let secs = retry_after_ms.div_ceil(1000).max(1);
    Response::json(
        429,
        error_json(
            "shed",
            &format!("overloaded (queue load {load:.2}); retry after {retry_after_ms} ms"),
            Some(retry_after_ms),
        ),
    )
    .with_header("Retry-After", &secs.to_string())
}

/// Every shard is terminally dead: the service cannot serve. 503 with a
/// `Retry-After` (an operator restart is the only way back), the same
/// shape a shutting-down pool answers with.
fn unhealthy_response(retry_after_ms: u64) -> Response {
    let secs = retry_after_ms.div_ceil(1000).max(1);
    Response::json(
        503,
        error_json(
            "unhealthy",
            "service unhealthy: every shard is dead (restart limit exhausted)",
            Some(retry_after_ms),
        ),
    )
    .with_header("Retry-After", &secs.to_string())
}

/// The `ServeError` → HTTP status taxonomy (wire contract).
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::QueueFull => 429,
        ServeError::WrongShape { .. }
        | ServeError::McSamplesTooLarge { .. }
        | ServeError::InvalidDeferThreshold { .. } => 400,
        ServeError::ShuttingDown => 503,
        ServeError::Timeout => 504,
        // Per-request serving failures past the retry budget: the pool
        // may still be healthy for other requests, so these are 502s —
        // only the all-shards-dead pre-check escalates to a 503.
        ServeError::Disconnected => 502,
        ServeError::ShardFailed { .. } => 502,
        ServeError::Config(_) | ServeError::Startup(_) => 500,
    }
}

fn error_kind(e: &ServeError) -> &'static str {
    match e {
        ServeError::QueueFull => "queue_full",
        ServeError::WrongShape { .. } => "wrong_shape",
        ServeError::McSamplesTooLarge { .. } => "mc_samples_too_large",
        ServeError::InvalidDeferThreshold { .. } => "invalid_defer_threshold",
        ServeError::ShuttingDown => "shutting_down",
        ServeError::Timeout => "timeout",
        ServeError::Disconnected => "disconnected",
        ServeError::ShardFailed { .. } => "shard_failed",
        ServeError::Config(_) => "config",
        ServeError::Startup(_) => "startup",
    }
}
